//! Offline drop-in shim for the parts of `anyhow` this workspace uses.
//!
//! The image builds without a crates.io cache, so the real `anyhow` cannot
//! be fetched. This shim keeps the same surface — [`Error`], [`Result`],
//! [`Context`], `anyhow!`, `bail!`, `ensure!` — with a simpler
//! representation: an error is a chain of messages (outermost context
//! first). Downcasting and backtraces are not supported; nothing in this
//! workspace needs them.

use std::fmt;

/// A message-chain error type. `Display` shows the outermost message;
/// `{:#}` (alternate) shows the whole chain joined by `": "`, matching how
/// anyhow renders context chains.
pub struct Error {
    /// Outermost message first.
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts = self.chain();
        if let Some(first) = parts.next() {
            f.write_str(first)?;
        }
        let mut caused = false;
        for part in parts {
            if !caused {
                f.write_str("\n\nCaused by:")?;
                caused = true;
            }
            write!(f, "\n    {part}")?;
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Sealed conversion helper so [`Context`] applies both to foreign error
/// types and to `anyhow::Result` itself.
pub trait IntoError: Sized {
    fn into_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_render() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing flag").unwrap_err();
        assert_eq!(e.to_string(), "missing flag");
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root {}", 42);
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let b = anyhow!("x={}", 7);
        assert_eq!(b.to_string(), "x=7");
        fn guarded(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            Ok(1)
        }
        assert!(guarded(true).is_ok());
        assert!(guarded(false).is_err());
    }
}
