//! Offline stub of the `xla` crate surface used by
//! `nimrod_g::runtime::ChamberRuntime`.
//!
//! The real PJRT bindings need the XLA native library, which this image
//! does not carry. The stub keeps the runtime bridge compiling; every entry
//! point fails with [`Error::Unavailable`], so callers take their existing
//! "artifacts not built" skip paths (live mode checks `manifest.json`
//! before touching PJRT, and `ChamberRuntime::load` surfaces the error).

use std::fmt;

/// Stub error: PJRT is unavailable in this build.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("PJRT/XLA native runtime is not available in this offline build")
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Host literal (stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}
