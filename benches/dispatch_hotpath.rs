//! Bench: hot-path micro-benchmarks (EXPERIMENTS.md §Perf).
//!
//! The per-cycle costs of a live deployment: scheduler tick (policy
//! allocation over N resource views), candidate-index re-keying
//! (per-entry vs chunked bulk over ViewColumns), dispatcher
//! reconciliation, the broker's ScheduleAdvisor facade versus the inlined
//! pipeline (`broker_overhead`), event queue throughput, Clustor frame
//! encode/decode, and the PJRT chamber executions the job-wrapper performs
//! (batch-1 and full-batch).
//!
//! ```bash
//! make artifacts && cargo bench --bench dispatch_hotpath
//! ```

use nimrod_g::broker::{PolicyRegistry, ScheduleAdvisor, TickCtx};
use nimrod_g::dispatcher::plan_actions;
use nimrod_g::engine::Experiment;
use nimrod_g::plan::{expand, Plan};
use nimrod_g::protocol::{read_frame, write_frame, Message};
use nimrod_g::runtime::ChamberRuntime;
use nimrod_g::scheduler::{CandidateIndex, ResourceView, SchedCtx, ViewColumns};
use nimrod_g::simtime::EventQueue;
use nimrod_g::types::{ResourceId, HOUR};
use nimrod_g::util::bench::Bench;
use nimrod_g::util::rng::Rng;

fn views(n: usize, rng: &mut Rng) -> Vec<ResourceView> {
    (0..n)
        .map(|i| ResourceView {
            id: ResourceId(i as u32),
            slots: rng.range(1, 16) as u32,
            planning_speed: rng.uniform(0.4, 2.0),
            rate: rng.uniform(0.2, 3.0),
            in_flight: 0,
            measured_jphps: None,
            batch_queue: rng.chance(0.4),
        })
        .collect()
}

fn experiment(jobs: usize) -> Experiment {
    let src = format!(
        "parameter i integer range from 1 to {jobs}\ntask main\nexecute run $i\nendtask"
    );
    let specs = expand(&Plan::parse(&src).unwrap(), 0).unwrap();
    Experiment::new(specs, 15.0 * HOUR, None, "u", 3)
}

fn main() {
    let registry = PolicyRegistry::with_builtins();
    let mut b = Bench::new("dispatch hot path");

    // Scheduler tick at GUSTO and 8x-GUSTO sizes, index-backed (the index
    // is built once, as the drivers maintain it persistently).
    for n in [70, 280, 560] {
        let mut rng = Rng::new(1);
        let vs = views(n, &mut rng);
        let ix = CandidateIndex::from_views(&vs);
        let mut policy = registry.resolve("cost").unwrap();
        b.iter(&format!("cost-opt allocate ({n} resources)"), || {
            let mut ctx = SchedCtx {
                now: 0.0,
                deadline: 15.0 * HOUR,
                budget_headroom: Some(1e9),
                remaining_jobs: 165,
                job_work_ref_h: 2.0,
                resources: &vs,
                candidates: &ix,
                rng: &mut rng,
            };
            policy.allocate(&mut ctx)
        });
        b.iter(&format!("candidate-index full re-rank ({n} resources)"), || {
            CandidateIndex::from_views(&vs).len()
        });
        b.iter(&format!("ranked walks, all dims ({n} resources)"), || {
            ix.cost_ranked().count()
                + ix.speed_ranked().count()
                + ix.rate_ranked().count()
                + ix.service_ranked().count()
        });
    }

    // Dirty-queue re-key: per-entry update_cols versus the chunked
    // update_cols_bulk used when a drained dirty queue crosses the bulk
    // threshold — same keys (shared `_parts` helpers), different key
    // derivation shape (columnar chunks vs one row at a time).
    for n in [280, 560] {
        let mut rng = Rng::new(4);
        let vs = views(n, &mut rng);
        let mut cols = ViewColumns::new(n);
        for v in &vs {
            cols.set(v);
        }
        // A churny tick's worth of dirty entries: every 3rd resource.
        let dirty: Vec<u32> = (0..n as u32).step_by(3).collect();
        let mut ix_per = CandidateIndex::from_views(&vs);
        b.iter(
            &format!("re-key per-entry ({} of {n} dirty)", dirty.len()),
            || {
                for &r in &dirty {
                    ix_per.update_cols(ResourceId(r), &cols);
                }
                ix_per.len()
            },
        );
        let mut ix_bulk = CandidateIndex::from_views(&vs);
        b.iter(
            &format!("re-key chunked bulk ({} of {n} dirty)", dirty.len()),
            || {
                ix_bulk.update_cols_bulk(&dirty, &cols);
                ix_bulk.len()
            },
        );
    }

    // Dispatcher reconciliation against a 165-job table.
    {
        let exp = experiment(165);
        let mut rng = Rng::new(2);
        let vs = views(70, &mut rng);
        let ix = CandidateIndex::from_views(&vs);
        let mut policy = registry.resolve("cost").unwrap();
        let alloc = {
            let mut ctx = SchedCtx {
                now: 0.0,
                deadline: 15.0 * HOUR,
                budget_headroom: None,
                remaining_jobs: 165,
                job_work_ref_h: 2.0,
                resources: &vs,
                candidates: &ix,
                rng: &mut rng,
            };
            policy.allocate(&mut ctx)
        };
        b.iter("plan_actions (165 jobs, 70 resources)", || {
            plan_actions(&alloc, &exp)
        });
    }

    // broker_overhead: the full selection+assignment tick, inlined versus
    // through the ScheduleAdvisor facade — the facade must add no
    // measurable per-tick cost.
    {
        let exp = experiment(165);
        let mut rng = Rng::new(3);
        let vs = views(70, &mut rng);
        let ix = CandidateIndex::from_views(&vs);
        let mut policy = registry.resolve("cost").unwrap();
        b.iter("tick inlined (policy + plan_actions, 70 res)", || {
            let alloc = {
                let mut ctx = SchedCtx {
                    now: 0.0,
                    deadline: 15.0 * HOUR,
                    budget_headroom: Some(1e9),
                    remaining_jobs: exp.remaining(),
                    job_work_ref_h: 2.0,
                    resources: &vs,
                    candidates: &ix,
                    rng: &mut rng,
                };
                policy.allocate(&mut ctx)
            };
            plan_actions(&alloc, &exp)
        });
        let mut advisor = ScheduleAdvisor::resolve("cost", 2.0).unwrap();
        b.iter("broker_overhead: tick via ScheduleAdvisor", || {
            advisor.advise(
                TickCtx {
                    now: 0.0,
                    deadline: 15.0 * HOUR,
                    budget_headroom: Some(1e9),
                    views: &vs,
                    candidates: &ix,
                },
                &exp,
                &mut rng,
            )
        });
    }

    // Event queue throughput.
    b.iter("event queue push+pop x1000", || {
        let mut q = EventQueue::new();
        for i in 0..1000u32 {
            q.schedule_at((i % 97) as f64, i);
        }
        let mut acc = 0u64;
        while let Some((_, e)) = q.pop() {
            acc += e as u64;
        }
        acc
    });

    // Protocol framing.
    b.iter("protocol frame encode+decode", || {
        let msg = Message::Status {
            jobs_total: 165,
            jobs_completed: 42,
            jobs_failed: 1,
            jobs_running: 8,
            spent: 1234.5,
            busy_workers: 8,
            elapsed_s: 77.7,
        };
        let mut buf = Vec::with_capacity(256);
        write_frame(&mut buf, &msg).unwrap();
        read_frame(&mut &buf[..]).unwrap()
    });

    // PJRT execution (the job-wrapper's compute call).
    let dir = ChamberRuntime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        match ChamberRuntime::load(&dir) {
            Ok(rt) => {
                let batch = rt.batch_size();
                b.iter("pjrt chamber execute (batch=1)", || {
                    rt.run(&[[400.0, 1.0, 10.0]]).unwrap()
                });
                let params: Vec<[f32; 3]> = (0..batch)
                    .map(|i| [200.0 + i as f32 * 40.0, 1.0, 10.0])
                    .collect();
                b.iter(&format!("pjrt chamber execute (batch={batch})"), || {
                    rt.run(&params).unwrap()
                });
            }
            Err(e) => eprintln!("(skipping PJRT cases: {e:#})"),
        }
    } else {
        eprintln!("(skipping PJRT cases: run `make artifacts` first)");
    }

    b.report();
}
