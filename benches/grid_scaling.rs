//! Bench: architecture scaling (paper §2/Figure 1-2).
//!
//! The component architecture must keep up as the grid grows: this bench
//! scales the GUSTO-like testbed from ~35 to ~560 machines and measures
//! (a) end-to-end experiment wall time, (b) simulator event throughput,
//! and (c) MDS discovery + scheduler tick latency at each size — the
//! pieces that run on every scheduling cycle in a live deployment.
//!
//! ```bash
//! cargo bench --bench grid_scaling
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::grid::dynamics::ResourceDyn;
use nimrod_g::grid::mds::Mds;
use nimrod_g::grid::Testbed;
use nimrod_g::types::HOUR;
use nimrod_g::util::bench::Bench;
use nimrod_g::util::rng::Rng;

fn main() {
    println!("== grid scaling: testbed size sweep ==\n");
    println!(
        "{:<10} {:>10} {:>8} {:>12} {:>14} {:>12}",
        "scale", "machines", "cpus", "makespan(h)", "sim events", "wall(ms)"
    );
    for scale in [0.5, 1.0, 2.0, 4.0, 8.0] {
        let tb = Testbed::gusto(3, scale);
        let (machines, cpus) = (tb.resources.len(), tb.total_cpus());
        let t0 = std::time::Instant::now();
        let r = Broker::experiment()
            .deadline_h(15.0)
            .policy("cost")
            .seed(0x5CA1E)
            .testbed(tb)
            .run()
            .expect("scaling experiment");
        let wall = t0.elapsed();
        println!(
            "{scale:<10} {machines:>10} {cpus:>8} {:>12.2} {:>14} {:>12.1}",
            r.makespan_s / HOUR,
            r.events,
            wall.as_secs_f64() * 1e3
        );
    }

    // Per-cycle costs: MDS refresh + discovery at each testbed size.
    let mut b = Bench::new("per-cycle component costs");
    for scale in [1.0, 4.0, 8.0] {
        let tb = Testbed::gusto(3, scale);
        let mut rng = Rng::new(1);
        let dyns: Vec<ResourceDyn> = tb
            .resources
            .iter()
            .map(|s| ResourceDyn::new(s, &mut rng))
            .collect();
        let mut mds = Mds::new(&tb, &dyns);
        let n = tb.resources.len();
        b.iter(&format!("mds refresh ({n} machines)"), || {
            mds.refresh(&tb, &dyns, 0.0)
        });
        b.iter(&format!("discovery ({n} machines)"), || {
            mds.discover(&tb, "rajkumar").count()
        });
    }
    b.report();
}
