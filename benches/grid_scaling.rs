//! Bench: architecture scaling (paper §2/Figure 1-2).
//!
//! The component architecture must keep up as the grid grows. Sections:
//!
//! 1. **End-to-end GUSTO sweep** — scale the GUSTO-like testbed ~35 → ~560
//!    machines, measure experiment wall time and event throughput.
//! 2. **Incremental tick sweep (100 → 10,000 machines)** — the headline
//!    measurement for the event-driven pipeline: on a *quiet* synthetic
//!    grid (flat prices, frozen load, no churn) per-tick view maintenance
//!    must be O(changed), not O(resources), and policy allocation must run
//!    off the incrementally re-keyed candidate index, not per-tick sorts.
//!    Each size runs three times — the incremental pipeline, the same
//!    simulation forced to rebuild every view every tick
//!    (`set_full_view_rebuild`), and the same simulation forced to re-rank
//!    the whole candidate index every tick (`set_full_allocation_sort`,
//!    the sort-every-tick allocation baseline) — and all three must replay
//!    the identical trace. `touched/tick` is the direct sub-linearity
//!    evidence for discovery; the index-vs-full-sort µs/tick ratio is the
//!    allocation-phase evidence.
//! 3. **Multi-tenant sweep (1 → 8 tenants, one shared 1,000-machine
//!    grid)** — N co-scheduled brokers dirty each other's view tables and
//!    indexes, so this measures that cross-tenant dirtying keeps per-tick
//!    maintenance O(changed) instead of O(tenants × resources).
//! 4. **GRACE auction vs posted sweep** — market-layer overhead per tick.
//! 5. **Advance-reservation on/off sweep** — per-tick cost of the hold
//!    machinery (shadow probes, expiry sweeps, occupancy folding) versus
//!    the same world with the subsystem left off.
//! 6. **Parallel-tick thread sweep** — many-tenant churny worlds
//!    (index-storm-, mega-grid- and world-storm-shaped) run at 1/2/4/8
//!    workers, each multi-thread count three ways: through the persistent
//!    worker pool with the default streaming ordered merge, through the
//!    same pool forced back onto the barrier merge
//!    (`set_barrier_merge`), and through the per-batch
//!    `std::thread::scope` spawn baseline (always barrier). Every thread
//!    count, spawn mode and merge mode must replay the identical trace
//!    (asserted); the JSON `thread_sweep` rows carry µs/tick, speedup vs
//!    1 thread, the spawn/merge-mode axes, the merge share of the
//!    batched tick, and `merge_overlap` — the fraction of commit time
//!    the streaming merge hid under still-running shards.
//! 7. **Per-cycle component costs** — MDS refresh/discovery latency.
//!
//! Results are also written to `BENCH_grid_scaling.json` (machine-readable:
//! µs/tick, touched/tick, allocation-phase share, index-vs-full-sort
//! speedup per size, reservation on/off overhead, thread-sweep speedups) —
//! CI archives it as the perf-trajectory artifact.
//!
//! ```bash
//! cargo bench --bench grid_scaling              # full sweep (10k machines)
//! cargo bench --bench grid_scaling -- --quick   # CI smoke (≤1k machines)
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::config::WorkloadConfig;
use nimrod_g::economy::market::GraceConfig;
use nimrod_g::economy::reservation::ReservationConfig;
use nimrod_g::grid::dynamics::ResourceDyn;
use nimrod_g::grid::mds::Mds;
use nimrod_g::grid::Testbed;
use nimrod_g::metrics::{Report, WorldReport};
use nimrod_g::types::HOUR;
use nimrod_g::util::bench::Bench;
use nimrod_g::util::json::Json;
use nimrod_g::util::rng::Rng;
use std::collections::BTreeMap;

/// Make a grid "quiet": flat prices, frozen background load, no failures
/// inside the run. Nothing dirties the view table except the experiment's
/// own job traffic, which is what isolates view-maintenance scaling.
fn quiet(mut tb: Testbed) -> Testbed {
    for spec in &mut tb.resources {
        spec.bg_load_mean = 0.0;
        spec.bg_load_vol = 0.0;
        spec.mtbf_s = 1e12;
        spec.price.time_of_day = false;
    }
    tb
}

/// Run the fixed 2,000-job workload over `tb`, returning wall seconds and
/// the report. `full_view_rebuild` switches the view table to the
/// rebuilt-every-tick baseline; `full_alloc_sort` switches allocation to
/// the sort-every-tick candidate-ranking baseline.
fn sweep_run(
    tb: Testbed,
    full_view_rebuild: bool,
    full_alloc_sort: bool,
) -> (f64, Report) {
    let mut sim = Broker::experiment()
        .plan(
            "parameter i integer range from 1 to 2000\n\
             task main\nexecute chamber $i\nendtask",
        )
        .workload(WorkloadConfig {
            job_work_ref_h: 0.25,
            ..WorkloadConfig::default()
        })
        .deadline_h(12.0)
        .policy("cost")
        .seed(0x10C4)
        .testbed(tb)
        .simulate()
        .expect("sweep sim");
    sim.set_full_view_rebuild(full_view_rebuild);
    sim.set_full_allocation_sort(full_alloc_sort);
    let t0 = std::time::Instant::now();
    let report = sim.run();
    (t0.elapsed().as_secs_f64(), report)
}

/// Run `tenants` co-scheduled 500-job time-optimizing brokers on one quiet
/// synthetic grid; returns wall seconds and the world report. `market`
/// switches the world from posted prices to periodic GRACE auctions;
/// `rsv` switches on the advance-reservation subsystem.
fn tenant_sweep_run(
    tb: Testbed,
    tenants: usize,
    full_view_rebuild: bool,
    market: Option<GraceConfig>,
    rsv: Option<ReservationConfig>,
) -> (f64, WorldReport) {
    let plan = "parameter i integer range from 1 to 500\n\
                task main\nexecute chamber $i\nendtask";
    let light = WorkloadConfig {
        job_work_ref_h: 0.25,
        ..WorkloadConfig::default()
    };
    let mut b = Broker::experiment()
        .plan(plan)
        .workload(light.clone())
        .deadline_h(10.0)
        .policy("time")
        .seed(0x7E4A)
        .testbed(tb);
    if let Some(cfg) = market {
        b = b.grace_market(cfg);
    }
    if let Some(cfg) = rsv {
        b = b.reservations(cfg);
    }
    for k in 1..tenants {
        b = b.tenant(
            Broker::experiment()
                .plan(plan)
                .workload(light.clone())
                .deadline_h(10.0 + k as f64)
                .policy("time")
                .user(&format!("tenant{k}")),
        );
    }
    let mut world = b.world().expect("tenant sweep world");
    world.set_full_view_rebuild(full_view_rebuild);
    let t0 = std::time::Instant::now();
    let report = world.run_world();
    (t0.elapsed().as_secs_f64(), report)
}

/// Run a churny, demand-priced, many-tenant world (the index-storm shape:
/// heavy dirty-view traffic, every tenant ticking on the same period so
/// tick batches hold all of them) at `threads` workers. `scoped_spawn`
/// switches phase 2 from the persistent worker pool to the per-batch
/// `std::thread::scope` baseline it replaced; `barrier_merge` forces the
/// pooled path back onto the drain-after-barrier merge instead of the
/// streaming commit queue — same trace either way, different overlap.
/// Returns wall seconds and the world report; the caller compares traces
/// across thread counts, spawn modes and merge modes.
fn storm_run(
    tb: Testbed,
    tenants: usize,
    jobs: usize,
    threads: usize,
    scoped_spawn: bool,
    barrier_merge: bool,
) -> (f64, WorldReport) {
    let plan = format!(
        "parameter i integer range from 1 to {jobs}\n\
         task main\nexecute chamber $i\nendtask"
    );
    let light = WorkloadConfig {
        job_work_ref_h: 0.25,
        ..WorkloadConfig::default()
    };
    let policies = ["cost", "time", "deadline-only"];
    let mut b = Broker::experiment()
        .plan(plan.as_str())
        .workload(light.clone())
        .deadline_h(10.0)
        .policy("cost")
        .user("storm0")
        .seed(0x57A2)
        .demand_pricing(0.7)
        .testbed(tb)
        .threads(threads)
        .tweak_testbed(|tb| {
            for spec in &mut tb.resources {
                spec.mtbf_s = 2.5 * 3600.0;
                spec.mttr_s = 0.5 * 3600.0;
            }
        });
    for k in 1..tenants {
        b = b.tenant(
            Broker::experiment()
                .plan(plan.as_str())
                .workload(light.clone())
                // Staggered deadlines, identical tick periods: schedules
                // diverge per tenant but ticks stay coincident, so every
                // batch carries the full tenant set.
                .deadline_h(10.0 + 0.5 * (k % 8) as f64)
                .policy(policies[k % policies.len()])
                .user(&format!("storm{k}")),
        );
    }
    let mut world = b.world().expect("thread sweep world");
    world.set_scoped_spawn(scoped_spawn);
    world.set_barrier_merge(barrier_merge);
    let t0 = std::time::Instant::now();
    let report = world.run_world();
    (t0.elapsed().as_secs_f64(), report)
}

/// Allocation-phase share of a run's wall time (policy selection +
/// dispatcher reconciliation nanoseconds over total wall seconds).
fn alloc_share(report: &Report, wall_s: f64) -> f64 {
    if wall_s <= 0.0 {
        return 0.0;
    }
    (report.alloc_ns as f64 / 1e9) / wall_s
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut grid_rows: Vec<Json> = Vec::new();
    let mut tenant_rows: Vec<Json> = Vec::new();

    println!("== grid scaling: GUSTO end-to-end sweep ==\n");
    println!(
        "{:<10} {:>10} {:>8} {:>12} {:>14} {:>12}",
        "scale", "machines", "cpus", "makespan(h)", "sim events", "wall(ms)"
    );
    let scales: &[f64] = if quick {
        &[0.5, 1.0]
    } else {
        &[0.5, 1.0, 2.0, 4.0, 8.0]
    };
    for &scale in scales {
        let tb = Testbed::gusto(3, scale);
        let (machines, cpus) = (tb.resources.len(), tb.total_cpus());
        let t0 = std::time::Instant::now();
        let r = Broker::experiment()
            .deadline_h(15.0)
            .policy("cost")
            .seed(0x5CA1E)
            .testbed(tb)
            .run()
            .expect("scaling experiment");
        let wall = t0.elapsed();
        println!(
            "{scale:<10} {machines:>10} {cpus:>8} {:>12.2} {:>14} {:>12.1}",
            r.makespan_s / HOUR,
            r.events,
            wall.as_secs_f64() * 1e3
        );
    }

    println!("\n== incremental pipeline: quiet-grid sweep ==\n");
    println!(
        "{:<10} {:>7} {:>13} {:>13} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "machines",
        "ticks",
        "touched/tick",
        "touched/tick",
        "µs/tick",
        "µs/tick",
        "µs/tick",
        "view",
        "alloc"
    );
    println!(
        "{:<10} {:>7} {:>13} {:>13} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "",
        "",
        "(increm.)",
        "(rebuild)",
        "(increm.)",
        "(rebuild)",
        "(fullsort)",
        "speedup",
        "speedup"
    );
    // sites × per-site: 100, 1,000, 3,000, 10,000 machines.
    let shapes: &[(usize, usize)] = if quick {
        &[(4, 25), (20, 50)]
    } else {
        &[(4, 25), (20, 50), (60, 50), (100, 100)]
    };
    for &(sites, per_site) in shapes {
        let tb = quiet(Testbed::synthetic(sites, per_site, 7));
        let machines = tb.resources.len();
        let (wall_inc, ri) = sweep_run(tb.clone(), false, false);
        let (wall_full, rf) = sweep_run(tb.clone(), true, false);
        let (wall_sort, rs) = sweep_run(tb, false, true);
        // Same trace, different maintenance cost — anything else is a bug.
        assert_eq!(ri.events, rf.events, "incremental trace diverged");
        assert_eq!(ri.ticks, rf.ticks, "incremental tick count diverged");
        assert_eq!(
            ri.makespan_s.to_bits(),
            rf.makespan_s.to_bits(),
            "incremental timeline diverged"
        );
        assert_eq!(ri.events, rs.events, "full-sort trace diverged");
        assert_eq!(ri.ticks, rs.ticks, "full-sort tick count diverged");
        assert_eq!(
            ri.makespan_s.to_bits(),
            rs.makespan_s.to_bits(),
            "full-sort timeline diverged"
        );
        let ticks = ri.ticks.max(1);
        let us_inc = wall_inc * 1e6 / ticks as f64;
        let us_full = wall_full * 1e6 / ticks as f64;
        let us_sort = wall_sort * 1e6 / ticks as f64;
        println!(
            "{machines:<10} {ticks:>7} {:>13.1} {:>13.1} {us_inc:>11.1} {us_full:>11.1} {us_sort:>11.1} {:>8.2}x {:>8.2}x",
            ri.view_refreshes as f64 / ticks as f64,
            rf.view_refreshes as f64 / ticks as f64,
            wall_full / wall_inc.max(1e-9),
            wall_sort / wall_inc.max(1e-9),
        );
        grid_rows.push(Json::obj(vec![
            ("machines", Json::num(machines as f64)),
            ("ticks", Json::num(ticks as f64)),
            (
                "touched_per_tick_incremental",
                Json::num(ri.view_refreshes as f64 / ticks as f64),
            ),
            (
                "touched_per_tick_rebuild",
                Json::num(rf.view_refreshes as f64 / ticks as f64),
            ),
            ("us_per_tick_index", Json::num(us_inc)),
            ("us_per_tick_view_rebuild", Json::num(us_full)),
            ("us_per_tick_full_sort", Json::num(us_sort)),
            ("alloc_share_index", Json::num(alloc_share(&ri, wall_inc))),
            (
                "alloc_share_full_sort",
                Json::num(alloc_share(&rs, wall_sort)),
            ),
            (
                "view_rebuild_speedup",
                Json::num(wall_full / wall_inc.max(1e-9)),
            ),
            (
                "index_vs_full_sort_speedup",
                Json::num(wall_sort / wall_inc.max(1e-9)),
            ),
        ]));
    }
    println!(
        "\n(touched/tick flat while machines grow 100x ⇒ per-tick view \
         maintenance is O(changed); the fullsort column re-ranks every \
         candidate every tick, which is the allocation cost the index \
         retires — its speedup over the incremental column is the \
         acceptance figure in BENCH_grid_scaling.json.)"
    );

    println!("\n== multi-tenant brokering: shared-grid sweep ==\n");
    println!(
        "{:<8} {:>7} {:>14} {:>14} {:>13} {:>13} {:>9}",
        "tenants",
        "ticks",
        "touched/tick",
        "touched/tick",
        "µs/tick",
        "µs/tick",
        "speedup"
    );
    println!(
        "{:<8} {:>7} {:>14} {:>14} {:>13} {:>13} {:>9}",
        "", "", "(incremental)", "(rebuild)", "(incremental)", "(rebuild)", ""
    );
    let tenant_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    // Posted-price incremental runs, kept for the auction sweep below so
    // the same (tenant count, grid) baseline is not simulated twice.
    let mut posted_cache: BTreeMap<usize, (f64, WorldReport)> = BTreeMap::new();
    for &tenants in tenant_counts {
        let tb = quiet(Testbed::synthetic(20, 50, 7)); // 1,000 machines
        let machines = tb.resources.len();
        let (wall_inc, wi) =
            tenant_sweep_run(tb.clone(), tenants, false, None, None);
        let (wall_full, wf) = tenant_sweep_run(tb, tenants, true, None, None);
        posted_cache.insert(tenants, (wall_inc, wi.clone()));
        // Same world trace, different maintenance cost.
        assert_eq!(wi.events, wf.events, "multi-tenant trace diverged");
        let totals = |wr: &WorldReport| {
            wr.tenants.iter().fold((0u64, 0u64), |(t, v), x| {
                (t + x.report.ticks, v + x.report.view_refreshes)
            })
        };
        let (ticks_i, touched_i) = totals(&wi);
        let (ticks_f, touched_f) = totals(&wf);
        assert_eq!(ticks_i, ticks_f, "tick counts diverged");
        for (a, b) in wi.tenants.iter().zip(&wf.tenants) {
            assert_eq!(
                a.report.makespan_s.to_bits(),
                b.report.makespan_s.to_bits(),
                "tenant timeline diverged"
            );
        }
        let ticks = ticks_i.max(1);
        println!(
            "{tenants:<8} {ticks:>7} {:>14.1} {:>14.1} {:>13.1} {:>13.1} {:>8.2}x",
            touched_i as f64 / ticks as f64,
            touched_f as f64 / ticks as f64,
            wall_inc * 1e6 / ticks as f64,
            wall_full * 1e6 / ticks as f64,
            wall_full / wall_inc.max(1e-9),
        );
        let alloc_ns: u64 =
            wi.tenants.iter().map(|t| t.report.alloc_ns).sum();
        tenant_rows.push(Json::obj(vec![
            ("tenants", Json::num(tenants as f64)),
            ("machines", Json::num(machines as f64)),
            ("ticks", Json::num(ticks as f64)),
            (
                "touched_per_tick_incremental",
                Json::num(touched_i as f64 / ticks as f64),
            ),
            (
                "touched_per_tick_rebuild",
                Json::num(touched_f as f64 / ticks as f64),
            ),
            (
                "us_per_tick_incremental",
                Json::num(wall_inc * 1e6 / ticks as f64),
            ),
            (
                "us_per_tick_rebuild",
                Json::num(wall_full * 1e6 / ticks as f64),
            ),
            (
                "alloc_share_incremental",
                Json::num(if wall_inc > 0.0 {
                    (alloc_ns as f64 / 1e9) / wall_inc
                } else {
                    0.0
                }),
            ),
            (
                "view_rebuild_speedup",
                Json::num(wall_full / wall_inc.max(1e-9)),
            ),
        ]));
    }
    println!(
        "\n(cross-tenant dirtying stays O(changed): touched/tick grows with \
         contention, not with tenants × machines — the rebuild column pays \
         every tenant a full table per tick.)"
    );

    println!("\n== GRACE market: auction vs posted tenant sweep ==\n");
    println!(
        "{:<8} {:>13} {:>13} {:>10} {:>12} {:>12} {:>11}",
        "tenants",
        "µs/tick",
        "µs/tick",
        "overhead",
        "agreements",
        "rounds/agr",
        "clearing"
    );
    println!(
        "{:<8} {:>13} {:>13} {:>10} {:>12} {:>12} {:>11}",
        "", "(posted)", "(auction)", "", "", "", "samples"
    );
    let auction_counts: &[usize] = if quick { &[2] } else { &[2, 4, 8] };
    for &tenants in auction_counts {
        let tb = quiet(Testbed::synthetic(20, 50, 7)); // 1,000 machines
        // The posted baseline is the multi-tenant sweep's incremental run;
        // reuse it when that section already produced it.
        let (wall_posted, wp) = posted_cache.remove(&tenants).unwrap_or_else(
            || tenant_sweep_run(tb.clone(), tenants, false, None, None),
        );
        let (wall_auction, wa) = tenant_sweep_run(
            tb,
            tenants,
            false,
            Some(GraceConfig::default()),
            None,
        );
        assert!(
            !wp.has_market_data(),
            "posted sweep must not trade on the market"
        );
        assert!(
            wa.agreements_won() > 0,
            "auction sweep must strike agreements"
        );
        for t in wa.tenants.iter().chain(&wp.tenants) {
            assert_eq!(
                t.report.jobs_completed + t.report.jobs_failed,
                t.report.jobs_total,
                "{}: every tenant accounts for every job",
                t.user
            );
        }
        let ticks = |wr: &WorldReport| {
            wr.tenants
                .iter()
                .map(|t| t.report.ticks)
                .sum::<u64>()
                .max(1)
        };
        let (tp, ta) = (ticks(&wp), ticks(&wa));
        // Overhead is per-tick vs per-tick: auction worlds schedule
        // differently and run different tick counts, so a total-wall ratio
        // would not match the two columns beside it.
        let us_posted = wall_posted * 1e6 / tp as f64;
        let us_auction = wall_auction * 1e6 / ta as f64;
        println!(
            "{tenants:<8} {us_posted:>13.1} {us_auction:>13.1} {:>9.2}x {:>12} {:>12.1} {:>11}",
            us_auction / us_posted.max(1e-9),
            wa.agreements_won(),
            wa.rounds_per_agreement(),
            wa.clearing_prices.len(),
        );
    }
    println!(
        "\n(auction overhead = negotiation at every MDS refresh: tender \
         derivation + per-owner quoting + cheapest-set selection, all \
         RNG-free; the posted column is the same world with the market \
         switched off.)"
    );

    println!("\n== advance reservations: on/off overhead sweep ==\n");
    println!(
        "{:<8} {:>13} {:>13} {:>10} {:>9} {:>13}",
        "tenants", "µs/tick", "µs/tick", "overhead", "commits", "held slot-h"
    );
    println!(
        "{:<8} {:>13} {:>13} {:>10} {:>9} {:>13}",
        "", "(off)", "(on)", "", "", ""
    );
    let mut rsv_rows: Vec<Json> = Vec::new();
    let rsv_counts: &[usize] = if quick { &[2] } else { &[2, 4] };
    for &tenants in rsv_counts {
        // A 100-machine grid so the 500-job plans stay partly undispatched
        // past the trigger point and the probe → reserve → commit ladder
        // actually runs inside the measured window.
        let tb = quiet(Testbed::synthetic(4, 25, 7));
        let eager = ReservationConfig {
            trigger_frac: 0.05,
            ..ReservationConfig::default()
        };
        let (wall_off, w_off) =
            tenant_sweep_run(tb.clone(), tenants, false, None, None);
        let (wall_on, w_on) =
            tenant_sweep_run(tb, tenants, false, None, Some(eager));
        assert!(
            !w_off.has_reservation_data(),
            "reservations must be strictly opt-in"
        );
        let ticks = |wr: &WorldReport| {
            wr.tenants
                .iter()
                .map(|t| t.report.ticks)
                .sum::<u64>()
                .max(1)
        };
        // Held slots reshape the schedule, so the two worlds run different
        // tick counts — compare per-tick cost, not total wall time.
        let (t_off, t_on) = (ticks(&w_off), ticks(&w_on));
        let us_off = wall_off * 1e6 / t_off as f64;
        let us_on = wall_on * 1e6 / t_on as f64;
        let held_s: f64 =
            w_on.tenants.iter().map(|t| t.held_slot_seconds).sum();
        println!(
            "{tenants:<8} {us_off:>13.1} {us_on:>13.1} {:>9.2}x {:>9} {:>13.1}",
            us_on / us_off.max(1e-9),
            w_on.reservations_committed(),
            held_s / 3600.0,
        );
        rsv_rows.push(Json::obj(vec![
            ("tenants", Json::num(tenants as f64)),
            ("us_per_tick_off", Json::num(us_off)),
            ("us_per_tick_on", Json::num(us_on)),
            (
                "reservation_overhead",
                Json::num(us_on / us_off.max(1e-9)),
            ),
            (
                "commits",
                Json::num(w_on.reservations_committed() as f64),
            ),
            ("held_slot_s", Json::num(held_s)),
        ]));
    }
    println!(
        "\n(the on column pays shadow-schedule probes at the trigger point \
         plus per-tick hold expiry sweeps and reserved-slot occupancy \
         folding; the off column is the identical world with no \
         ReservationConfig, where the subsystem must cost nothing.)"
    );

    println!("\n== parallel tick: thread sweep (spawn × merge mode) ==\n");
    println!(
        "{:<14} {:>8} {:>9} {:>8} {:>7} {:>10} {:>8} {:>11} {:>9} {:>12} {:>9}",
        "scenario", "tenants", "machines", "threads", "spawn", "merge", "ticks", "µs/tick", "speedup", "merge share", "overlap"
    );
    let mut thread_rows: Vec<Json> = Vec::new();
    let thread_counts: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    // (scenario, sites, per-site, tenants, jobs-per-tenant). Full mode is
    // the acceptance shape — 64 tenants on the 10,000-machine index-storm
    // grid — plus a mega-grid-shaped world and the world-storm shape (256
    // small brokers on a 128-machine grid: maximum batch width, so the
    // streaming commit queue's deepest reorder window); quick is a CI
    // thread smoke.
    let storm_shapes: &[(&str, usize, usize, usize, usize)] = if quick {
        &[("index-storm", 4, 25, 8, 30)]
    } else {
        &[
            ("index-storm", 100, 100, 64, 400),
            ("mega-grid", 120, 45, 16, 400),
            ("world-storm", 4, 32, 256, 6),
        ]
    };
    for &(scenario, sites, per_site, tenants, jobs) in storm_shapes {
        let tb = Testbed::synthetic(sites, per_site, 7);
        let machines = tb.resources.len();
        let mut base: Option<(f64, WorldReport)> = None;
        for &threads in thread_counts {
            // At 1 thread every spawn/merge mode is the same sequential
            // reference path, so it gets one row; above that, the
            // persistent pool runs both merge modes (streaming commit
            // queue and the barrier drain it pipelined away), and the
            // per-batch scoped-spawn baseline rides along (barrier only —
            // scoped spawns have no caller thread to stream commits on).
            let modes: &[(&str, &str)] = if threads == 1 {
                &[("seq", "streaming")]
            } else {
                &[
                    ("pooled", "streaming"),
                    ("pooled", "barrier"),
                    ("scoped", "barrier"),
                ]
            };
            for &(spawn, merge_mode) in modes {
                let scoped = spawn == "scoped";
                let barrier = !scoped && merge_mode == "barrier";
                let (wall, wr) = storm_run(
                    tb.clone(),
                    tenants,
                    jobs,
                    threads,
                    scoped,
                    barrier,
                );
                // Bit-exact replay across thread counts and spawn modes is
                // the contract the whole parallel section rests on — verify
                // it right here where the speedup numbers are minted.
                if let Some((_, w1)) = &base {
                    assert_eq!(
                        w1.events, wr.events,
                        "{scenario}: trace diverged at {threads} threads ({spawn}/{merge_mode})"
                    );
                    for (a, b) in w1.tenants.iter().zip(&wr.tenants) {
                        assert_eq!(
                            a.report.makespan_s.to_bits(),
                            b.report.makespan_s.to_bits(),
                            "{scenario}/{}: timeline diverged at {threads} threads ({spawn}/{merge_mode})",
                            a.user
                        );
                        assert_eq!(
                            a.report.total_cost.to_bits(),
                            b.report.total_cost.to_bits(),
                            "{scenario}/{}: spend diverged at {threads} threads ({spawn}/{merge_mode})",
                            a.user
                        );
                    }
                }
                // A drained-after-barrier merge can never overlap the
                // lanes; only the streaming commit queue may report
                // overlapped commit nanoseconds.
                if merge_mode == "barrier" {
                    assert_eq!(
                        wr.merge_overlap_ns, 0,
                        "{scenario}: {spawn}/barrier at {threads} threads \
                         reported overlapped commit time"
                    );
                }
                // The mode under measurement must be the mode that ran.
                if spawn == "pooled" {
                    assert!(
                        wr.pool_rounds > 0,
                        "{scenario}: pooled run at {threads} threads never \
                         scattered a batch through the pool"
                    );
                } else {
                    assert_eq!(
                        wr.pool_rounds, 0,
                        "{scenario}: {spawn} run must stay pool-free"
                    );
                }
                let ticks = wr
                    .tenants
                    .iter()
                    .map(|t| t.report.ticks)
                    .sum::<u64>()
                    .max(1);
                let us_tick = wall * 1e6 / ticks as f64;
                let speedup = match &base {
                    Some((wall1, _)) => wall1 / wall.max(1e-9),
                    None => 1.0,
                };
                let phase_ns = wr.snapshot_ns + wr.parallel_ns + wr.merge_ns;
                let merge_share = if phase_ns > 0 {
                    wr.merge_ns as f64 / phase_ns as f64
                } else {
                    0.0
                };
                // Fraction of total commit time the streaming merge hid
                // under still-running shards (0 in barrier/seq rows).
                let merge_overlap = if wr.merge_ns > 0 {
                    wr.merge_overlap_ns as f64 / wr.merge_ns as f64
                } else {
                    0.0
                };
                println!(
                    "{scenario:<14} {tenants:>8} {machines:>9} {threads:>8} {spawn:>7} {merge_mode:>10} {ticks:>8} {us_tick:>11.1} {:>8.2}x {:>11.1}% {:>8.1}%",
                    speedup,
                    merge_share * 100.0,
                    merge_overlap * 100.0,
                );
                thread_rows.push(Json::obj(vec![
                    ("scenario", Json::str(scenario)),
                    ("tenants", Json::num(tenants as f64)),
                    ("machines", Json::num(machines as f64)),
                    ("threads", Json::num(threads as f64)),
                    ("spawn", Json::str(spawn)),
                    ("merge_mode", Json::str(merge_mode)),
                    ("ticks", Json::num(ticks as f64)),
                    ("us_per_tick", Json::num(us_tick)),
                    ("speedup_vs_1", Json::num(speedup)),
                    ("merge_share", Json::num(merge_share)),
                    ("merge_overlap", Json::num(merge_overlap)),
                ]));
                if base.is_none() {
                    base = Some((wall, wr));
                }
            }
        }
    }
    println!(
        "\n(speedup is whole-run wall time vs the same world at 1 thread — \
         phase 1 and event processing stay sequential, so this is the \
         Amdahl-limited figure; pooled rows reuse the persistent worker \
         pool, scoped rows pay a fresh std::thread::scope spawn per batch; \
         merge share is the commit queue's slice of the three-phase \
         batched tick, and overlap is how much of it the streaming merge \
         hid under still-running shards — the barrier rows are the PR-9 \
         drain-after-barrier baseline the pipeline retired.)"
    );

    // Machine-readable perf trajectory (archived by CI).
    let out = Json::obj(vec![
        ("bench", Json::str("grid_scaling")),
        ("mode", Json::str(if quick { "quick" } else { "full" })),
        ("grid_sweep", Json::arr(grid_rows)),
        ("tenant_sweep", Json::arr(tenant_rows)),
        ("reservation_sweep", Json::arr(rsv_rows)),
        ("thread_sweep", Json::arr(thread_rows)),
    ]);
    match std::fs::write("BENCH_grid_scaling.json", out.to_string()) {
        Ok(()) => println!("\nwrote BENCH_grid_scaling.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_grid_scaling.json: {e}"),
    }

    // Per-cycle costs: MDS refresh + discovery at each testbed size.
    if !quick {
        let mut b = Bench::new("per-cycle component costs");
        for scale in [1.0, 4.0, 8.0] {
            let tb = Testbed::gusto(3, scale);
            let mut rng = Rng::new(1);
            let dyns: Vec<ResourceDyn> = tb
                .resources
                .iter()
                .map(|s| ResourceDyn::new(s, &mut rng))
                .collect();
            let mut mds = Mds::new(&tb, &dyns);
            let n = tb.resources.len();
            b.iter(&format!("mds refresh ({n} machines)"), || {
                mds.refresh(&tb, &dyns, 0.0)
            });
            b.iter(&format!("discovery ({n} machines)"), || {
                mds.discover(&tb, "rajkumar").count()
            });
        }
        b.report();
    }
}
