//! Bench: computational-economy ablations (paper §3).
//!
//! The paper's §3 claims, each regenerated as a table:
//!   1. cost-optimizing DBC meets the deadline at lower cost than
//!      time-optimizing; relaxing the deadline lowers cost further
//!      ("if the user deadline is relaxed, the chances of obtaining
//!      low-cost access to resources are high");
//!   2. time-of-day pricing matters: an experiment started at the owners'
//!      night is cheaper than one started at peak;
//!   3. budgets bind: with a tight budget the cost-optimizer trades the
//!      deadline for staying inside the envelope.
//!
//! ```bash
//! cargo bench --bench economy_ablation
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::types::HOUR;

fn run(policy: &str, deadline_h: f64, budget: Option<f64>, start_utc: f64) -> nimrod_g::metrics::Report {
    let mut b = Broker::experiment()
        .deadline_h(deadline_h)
        .policy(policy)
        .start_utc_hour(start_utc)
        .seed(0xEC0);
    if let Some(budget) = budget {
        b = b.budget(budget);
    }
    b.run().expect("ablation experiment")
}

fn main() {
    println!("== ablation 1: policy x deadline (165-job calibration) ==\n");
    println!(
        "{:<20} {:>9} {:>12} {:>12} {:>9} {:>6}",
        "policy", "deadline", "makespan(h)", "cost(G$)", "peak-cpu", "met"
    );
    let mut cost_by_deadline = Vec::new();
    for policy in ["cost", "time", "conservative-time", "deadline-only"] {
        for deadline_h in [10.0, 15.0, 20.0] {
            let r = run(policy, deadline_h, None, 22.0);
            println!(
                "{policy:<20} {deadline_h:>8.0}h {:>12.2} {:>12.0} {:>9} {:>6}",
                r.makespan_s / HOUR,
                r.total_cost,
                r.busy_cpus.peak(),
                r.deadline_met
            );
            if policy == "cost" {
                cost_by_deadline.push(r.total_cost);
            }
        }
    }
    let relaxed_cheaper = cost_by_deadline.windows(2).all(|w| w[1] <= w[0] * 1.05);
    println!("\nrelaxed deadline ⇒ lower cost (cost policy): {relaxed_cheaper}");

    println!("\n== ablation 2: time-of-day start hour (cost policy, 15 h) ==\n");
    println!("{:<28} {:>12} {:>12}", "experiment start", "cost(G$)", "makespan(h)");
    for (label, utc) in [
        ("22:00 UTC (US night)", 22.0),
        ("15:00 UTC (US peak)", 15.0),
        ("05:00 UTC (AU/JP peak)", 5.0),
    ] {
        let r = run("cost", 15.0, None, utc);
        println!(
            "{label:<28} {:>12.0} {:>12.2}",
            r.total_cost,
            r.makespan_s / HOUR
        );
    }

    println!("\n== ablation 3: budget envelope (cost policy, 15 h) ==\n");
    println!(
        "{:<16} {:>12} {:>12} {:>10} {:>6}",
        "budget(G$)", "spent(G$)", "makespan(h)", "done", "met"
    );
    for budget in [f64::INFINITY, 2.0e6, 1.0e6, 0.5e6, 0.2e6] {
        let b = if budget.is_finite() { Some(budget) } else { None };
        let r = run("cost", 15.0, b, 22.0);
        println!(
            "{:<16} {:>12.0} {:>12.2} {:>7}/{:<3} {:>5}",
            if budget.is_finite() {
                format!("{budget:.0}")
            } else {
                "unlimited".to_string()
            },
            r.total_cost,
            r.makespan_s / HOUR,
            r.jobs_completed,
            r.jobs_total,
            r.deadline_met
        );
        if let Some(b) = b {
            assert!(
                r.total_cost <= b + 1e-6,
                "budget invariant violated: spent {} > {}",
                r.total_cost,
                b
            );
        }
    }
    println!("\n(budget column is a hard invariant — asserted, never exceeded)");

    println!("\n== ablation 4: competing experiments (cost policy, 20 h) ==\n");
    println!(
        "{:<26} {:>12} {:>12} {:>10}",
        "grid contention", "cost(G$)", "makespan(h)", "resources"
    );
    for (label, interarrival) in [
        ("quiet grid", None),
        ("competitor every 2 h", Some(2.0 * 3600.0)),
        ("competitor every 30 min", Some(1800.0)),
    ] {
        let mut b = Broker::experiment()
            .deadline_h(20.0)
            .policy("cost")
            .seed(0xEC0);
        if let Some(s) = interarrival {
            b = b.competition(nimrod_g::grid::competition::CompetitionModel {
                mean_interarrival_s: s,
                mean_duration_s: 4.0 * 3600.0,
                mean_cpus: 60.0,
            });
        }
        let r = b.run().expect("competition experiment");
        println!(
            "{label:<26} {:>12.0} {:>12.2} {:>10}",
            r.total_cost,
            r.makespan_s / HOUR,
            r.resources_used
        );
    }
    println!("\n(paper §3: \"the cost changes as other competing experiments are put on the grid\")");
}
