//! Bench: paper **Figure 3** — "GUSTO resources usage for 10, 15, and 20
//! hours of deadline".
//!
//! Regenerates the figure's series: number of processors in use over time
//! for the 165-job ionization-chamber calibration under the
//! cost-optimizing DBC scheduler on the ~70-machine GUSTO-like testbed.
//! The paper's qualitative claims to check: tighter deadline ⇒ more
//! (and costlier) processors; every deadline met; the resource set adapts
//! over the run. Also wall-times the simulation itself.
//!
//! ```bash
//! cargo bench --bench figure3_deadline
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::types::HOUR;
use nimrod_g::util::bench::Bench;

fn run(deadline_h: f64, seed: u64) -> nimrod_g::metrics::Report {
    Broker::experiment()
        .deadline_h(deadline_h)
        .policy("cost")
        .seed(seed)
        .run()
        .expect("figure3 experiment")
}

fn main() {
    println!("== Figure 3: processors in use vs time, by deadline ==\n");
    let seed = 0xF16_3;
    let mut reports = Vec::new();
    for deadline_h in [10.0, 15.0, 20.0] {
        let r = run(deadline_h, seed);
        println!("deadline {deadline_h:>4.0} h: {}", r.summary());
        reports.push((deadline_h, r));
    }

    // The figure's series: hourly processors-in-use per deadline.
    println!("\nhour, busy@10h, busy@15h, busy@20h");
    let horizon = reports
        .iter()
        .map(|(_, r)| r.makespan_s)
        .fold(0.0f64, f64::max);
    let mut t = 0.0;
    while t <= horizon + 1.0 {
        print!("{:>4.1}", t / 3600.0);
        for (_, r) in &reports {
            print!(", {:>6}", r.busy_cpus.at(t));
        }
        println!();
        t += HOUR;
    }

    // Qualitative checks the paper's text makes.
    let avg: Vec<f64> = reports
        .iter()
        .map(|(_, r)| r.busy_cpus.average(r.makespan_s.max(1.0)))
        .collect();
    println!(
        "\navg busy cpus: 10h={:.1} 15h={:.1} 20h={:.1}  (paper: tighter ⇒ more)",
        avg[0], avg[1], avg[2]
    );
    let costs: Vec<f64> = reports.iter().map(|(_, r)| r.total_cost).collect();
    println!(
        "total cost:    10h={:.0} 15h={:.0} 20h={:.0}  (paper: tighter ⇒ costlier)",
        costs[0], costs[1], costs[2]
    );
    let met = reports.iter().all(|(_, r)| r.deadline_met);
    println!("all deadlines met: {met}");

    // Wall-clock cost of regenerating the figure (simulator throughput).
    let mut b = Bench::new("figure3 simulation wall time").fast();
    for deadline_h in [10.0, 15.0, 20.0] {
        b.iter(&format!("simulate 165 jobs @ {deadline_h}h deadline"), || {
            run(deadline_h, seed)
        });
    }
    b.report();
}
