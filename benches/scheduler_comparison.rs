//! Bench: Nimrod/G DBC schedulers vs the related-work baselines (paper §6).
//!
//! The paper's qualitative comparison, regenerated quantitatively: AppLeS
//! (perf-only), REXEC (fixed-rate cap), round-robin and random do not use
//! the computational economy, so at an equal deadline the economy-aware
//! cost-optimizer should finish within deadline at distinctly lower cost
//! than perf-only/round-robin/random, while time-opt should be fastest.
//!
//! ```bash
//! cargo bench --bench scheduler_comparison
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::scheduler::ALL_POLICIES;
use nimrod_g::types::HOUR;

fn main() {
    println!("== scheduler comparison: 165-job calibration, 15 h deadline ==\n");
    println!(
        "{:<20} {:>12} {:>12} {:>9} {:>10} {:>6}",
        "policy", "makespan(h)", "cost(G$)", "peak-cpu", "resources", "met"
    );
    let mut results = Vec::new();
    for policy in ALL_POLICIES {
        let r = Broker::experiment()
            .deadline_h(15.0)
            .policy(policy)
            .seed(0x5C0ED)
            .run()
            .expect("comparison experiment");
        println!(
            "{policy:<20} {:>12.2} {:>12.0} {:>9} {:>10} {:>6}",
            r.makespan_s / HOUR,
            r.total_cost,
            r.busy_cpus.peak(),
            r.resources_used,
            r.deadline_met
        );
        results.push((policy, r));
    }

    let cost_of = |name: &str| {
        results
            .iter()
            .find(|(p, _)| *p == name)
            .map(|(_, r)| r.total_cost)
            .unwrap()
    };
    println!("\nshape checks (paper §3/§6):");
    let cost = cost_of("cost");
    for baseline in ["perf", "round-robin", "random", "deadline-only"] {
        let b = cost_of(baseline);
        println!(
            "  cost-opt {:.0} vs {baseline} {:.0}  -> {:.2}x cheaper: {}",
            cost,
            b,
            b / cost,
            b > cost
        );
    }
    let makespan_of = |name: &str| {
        results
            .iter()
            .find(|(p, _)| *p == name)
            .map(|(_, r)| r.makespan_s)
            .unwrap()
    };
    println!(
        "  time-opt fastest of the DBC family: {}",
        makespan_of("time") <= makespan_of("cost")
    );
}
