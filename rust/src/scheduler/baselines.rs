//! Baseline schedulers from the paper's related-work section (§6), used by
//! the comparison benches: none of these understand the computational
//! economy, which is exactly the gap the paper's DBC schedulers fill.
//!
//! Like the DBC family, the baselines consume the driver's persistent
//! [`crate::scheduler::CandidateIndex`] instead of filtering/sorting the
//! view table per tick: the speed-ordered policies walk the shared
//! fastest-first ranking, and the rotation policies walk the id-ordered
//! eligible set.

use super::{Allocation, Policy, ResourceView, SchedCtx};

/// Classic round-robin: hand slots out one at a time cycling over the
/// eligible resources (ascending id) until remaining jobs are covered.
/// Position persists across ticks so the rotation is fair over the
/// experiment.
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl Policy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let rs: Vec<&ResourceView> = ctx.eligible_views().collect();
        let mut alloc = Allocation::new();
        if rs.is_empty() {
            return alloc;
        }
        let mut remaining = ctx.remaining_jobs;
        let mut exhausted = 0;
        while remaining > 0 && exhausted < rs.len() {
            let r = rs[self.cursor % rs.len()];
            self.cursor = (self.cursor + 1) % rs.len();
            let have = alloc.get(&r.id).copied().unwrap_or(0);
            if have < r.slots {
                alloc.insert(r.id, have + 1);
                remaining -= 1;
                exhausted = 0;
            } else {
                exhausted += 1;
            }
        }
        alloc
    }
}

/// Random subset: sample eligible resources uniformly until remaining jobs
/// are covered. The "no scheduler" straw-man.
#[derive(Debug, Default)]
pub struct RandomPick;

impl Policy for RandomPick {
    fn name(&self) -> &'static str {
        "random"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let rs: Vec<&ResourceView> = ctx.eligible_views().collect();
        let mut alloc = Allocation::new();
        if rs.is_empty() {
            return alloc;
        }
        let mut remaining = ctx.remaining_jobs;
        // Bounded draw count keeps the tick O(jobs + resources).
        let mut attempts = 4 * (ctx.remaining_jobs as usize + rs.len());
        while remaining > 0 && attempts > 0 {
            attempts -= 1;
            let r = rs[ctx.rng.below(rs.len())];
            let have = alloc.get(&r.id).copied().unwrap_or(0);
            if have < r.slots {
                alloc.insert(r.id, have + 1);
                remaining -= 1;
            }
        }
        alloc
    }
}

/// AppLeS-like performance-only selection: always run on the
/// highest-effective-speed machines available (NWS-style load-corrected),
/// price never considered, capacity never trimmed to the deadline.
#[derive(Debug, Default)]
pub struct PerfOnly;

impl Policy for PerfOnly {
    fn name(&self) -> &'static str {
        "perf"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let mut alloc = Allocation::new();
        let mut total = 0u32;
        for r in ctx.ranked_by_speed() {
            if total >= ctx.remaining_jobs {
                break;
            }
            let take = r.slots.min(ctx.remaining_jobs - total);
            alloc.insert(r.id, take);
            total += take;
        }
        alloc
    }
}

/// REXEC-like fixed-rate policy: the user caps the rate they will pay
/// (credits per minute in REXEC; G$/CPU-second here); any resource at or
/// under the cap is used, fastest first. No deadline awareness.
#[derive(Debug)]
pub struct FixedRate {
    pub max_rate: f64,
}

impl Default for FixedRate {
    fn default() -> Self {
        FixedRate { max_rate: 1.0 }
    }
}

impl Policy for FixedRate {
    fn name(&self) -> &'static str {
        "fixed-rate"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let mut alloc = Allocation::new();
        // O(1) bail when even the cheapest quote sits above the cap (the
        // index's rate ranking answers this without a walk).
        match ctx.candidates.min_rate() {
            Some(min) if min <= self.max_rate => {}
            _ => return alloc,
        }
        let mut total = 0u32;
        for r in ctx.ranked_by_speed() {
            if total >= ctx.remaining_jobs {
                break;
            }
            if r.rate > self.max_rate {
                continue;
            }
            let take = r.slots.min(ctx.remaining_jobs - total);
            alloc.insert(r.id, take);
            total += take;
        }
        alloc
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{index_of, view};
    use super::*;
    use crate::scheduler::{CandidateIndex, ResourceView};
    use crate::types::{ResourceId, HOUR};
    use crate::util::rng::Rng;

    fn ctx<'a>(
        resources: &'a [ResourceView],
        candidates: &'a CandidateIndex,
        rng: &'a mut Rng,
        jobs: u32,
    ) -> SchedCtx<'a> {
        SchedCtx {
            now: 0.0,
            deadline: 10.0 * HOUR,
            budget_headroom: None,
            remaining_jobs: jobs,
            job_work_ref_h: 1.0,
            resources,
            candidates,
            rng,
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let rs = vec![view(0, 4, 1.0, 1.0), view(1, 4, 1.0, 1.0), view(2, 4, 1.0, 1.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 6);
        let alloc = RoundRobin::default().allocate(&mut c);
        assert_eq!(alloc.len(), 3);
        assert!(alloc.values().all(|&n| n == 2), "{alloc:?}");
    }

    #[test]
    fn round_robin_caps_at_slots() {
        let rs = vec![view(0, 1, 1.0, 1.0), view(1, 2, 1.0, 1.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 100);
        let alloc = RoundRobin::default().allocate(&mut c);
        assert_eq!(alloc[&ResourceId(0)], 1);
        assert_eq!(alloc[&ResourceId(1)], 2);
    }

    #[test]
    fn random_total_never_exceeds_jobs_or_slots() {
        let rs = vec![view(0, 3, 1.0, 1.0), view(1, 2, 1.0, 1.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(42);
        let mut c = ctx(&rs, &ix, &mut rng, 4);
        let alloc = RandomPick.allocate(&mut c);
        let total: u32 = alloc.values().sum();
        assert!(total <= 4);
        for (id, n) in &alloc {
            let r = rs.iter().find(|r| r.id == *id).unwrap();
            assert!(*n <= r.slots);
        }
    }

    #[test]
    fn perf_only_picks_fastest() {
        let rs = vec![view(0, 8, 0.5, 0.01), view(1, 8, 3.0, 50.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 4);
        let alloc = PerfOnly.allocate(&mut c);
        assert_eq!(alloc.get(&ResourceId(1)), Some(&4));
        assert!(!alloc.contains_key(&ResourceId(0)));
    }

    #[test]
    fn fixed_rate_excludes_expensive() {
        let rs = vec![view(0, 8, 1.0, 0.5), view(1, 8, 5.0, 2.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 16);
        let alloc = FixedRate { max_rate: 1.0 }.allocate(&mut c);
        assert!(alloc.contains_key(&ResourceId(0)));
        assert!(!alloc.contains_key(&ResourceId(1)));
    }

    #[test]
    fn fixed_rate_bails_when_every_quote_exceeds_the_cap() {
        let rs = vec![view(0, 8, 1.0, 3.0), view(1, 8, 5.0, 2.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 16);
        let alloc = FixedRate { max_rate: 1.0 }.allocate(&mut c);
        assert!(alloc.is_empty(), "{alloc:?}");
    }

    #[test]
    fn speed_ties_rank_by_resource_id() {
        // Regression for the shared ranking keys: stable (key, id) order.
        // Three machines at identical speed must be walked in id order, so
        // a perf allocation smaller than total capacity lands on the
        // lowest ids — exactly what the old stable sort produced.
        let rs = vec![
            view(0, 2, 2.0, 1.0),
            view(1, 2, 2.0, 1.0),
            view(2, 2, 2.0, 1.0),
        ];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 3);
        let alloc = PerfOnly.allocate(&mut c);
        assert_eq!(alloc.get(&ResourceId(0)), Some(&2));
        assert_eq!(alloc.get(&ResourceId(1)), Some(&1));
        assert!(!alloc.contains_key(&ResourceId(2)), "{alloc:?}");
    }
}
