//! The persistent candidate index: incrementally-maintained ranked
//! orderings of eligible resources.
//!
//! Every builtin policy used to re-sort the full `Vec<ResourceView>` on
//! every scheduling tick — the O(R log R) cost the ROADMAP flags as the
//! allocation bottleneck once discovery went O(changed). The paper's
//! schedule advisor re-evaluates *selection* at every scheduling event,
//! but the *rankings* selection walks (cheapest-first for the
//! cost-optimizing DBC, fastest-first for the time-optimizing family) only
//! change when a resource's scheduler-visible state changes — exactly the
//! dirty-view deltas the incremental tick pipeline already computes.
//!
//! [`CandidateIndex`] keeps one ordered set per ranking dimension
//! ([`RankKeys`]): a view that did not change keeps its rank for free, and
//! a dirtied view is re-keyed and repositioned in O(log R)
//! ([`CandidateIndex::update`]). Policies then consume ranked iterators
//! from [`super::SchedCtx`] instead of sorting, so a tick's allocation
//! cost is O(candidates actually walked · log R) — sub-linear on big
//! grids, where the greedy capacity fills stop after a handful of
//! machines.
//!
//! **Ordering contract.** Every dimension totally orders `(key,
//! ResourceId)`, so equal keys always tie-break toward the lower resource
//! id — the same order the old stable sorts produced over the id-ordered
//! view table. The shared key helpers ([`cost_rank_key`],
//! [`service_rank_key`]) replace the five hand-rolled `sort_by`
//! comparators the DBC and baseline policies used to duplicate; policies
//! and the index *must* rank through them, or the
//! `set_full_allocation_sort` baseline stops being bit-exact.
//!
//! **Maintenance contract.** Whatever refreshes a tenant's view table must
//! hand every rebuilt entry to [`CandidateIndex::update`] (the sim world
//! does this inside `refresh_dirty_views`; the live driver rebuilds its
//! tiny index per tick with [`CandidateIndex::from_views`]). A driver that
//! mutates views without updating the index desynchronizes ranking from
//! state, and the `allocation_matches_full_sort_bit_exactly` equivalence
//! tests fail.

use super::ResourceView;
use crate::types::ResourceId;
use std::cmp::{Ordering, Reverse};
use std::collections::BTreeSet;

/// `f64` wrapper ordered by [`f64::total_cmp`], so ranking keys can live
/// in `BTreeSet`s. Consistent `Eq`/`Ord` (equality is `total_cmp ==
/// Equal`, which distinguishes `-0.0` from `0.0` exactly like the sorts
/// the policies used to run).
#[derive(Debug, Clone, Copy)]
pub struct TotalF64(pub f64);

impl PartialEq for TotalF64 {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for TotalF64 {}

impl PartialOrd for TotalF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TotalF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Cost-ranking key: G$ per reference-CPU-hour on this machine
/// (`rate × 3600 / planning_speed`). [`ResourceView::cost_per_job`] is
/// this key times the per-job work estimate — a positive scalar common to
/// every resource at a given tick — so ranking by this key *is* ranking by
/// expected cost per job, while the key itself stays work-independent.
/// That independence is what lets the index persist across ticks as the
/// work estimate moves with completion history.
pub fn cost_rank_key(v: &ResourceView) -> f64 {
    cost_rank_key_parts(v.rate, v.planning_speed)
}

/// [`cost_rank_key`] from bare columns — the form the struct-of-arrays
/// refresh path ([`super::ViewColumns`]) feeds. One body for both entry
/// points keeps AoS and SoA re-keying bit-identical by construction.
pub fn cost_rank_key_parts(rate: f64, planning_speed: f64) -> f64 {
    if planning_speed <= 0.0 {
        f64::INFINITY
    } else {
        rate * 3600.0 / planning_speed
    }
}

/// Service-ranking key: the measured jobs/hour/slot when completion
/// history exists, else the capability-prior planning speed. Orders
/// resources by observed delivery within the measured subset and by
/// advertised capability within the unmeasured one. No builtin policy
/// walks [`CandidateIndex::service_ranked`] yet — the dimension exists
/// for history-aware out-of-crate policies, and costs one extra O(log R)
/// set touch per re-key.
pub fn service_rank_key(v: &ResourceView) -> f64 {
    service_rank_key_parts(v.measured_jphps.unwrap_or(0.0), v.planning_speed)
}

/// [`service_rank_key`] from bare columns, with "no history" encoded as a
/// non-positive `measured` (the [`super::ViewColumns`] convention —
/// `Some(m)` with `m ≤ 0` already fell back to the prior, so `None ↦ 0.0`
/// is lossless for ranking purposes).
pub fn service_rank_key_parts(measured: f64, planning_speed: f64) -> f64 {
    if measured > 0.0 {
        measured
    } else {
        planning_speed
    }
}

/// Width of one [`CandidateIndex::update_cols_bulk`] chunk: keys for a
/// whole chunk are derived off the dense columns before any ranking set
/// is touched.
const BULK_CHUNK: usize = 16;

/// The ranking keys one resource is currently filed under (so an update
/// can remove the exact stale entries before re-inserting).
#[derive(Debug, Clone, Copy)]
struct RankKeys {
    cost: f64,
    speed: f64,
    rate: f64,
    service: f64,
}

/// Ranked orderings of the *eligible* resources (positive planning speed,
/// at least one slot), maintained incrementally from dirty-view deltas.
/// See the module docs for the ordering and maintenance contracts.
#[derive(Debug, Default)]
pub struct CandidateIndex {
    /// Per-resource keys currently in the sets (`None` = ineligible or
    /// never seen). Indexed by `ResourceId`.
    keys: Vec<Option<RankKeys>>,
    /// Cheapest expected cost per job first; price ties break toward the
    /// faster machine, then the lower id (the cost-optimizing DBC order).
    by_cost: BTreeSet<(TotalF64, Reverse<TotalF64>, u32)>,
    /// Fastest planning speed first (the time-optimizing / perf order).
    by_speed: BTreeSet<(Reverse<TotalF64>, u32)>,
    /// Lowest quoted rate first (rate-cap range queries).
    by_rate: BTreeSet<(TotalF64, u32)>,
    /// Highest measured-or-prior service rate first.
    by_service: BTreeSet<(Reverse<TotalF64>, u32)>,
}

impl CandidateIndex {
    /// An empty index sized for `n` resources (ids `0..n`; updates for
    /// larger ids grow the key table on demand).
    pub fn new(n: usize) -> CandidateIndex {
        CandidateIndex {
            keys: vec![None; n],
            ..CandidateIndex::default()
        }
    }

    /// Build an index by ranking every view once — the construction the
    /// live driver (tiny resource pools, views rebuilt each tick) and the
    /// policy unit tests use.
    pub fn from_views(views: &[ResourceView]) -> CandidateIndex {
        let mut ix = CandidateIndex::new(views.len());
        for v in views {
            ix.update(v);
        }
        ix
    }

    /// Discard every ranking and re-derive them all from `views` — the
    /// sort-every-tick baseline behind `set_full_allocation_sort`. Produces
    /// exactly the state incremental maintenance converges to; only the
    /// cost differs (O(R log R) here versus O(dirty · log R)).
    pub fn rebuild_from(&mut self, views: &[ResourceView]) {
        self.by_cost.clear();
        self.by_speed.clear();
        self.by_rate.clear();
        self.by_service.clear();
        for k in &mut self.keys {
            *k = None;
        }
        for v in views {
            self.update(v);
        }
    }

    /// The one eligibility rule every builtin policy shares: schedulable
    /// means a positive (stale-directory) speed and at least one slot.
    /// Down, unauthorized and saturated machines fall out of every
    /// ranking here, so policies never re-filter them.
    pub fn is_eligible(v: &ResourceView) -> bool {
        Self::is_eligible_parts(v.planning_speed, v.slots)
    }

    /// [`CandidateIndex::is_eligible`] from bare columns (see
    /// [`cost_rank_key_parts`] for why the parts forms exist).
    pub fn is_eligible_parts(planning_speed: f64, slots: u32) -> bool {
        planning_speed > 0.0 && slots > 0
    }

    /// Re-key one resource from its freshly-rebuilt view: remove the stale
    /// entries (if any), then re-insert under the new keys if the view is
    /// still eligible. O(log R). Call this for every view entry a refresh
    /// rewrites — see the module-level maintenance contract.
    pub fn update(&mut self, v: &ResourceView) {
        self.unfile(v.id.0);
        if !Self::is_eligible(v) {
            return;
        }
        self.file(
            v.id.0,
            RankKeys {
                cost: cost_rank_key(v),
                speed: v.planning_speed,
                rate: v.rate,
                service: service_rank_key(v),
            },
        );
    }

    /// [`CandidateIndex::update`] reading the struct-of-arrays mirror
    /// instead of a [`ResourceView`] — the sim world's dirty-refresh hot
    /// path. Re-keying from four dense, same-index arrays touches 25 bytes
    /// per resource instead of striding whole view structs; every key goes
    /// through the same `_parts` helpers as [`CandidateIndex::update`], so
    /// the two entry points produce bit-identical rankings (unit-tested
    /// below).
    pub fn update_cols(&mut self, rid: ResourceId, cols: &super::ViewColumns) {
        self.unfile(rid.0);
        let i = rid.0 as usize;
        let speed = cols.speed[i];
        if !Self::is_eligible_parts(speed, cols.slots[i]) {
            return;
        }
        let rate = cols.rate[i];
        self.file(
            rid.0,
            RankKeys {
                cost: cost_rank_key_parts(rate, speed),
                speed,
                rate,
                service: service_rank_key_parts(cols.measured[i], speed),
            },
        );
    }

    /// [`CandidateIndex::update_cols`] over many resources at once — the
    /// batch path a view refresh takes when a sweep dirties a large slice
    /// of the table (MDS refresh, repricing sweeps, agreement expiry).
    /// Keys for each fixed-width chunk are derived first, in tight
    /// branch-light loops over the dense column arrays (no set is touched
    /// mid-chunk, so the arithmetic auto-vectorizes), then the chunk is
    /// filed. Every key goes through the same `_parts` helpers as the
    /// per-entry path and filing stays per-resource, so the resulting
    /// rankings are bit-identical to calling
    /// [`CandidateIndex::update_cols`] once per id (unit-proven below) —
    /// only the cache behaviour of the derive differs.
    pub fn update_cols_bulk(&mut self, rids: &[u32], cols: &super::ViewColumns) {
        let mut cost = [0.0f64; BULK_CHUNK];
        let mut service = [0.0f64; BULK_CHUNK];
        let mut eligible = [false; BULK_CHUNK];
        for chunk in rids.chunks(BULK_CHUNK) {
            // Derive pass: keys for the whole chunk straight off the four
            // dense arrays.
            for (k, &r) in chunk.iter().enumerate() {
                let i = r as usize;
                let speed = cols.speed[i];
                eligible[k] = Self::is_eligible_parts(speed, cols.slots[i]);
                cost[k] = cost_rank_key_parts(cols.rate[i], speed);
                service[k] = service_rank_key_parts(cols.measured[i], speed);
            }
            // File pass: unfile stale entries and re-insert under the
            // precomputed keys.
            for (k, &r) in chunk.iter().enumerate() {
                self.unfile(r);
                if !eligible[k] {
                    continue;
                }
                let i = r as usize;
                self.file(
                    r,
                    RankKeys {
                        cost: cost[k],
                        speed: cols.speed[i],
                        rate: cols.rate[i],
                        service: service[k],
                    },
                );
            }
        }
    }

    /// Remove resource `r`'s stale entries (if ranked), growing the key
    /// table to cover `r` on the way.
    fn unfile(&mut self, r: u32) {
        let i = r as usize;
        if i >= self.keys.len() {
            self.keys.resize(i + 1, None);
        }
        if let Some(k) = self.keys[i].take() {
            self.by_cost
                .remove(&(TotalF64(k.cost), Reverse(TotalF64(k.speed)), r));
            self.by_speed.remove(&(Reverse(TotalF64(k.speed)), r));
            self.by_rate.remove(&(TotalF64(k.rate), r));
            self.by_service.remove(&(Reverse(TotalF64(k.service)), r));
        }
    }

    /// Insert resource `r` under freshly-computed keys and record them for
    /// the next [`CandidateIndex::unfile`].
    fn file(&mut self, r: u32, k: RankKeys) {
        self.by_cost
            .insert((TotalF64(k.cost), Reverse(TotalF64(k.speed)), r));
        self.by_speed.insert((Reverse(TotalF64(k.speed)), r));
        self.by_rate.insert((TotalF64(k.rate), r));
        self.by_service.insert((Reverse(TotalF64(k.service)), r));
        self.keys[r as usize] = Some(k);
    }

    /// Number of eligible resources.
    pub fn len(&self) -> usize {
        self.by_cost.len()
    }

    /// True when no resource is currently eligible.
    pub fn is_empty(&self) -> bool {
        self.by_cost.is_empty()
    }

    /// True when `rid` is currently ranked (eligible).
    pub fn contains(&self, rid: ResourceId) -> bool {
        matches!(self.keys.get(rid.0 as usize), Some(Some(_)))
    }

    /// Eligible resources, cheapest expected cost per job first (ties:
    /// faster machine, then lower id).
    pub fn cost_ranked(&self) -> impl Iterator<Item = ResourceId> + '_ {
        self.by_cost.iter().map(|&(_, _, r)| ResourceId(r))
    }

    /// Eligible resources, fastest planning speed first (ties: lower id).
    pub fn speed_ranked(&self) -> impl Iterator<Item = ResourceId> + '_ {
        self.by_speed.iter().map(|&(_, r)| ResourceId(r))
    }

    /// Eligible resources, lowest quoted rate first (ties: lower id).
    pub fn rate_ranked(&self) -> impl Iterator<Item = ResourceId> + '_ {
        self.by_rate.iter().map(|&(_, r)| ResourceId(r))
    }

    /// Eligible resources, highest measured-or-prior service rate first
    /// (ties: lower id). See [`service_rank_key`] for the mixed scale.
    pub fn service_ranked(&self) -> impl Iterator<Item = ResourceId> + '_ {
        self.by_service.iter().map(|&(_, r)| ResourceId(r))
    }

    /// Eligible resources in ascending id order (the rotation order the
    /// round-robin/random baselines walk).
    pub fn eligible_ids(&self) -> impl Iterator<Item = ResourceId> + '_ {
        // keys[] is id-indexed, so a scan of the Somes IS id order.
        self.keys
            .iter()
            .enumerate()
            .filter(|(_, k)| k.is_some())
            .map(|(i, _)| ResourceId(i as u32))
    }

    /// Cheapest quoted rate among eligible resources (`None` when nothing
    /// is eligible) — lets rate-capped policies bail in O(1) when every
    /// quote sits above their cap.
    pub fn min_rate(&self) -> Option<f64> {
        self.by_rate.iter().next().map(|e| e.0 .0)
    }

    /// Audit this index against the view table it is supposed to mirror:
    /// every eligible view is ranked under exactly the keys a fresh re-key
    /// would produce (bit-compared), every ineligible view is absent, and
    /// no ranking carries extra entries. This is the runtime counterpart of
    /// the static DIRTY-PAIR lint rule — the debug tick validator in
    /// `sim::world` calls it so a driver that mutates views without
    /// updating the index fails loudly instead of scheduling on stale
    /// rankings. O(views · log R); debug builds only in practice.
    pub fn consistent_with(&self, views: &[ResourceView]) -> Result<(), String> {
        let mut eligible = 0usize;
        for v in views {
            let i = v.id.0 as usize;
            let stored = self.keys.get(i).copied().flatten();
            if !Self::is_eligible(v) {
                if stored.is_some() {
                    return Err(format!("{}: ineligible view still ranked", v.id));
                }
                continue;
            }
            eligible += 1;
            let Some(k) = stored else {
                return Err(format!("{}: eligible view missing from the index", v.id));
            };
            let fresh = [
                cost_rank_key(v),
                v.planning_speed,
                v.rate,
                service_rank_key(v),
            ];
            let kept = [k.cost, k.speed, k.rate, k.service];
            if fresh
                .iter()
                .zip(&kept)
                .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!(
                    "{}: stale ranking keys (view changed without an index update)",
                    v.id
                ));
            }
            let r = v.id.0;
            if !self
                .by_cost
                .contains(&(TotalF64(k.cost), Reverse(TotalF64(k.speed)), r))
                || !self.by_speed.contains(&(Reverse(TotalF64(k.speed)), r))
                || !self.by_rate.contains(&(TotalF64(k.rate), r))
                || !self.by_service.contains(&(Reverse(TotalF64(k.service)), r))
            {
                return Err(format!("{}: ranking entry missing for recorded keys", v.id));
            }
        }
        let sizes = [
            self.by_cost.len(),
            self.by_speed.len(),
            self.by_rate.len(),
            self.by_service.len(),
        ];
        if sizes.iter().any(|&s| s != eligible) {
            return Err(format!(
                "ranking sizes {sizes:?} != {eligible} eligible views"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::view;
    use super::*;

    fn ranked<I: Iterator<Item = ResourceId>>(it: I) -> Vec<u32> {
        it.map(|r| r.0).collect()
    }

    #[test]
    fn cost_order_is_cheapest_then_fastest_then_id() {
        // view(id, slots, speed, rate): cost key = rate*3600/speed.
        let views = vec![
            view(0, 4, 1.0, 2.0), // key 7200
            view(1, 4, 2.0, 2.0), // key 3600
            view(2, 4, 1.0, 1.0), // key 3600, slower than 1
            view(3, 4, 2.0, 2.0), // key 3600, ties 1 on speed -> id
        ];
        let ix = CandidateIndex::from_views(&views);
        assert_eq!(ranked(ix.cost_ranked()), vec![1, 3, 2, 0]);
    }

    #[test]
    fn speed_ties_break_toward_lower_id() {
        let views = vec![
            view(0, 1, 1.0, 1.0),
            view(1, 1, 2.0, 9.0),
            view(2, 1, 2.0, 0.1),
            view(3, 1, 0.5, 0.1),
        ];
        let ix = CandidateIndex::from_views(&views);
        // The regression the shared keys exist for: equal speeds order by
        // id, exactly like the old stable sorts over the id-ordered table.
        assert_eq!(ranked(ix.speed_ranked()), vec![1, 2, 0, 3]);
        assert_eq!(ranked(ix.eligible_ids()), vec![0, 1, 2, 3]);
    }

    #[test]
    fn ineligible_views_are_unranked() {
        let down = view(0, 4, 0.0, 1.0);
        let saturated = view(1, 0, 2.0, 1.0);
        let up = view(2, 2, 1.0, 1.0);
        let ix = CandidateIndex::from_views(&[down, saturated, up]);
        assert_eq!(ix.len(), 1);
        assert!(!ix.contains(ResourceId(0)));
        assert!(!ix.contains(ResourceId(1)));
        assert!(ix.contains(ResourceId(2)));
        assert_eq!(ix.min_rate(), Some(1.0));
    }

    #[test]
    fn update_repositions_and_evicts() {
        let mut views = vec![
            view(0, 4, 1.0, 1.0), // cost 3600
            view(1, 4, 1.0, 2.0), // cost 7200
        ];
        let mut ix = CandidateIndex::from_views(&views);
        assert_eq!(ranked(ix.cost_ranked()), vec![0, 1]);
        // Resource 1 gets cheap: it must move to the front...
        views[1].rate = 0.5;
        ix.update(&views[1]);
        assert_eq!(ranked(ix.cost_ranked()), vec![1, 0]);
        // ...and a failed resource must leave every ranking.
        views[0].planning_speed = 0.0;
        ix.update(&views[0]);
        assert_eq!(ranked(ix.cost_ranked()), vec![1]);
        assert_eq!(ranked(ix.speed_ranked()), vec![1]);
        assert_eq!(ranked(ix.rate_ranked()), vec![1]);
        assert_eq!(ix.min_rate(), Some(0.5));
        // Recovery re-ranks it.
        views[0].planning_speed = 3.0;
        ix.update(&views[0]);
        assert_eq!(ranked(ix.speed_ranked()), vec![0, 1]);
    }

    #[test]
    fn incremental_updates_converge_to_a_rebuild() {
        let mut views: Vec<_> = (0..12)
            .map(|i| view(i, 1 + i % 3, 0.5 + 0.3 * i as f64, 2.0 / (1 + i) as f64))
            .collect();
        let mut ix = CandidateIndex::from_views(&views);
        // Churn a few entries through several states.
        views[3].planning_speed = 0.0;
        ix.update(&views[3]);
        views[7].rate = 0.01;
        ix.update(&views[7]);
        views[3].planning_speed = 2.2;
        ix.update(&views[3]);
        views[5].slots = 0;
        ix.update(&views[5]);
        views[9].measured_jphps = Some(4.5);
        ix.update(&views[9]);
        let mut fresh = CandidateIndex::new(views.len());
        fresh.rebuild_from(&views);
        assert_eq!(ranked(ix.cost_ranked()), ranked(fresh.cost_ranked()));
        assert_eq!(ranked(ix.speed_ranked()), ranked(fresh.speed_ranked()));
        assert_eq!(ranked(ix.rate_ranked()), ranked(fresh.rate_ranked()));
        assert_eq!(ranked(ix.service_ranked()), ranked(fresh.service_ranked()));
        assert_eq!(ranked(ix.eligible_ids()), ranked(fresh.eligible_ids()));
    }

    #[test]
    fn service_rank_prefers_measured_history() {
        let mut slow_but_proven = view(0, 1, 0.5, 1.0);
        slow_but_proven.measured_jphps = Some(9.0);
        let fast_prior = view(1, 1, 3.0, 1.0);
        let ix = CandidateIndex::from_views(&[slow_but_proven, fast_prior]);
        assert_eq!(ranked(ix.service_ranked()), vec![0, 1]);
    }

    #[test]
    fn audit_matches_maintained_index_and_catches_desync() {
        let mut views = vec![
            view(0, 4, 1.0, 2.0),
            view(1, 0, 2.0, 1.0), // saturated: unranked by design
            view(2, 2, 1.5, 0.5),
        ];
        let mut ix = CandidateIndex::from_views(&views);
        assert!(ix.consistent_with(&views).is_ok());
        // Mutating a view without update() is exactly the desync the audit
        // (and the DIRTY-PAIR lint rule) exists to catch.
        views[0].rate = 9.0;
        let err = ix.consistent_with(&views).unwrap_err();
        assert!(err.contains("stale ranking keys"), "got: {err}");
        ix.update(&views[0]);
        assert!(ix.consistent_with(&views).is_ok());
        // An eligibility flip without update() is caught too.
        views[2].slots = 0;
        let err = ix.consistent_with(&views).unwrap_err();
        assert!(err.contains("still ranked"), "got: {err}");
        ix.update(&views[2]);
        assert!(ix.consistent_with(&views).is_ok());
    }

    #[test]
    fn update_cols_matches_update_bit_exactly() {
        use super::super::ViewColumns;
        // Cover the encoding edges: no history (None), zero / negative
        // measured history (both fall back to the prior), a down machine,
        // a saturated machine, and a plain measured entry.
        let mut views = vec![
            view(0, 4, 1.0, 2.0),
            view(1, 2, 2.5, 0.4),
            view(2, 0, 2.0, 1.0), // saturated
            view(3, 4, 0.0, 1.0), // down
            view(4, 1, 1.5, 3.0),
            view(5, 3, 0.7, 0.9),
        ];
        views[1].measured_jphps = Some(4.25);
        views[4].measured_jphps = Some(0.0);
        views[5].measured_jphps = Some(-1.0);
        let mut cols = ViewColumns::new(views.len());
        let mut via_views = CandidateIndex::new(views.len());
        let mut via_cols = CandidateIndex::new(views.len());
        for v in &views {
            cols.set(v);
            via_views.update(v);
            via_cols.update_cols(v.id, &cols);
        }
        assert_eq!(ranked(via_views.cost_ranked()), ranked(via_cols.cost_ranked()));
        assert_eq!(ranked(via_views.speed_ranked()), ranked(via_cols.speed_ranked()));
        assert_eq!(ranked(via_views.rate_ranked()), ranked(via_cols.rate_ranked()));
        assert_eq!(
            ranked(via_views.service_ranked()),
            ranked(via_cols.service_ranked())
        );
        // The audit bit-compares stored keys against a fresh AoS re-key, so
        // passing it proves the SoA path's keys match to the last bit.
        assert!(via_cols.consistent_with(&views).is_ok());
        // Churn through eligibility flips on both paths in lockstep.
        views[0].planning_speed = 0.0;
        views[2].slots = 3;
        views[4].measured_jphps = Some(9.0);
        for v in [&views[0], &views[2], &views[4]] {
            cols.set(v);
            via_views.update(v);
            via_cols.update_cols(v.id, &cols);
        }
        assert_eq!(ranked(via_views.cost_ranked()), ranked(via_cols.cost_ranked()));
        assert_eq!(
            ranked(via_views.service_ranked()),
            ranked(via_cols.service_ranked())
        );
        assert!(via_cols.consistent_with(&views).is_ok());
    }

    #[test]
    fn update_cols_bulk_matches_update_cols_bit_exactly() {
        use super::super::ViewColumns;
        // More ids than one BULK_CHUNK so the chunked derive spans a full
        // chunk plus a ragged tail, with eligibility flips and history
        // edge cases sprinkled through both.
        let n = BULK_CHUNK * 2 + 7;
        let mut views: Vec<_> = (0..n as u32)
            .map(|i| {
                view(
                    i,
                    (i % 5) as u32, // every 5th is saturated (slots 0)
                    if i % 7 == 3 { 0.0 } else { 0.3 + 0.217 * i as f64 },
                    0.05 + 1.31 * ((i * i) % 11) as f64,
                )
            })
            .collect();
        views[2].measured_jphps = Some(4.25);
        views[9].measured_jphps = Some(0.0);
        views[17].measured_jphps = Some(-3.0);
        views[20].measured_jphps = Some(0.75);
        let mut cols = ViewColumns::new(n);
        for v in &views {
            cols.set(v);
        }
        let rids: Vec<u32> = (0..n as u32).collect();
        let mut per_entry = CandidateIndex::new(n);
        for &r in &rids {
            per_entry.update_cols(ResourceId(r), &cols);
        }
        let mut bulk = CandidateIndex::new(n);
        bulk.update_cols_bulk(&rids, &cols);
        assert_eq!(ranked(per_entry.cost_ranked()), ranked(bulk.cost_ranked()));
        assert_eq!(ranked(per_entry.speed_ranked()), ranked(bulk.speed_ranked()));
        assert_eq!(ranked(per_entry.rate_ranked()), ranked(bulk.rate_ranked()));
        assert_eq!(
            ranked(per_entry.service_ranked()),
            ranked(bulk.service_ranked())
        );
        // The audit bit-compares stored keys against fresh AoS re-keys, so
        // passing it proves the chunked keys match to the last bit.
        assert!(bulk.consistent_with(&views).is_ok());
        // Re-keying a dirty subset over a live index (the refresh shape):
        // mutate some views, bulk-re-key just those ids on one index and
        // per-entry re-key them on the other.
        views[1].rate = 9.0;
        views[5].slots = 4;
        views[12].planning_speed = 0.0;
        views[20].measured_jphps = Some(11.0);
        let dirty: Vec<u32> = vec![1, 5, 12, 20];
        for &r in &dirty {
            cols.set(&views[r as usize]);
        }
        for &r in &dirty {
            per_entry.update_cols(ResourceId(r), &cols);
        }
        bulk.update_cols_bulk(&dirty, &cols);
        assert_eq!(ranked(per_entry.cost_ranked()), ranked(bulk.cost_ranked()));
        assert_eq!(ranked(per_entry.speed_ranked()), ranked(bulk.speed_ranked()));
        assert_eq!(ranked(per_entry.rate_ranked()), ranked(bulk.rate_ranked()));
        assert_eq!(
            ranked(per_entry.service_ranked()),
            ranked(bulk.service_ranked())
        );
        assert!(bulk.consistent_with(&views).is_ok());
    }

    #[test]
    fn total_f64_orders_like_total_cmp() {
        assert!(TotalF64(-0.0) < TotalF64(0.0));
        assert!(TotalF64(-0.0) != TotalF64(0.0));
        assert!(TotalF64(1.0) < TotalF64(f64::INFINITY));
        assert!(TotalF64(f64::INFINITY) < TotalF64(f64::NAN));
        assert_eq!(TotalF64(2.5), TotalF64(2.5));
    }
}
