//! Job-consumption-rate estimation ("Historical Information, including Job
//! Consumption Rate" — one of the paper's §3 scheduling parameters).
//!
//! For each resource the estimator maintains an EWMA of per-job service time
//! (dispatch → completion wall time divided by concurrency), giving the
//! measured jobs/hour/slot figure the DBC policies prefer over the
//! capability prior. It also tracks the global per-job work estimate that
//! seeds planning before any history exists.

use crate::types::{ResourceId, SimTime};
use std::collections::BTreeMap;

/// EWMA weight for new observations.
const ALPHA: f64 = 0.3;

#[derive(Debug, Clone, Default)]
struct ResStats {
    /// EWMA of observed per-job service seconds (queue + stage + run).
    ewma_service_s: Option<f64>,
    pub completed: u32,
    pub failed: u32,
}

/// Per-experiment historical information.
#[derive(Debug, Clone, Default)]
pub struct RateEstimator {
    stats: BTreeMap<ResourceId, ResStats>,
    /// EWMA of measured job work in reference CPU-hours.
    work_ewma_ref_h: Option<f64>,
}

impl RateEstimator {
    /// Record a completion: `service_s` is wall seconds from dispatch to
    /// completion; `work_ref_h` the job's work in reference CPU-hours
    /// (derived from machine speed × busy time).
    pub fn on_complete(
        &mut self,
        rid: ResourceId,
        service_s: SimTime,
        work_ref_h: f64,
    ) {
        let s = self.stats.entry(rid).or_default();
        s.completed += 1;
        s.ewma_service_s = Some(match s.ewma_service_s {
            Some(prev) => (1.0 - ALPHA) * prev + ALPHA * service_s,
            None => service_s,
        });
        if work_ref_h > 0.0 {
            self.work_ewma_ref_h = Some(match self.work_ewma_ref_h {
                Some(prev) => (1.0 - ALPHA) * prev + ALPHA * work_ref_h,
                None => work_ref_h,
            });
        }
    }

    /// Record a failure (drops the resource's attractiveness implicitly by
    /// keeping service history unchanged but counting the strike).
    pub fn on_failure(&mut self, rid: ResourceId) {
        self.stats.entry(rid).or_default().failed += 1;
    }

    /// Measured jobs/hour/slot, if any history exists for the resource.
    pub fn measured_jphps(&self, rid: ResourceId) -> Option<f64> {
        self.stats
            .get(&rid)
            .and_then(|s| s.ewma_service_s)
            .map(|svc| 3600.0 / svc.max(1e-6))
    }

    /// Completions recorded for a resource.
    pub fn completed(&self, rid: ResourceId) -> u32 {
        self.stats.get(&rid).map(|s| s.completed).unwrap_or(0)
    }

    /// Failures recorded for a resource.
    pub fn failures(&self, rid: ResourceId) -> u32 {
        self.stats.get(&rid).map(|s| s.failed).unwrap_or(0)
    }

    /// Current job-work estimate (ref CPU-hours), falling back to the prior.
    pub fn job_work_ref_h(&self, prior: f64) -> f64 {
        self.work_ewma_ref_h.unwrap_or(prior)
    }

    /// Total completions across resources.
    pub fn total_completed(&self) -> u32 {
        self.stats.values().map(|s| s.completed).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_history_means_no_measurement() {
        let est = RateEstimator::default();
        assert_eq!(est.measured_jphps(ResourceId(0)), None);
        assert_eq!(est.job_work_ref_h(2.5), 2.5);
    }

    #[test]
    fn single_observation_sets_rate() {
        let mut est = RateEstimator::default();
        est.on_complete(ResourceId(0), 1800.0, 0.5);
        // 1800 s per job = 2 jobs/hour.
        assert!((est.measured_jphps(ResourceId(0)).unwrap() - 2.0).abs() < 1e-9);
        assert!((est.job_work_ref_h(9.9) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges_toward_new_regime() {
        let mut est = RateEstimator::default();
        est.on_complete(ResourceId(0), 3600.0, 1.0);
        // Machine speeds up: 900 s/job from now on.
        for _ in 0..30 {
            est.on_complete(ResourceId(0), 900.0, 1.0);
        }
        let rate = est.measured_jphps(ResourceId(0)).unwrap();
        assert!((rate - 4.0).abs() < 0.1, "rate={rate}");
    }

    #[test]
    fn per_resource_isolation() {
        let mut est = RateEstimator::default();
        est.on_complete(ResourceId(0), 3600.0, 1.0);
        est.on_complete(ResourceId(1), 7200.0, 1.0);
        assert!(est.measured_jphps(ResourceId(0)).unwrap() > est
            .measured_jphps(ResourceId(1))
            .unwrap());
        assert_eq!(est.completed(ResourceId(0)), 1);
        assert_eq!(est.total_completed(), 2);
    }

    #[test]
    fn failures_counted() {
        let mut est = RateEstimator::default();
        est.on_failure(ResourceId(3));
        est.on_failure(ResourceId(3));
        assert_eq!(est.failures(ResourceId(3)), 2);
        assert_eq!(est.completed(ResourceId(3)), 0);
    }
}
