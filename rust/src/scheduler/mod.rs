//! The Nimrod/G schedule advisor (paper §2 "Scheduler", §3).
//!
//! Responsibilities split exactly as the paper lists them:
//!
//! 1. **resource discovery** — the simulation/live driver queries
//!    [`crate::grid::mds`] and assembles a [`ResourceView`] per authorized
//!    machine (stale capability + status + current quoted price);
//! 2. **resource selection** — a [`Policy`] turns those views plus the
//!    experiment state ([`SchedCtx`]) into an [`Allocation`]: a target
//!    number of concurrently in-flight jobs per resource;
//! 3. **job assignment** — the dispatcher tops resources up to their
//!    allocation and tears down what the policy no longer wants.
//!
//! **Selection is index-backed.** Policies do not sort the view table:
//! they walk ranked iterators off the persistent [`CandidateIndex`] each
//! driver maintains alongside its view table (cheapest-cost, fastest-speed,
//! lowest-rate, best-service orderings; see [`index`]). The index is
//! updated from the same dirty-view deltas that drive the incremental view
//! refresh — an unchanged view keeps its rank, a dirtied one is re-keyed
//! in O(log R) — so allocation cost scales with the candidates a policy
//! actually walks, not with grid size. **New drivers and policies must
//! keep the two in lockstep: every rebuilt view entry goes through
//! [`CandidateIndex::update`], and every ranking comparison goes through
//! the shared key helpers ([`index::cost_rank_key`],
//! [`index::service_rank_key`]).** The sort-every-tick baseline survives
//! behind the drivers' `set_full_allocation_sort` flag (mirroring
//! `set_full_view_rebuild`) and must replay bit-exactly.
//!
//! Policies implemented (see [`dbc`] and [`baselines`]):
//!
//! | name | behaviour |
//! |---|---|
//! | `cost` | deadline/budget-constrained **cost-optimizing** (the paper's headline scheduler: cheapest resources that still meet the deadline) |
//! | `time` | deadline-constrained **time-optimizing** (finish ASAP within budget) |
//! | `conservative-time` | time-optimizing with per-job budget guards |
//! | `deadline-only` | the pre-economy Nimrod/G (meet deadline, ignore cost) |
//! | `round-robin` | classic metacomputing baseline |
//! | `random` | random resource subset |
//! | `perf` | AppLeS-like performance-only selection |
//! | `fixed-rate` | REXEC-like: any resource priced under a user rate cap |

pub mod baselines;
pub mod dbc;
pub mod index;
pub mod rate;

pub use index::CandidateIndex;
pub use rate::RateEstimator;

use crate::types::{GridDollars, ResourceId, SimTime};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Safety factor applied to time-to-deadline when sizing capacity: plan to
/// finish in 85% of the remaining window, leaving slack for estimate error
/// and running stragglers (jobs are never pre-empted once started).
pub const DEADLINE_SAFETY: f64 = 0.85;

/// Smallest planning window, hours. Once `now` reaches the deadline the
/// raw window is zero or negative (and NaN with corrupt inputs like
/// `inf - inf`); dividing remaining work by it would make required rates
/// non-finite and capacity fills would allocate nothing — the run would
/// stall forever instead of finishing late. Clamping to a tiny positive
/// window degrades past-deadline scheduling to best-effort: the required
/// rate saturates every eligible slot.
pub(crate) const MIN_PLANNING_WINDOW_H: f64 = 1e-6;

/// Hours left in a safety-discounted planning window, guarded to stay
/// finite and positive (see [`MIN_PLANNING_WINDOW_H`]). The single window
/// guard shared by [`SchedCtx::hours_left`] and the DBC schedulers'
/// tunable-safety variant.
pub(crate) fn guarded_window_h(
    now: SimTime,
    deadline: SimTime,
    safety: f64,
) -> f64 {
    let h = (deadline - now) * safety / 3600.0;
    if h.is_finite() {
        h.max(MIN_PLANNING_WINDOW_H)
    } else {
        MIN_PLANNING_WINDOW_H
    }
}

/// Everything the scheduler knows about one discovered resource at tick
/// time. Assembled by the driver from MDS (stale), GRAM (in-flight counts),
/// the economy (current quoted rate for this user) and the rate estimator.
#[derive(Debug, Clone)]
pub struct ResourceView {
    pub id: ResourceId,
    /// Concurrent job slots GRAM admits (≤ CPUs).
    pub slots: u32,
    /// Stale effective speed from the directory (0 if down at last refresh).
    pub planning_speed: f64,
    /// Quoted G$/CPU-second for this user right now.
    pub rate: GridDollars,
    /// Jobs currently dispatched here (running + queued).
    pub in_flight: u32,
    /// Measured service rate, jobs/hour/slot, if history exists.
    pub measured_jphps: Option<f64>,
    pub batch_queue: bool,
}

impl ResourceView {
    /// Planning throughput in jobs/hour/slot: measured history if present,
    /// else the capability prior (speed / work-per-job).
    pub fn jphps(&self, job_work_ref_h: f64) -> f64 {
        match self.measured_jphps {
            Some(m) if m > 0.0 => m,
            _ => {
                if job_work_ref_h <= 0.0 {
                    0.0
                } else {
                    self.planning_speed / job_work_ref_h
                }
            }
        }
    }

    /// Expected G$ to run one job here (CPU-seconds × rate).
    pub fn cost_per_job(&self, job_work_ref_h: f64) -> GridDollars {
        if self.planning_speed <= 0.0 {
            return GridDollars::INFINITY;
        }
        // CPU-time on this machine = ref-work / speed.
        self.rate * job_work_ref_h / self.planning_speed * 3600.0
    }
}

/// Struct-of-arrays mirror of the ranking-relevant [`ResourceView`]
/// columns, indexed by dense resource id. The dirty-view refresh re-keys
/// the candidate index for every changed resource; chasing those four
/// fields through 60-byte view structs is cache-hostile on 10k-machine
/// grids, so the sim world maintains this mirror alongside the view table
/// and re-keys through [`CandidateIndex::update_cols`] instead. The
/// columns are a *projection* of the views, never a second source of
/// truth: whatever writes `views[i]` writes `cols.set(&views[i])` in the
/// same breath (the DIRTY-PAIR discipline extended to the mirror), and
/// the debug-tick `consistent_with` audit catches drift.
#[derive(Debug, Clone, Default)]
pub struct ViewColumns {
    /// Quoted G$/CPU-second ([`ResourceView::rate`]).
    pub rate: Vec<f64>,
    /// Admitted slots ([`ResourceView::slots`]).
    pub slots: Vec<u32>,
    /// Stale directory speed ([`ResourceView::planning_speed`]).
    pub speed: Vec<f64>,
    /// Measured jobs/hour/slot, `ResourceView::measured_jphps` with
    /// "no history" flattened to `0.0` (lossless for ranking: a
    /// non-positive measurement already falls back to the speed prior —
    /// see [`index::service_rank_key_parts`]).
    pub measured: Vec<f64>,
}

impl ViewColumns {
    /// Zeroed columns for `n` resources (all ineligible until `set`).
    pub fn new(n: usize) -> ViewColumns {
        ViewColumns {
            rate: vec![0.0; n],
            slots: vec![0; n],
            speed: vec![0.0; n],
            measured: vec![0.0; n],
        }
    }

    /// Project one freshly-rebuilt view into the columns, growing them if
    /// `v.id` is beyond the current size.
    pub fn set(&mut self, v: &ResourceView) {
        let i = v.id.0 as usize;
        if i >= self.slots.len() {
            self.rate.resize(i + 1, 0.0);
            self.slots.resize(i + 1, 0);
            self.speed.resize(i + 1, 0.0);
            self.measured.resize(i + 1, 0.0);
        }
        self.rate[i] = v.rate;
        self.slots[i] = v.slots;
        self.speed[i] = v.planning_speed;
        self.measured[i] = v.measured_jphps.unwrap_or(0.0);
    }

    /// Number of resources covered.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when sized for zero resources.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// Experiment state the policy plans against.
#[derive(Debug)]
pub struct SchedCtx<'a> {
    pub now: SimTime,
    pub deadline: SimTime,
    /// Remaining budget headroom (None = unlimited).
    pub budget_headroom: Option<GridDollars>,
    /// Jobs not yet completed (includes in-flight).
    pub remaining_jobs: u32,
    /// Current estimate of per-job work, reference-machine CPU-hours.
    pub job_work_ref_h: f64,
    pub resources: &'a [ResourceView],
    /// Ranked orderings over `resources`, maintained incrementally by the
    /// driver (see [`index`]). Policies consume candidates through the
    /// `ranked_by_*` iterators instead of sorting the view slice.
    pub candidates: &'a CandidateIndex,
    pub rng: &'a mut Rng,
}

/// Look a ranked candidate's view up in the driver's view slice. Drivers
/// keep the slice dense (`resources[i].id == i`), which is the O(1) fast
/// path; hand-built test slices with arbitrary ids fall back to a scan.
fn view_in(resources: &[ResourceView], rid: ResourceId) -> &ResourceView {
    match resources.get(rid.0 as usize) {
        Some(v) if v.id == rid => v,
        _ => resources
            .iter()
            .find(|v| v.id == rid)
            // lint:allow(PANIC-BUDGET): the index only ranks ids drawn from this very slice; a miss is a driver bug
            .expect("ranked candidate has a view"),
    }
}

impl<'a> SchedCtx<'a> {
    /// Hours to the (safety-discounted) deadline. Always finite and
    /// positive — see [`guarded_window_h`].
    pub fn hours_left(&self) -> f64 {
        guarded_window_h(self.now, self.deadline, DEADLINE_SAFETY)
    }

    /// Aggregate throughput (jobs/hour) needed to finish in time.
    pub fn required_rate_jph(&self) -> f64 {
        self.remaining_jobs as f64 / self.hours_left()
    }

    /// The view behind a ranked candidate id. Panics if the candidate has
    /// no view — the index and view table were updated out of lockstep.
    pub fn view(&self, rid: ResourceId) -> &'a ResourceView {
        view_in(self.resources, rid)
    }

    /// Eligible views, cheapest expected cost per job first (price ties
    /// break toward the faster machine, then the lower id).
    pub fn ranked_by_cost(&self) -> impl Iterator<Item = &'a ResourceView> + 'a {
        let rs: &'a [ResourceView] = self.resources;
        let ix: &'a CandidateIndex = self.candidates;
        ix.cost_ranked().map(move |rid| view_in(rs, rid))
    }

    /// Eligible views, fastest planning speed first (ties: lower id).
    pub fn ranked_by_speed(
        &self,
    ) -> impl Iterator<Item = &'a ResourceView> + 'a {
        let rs: &'a [ResourceView] = self.resources;
        let ix: &'a CandidateIndex = self.candidates;
        ix.speed_ranked().map(move |rid| view_in(rs, rid))
    }

    /// Eligible views in ascending id order (the rotation order of the
    /// round-robin/random baselines).
    pub fn eligible_views(&self) -> impl Iterator<Item = &'a ResourceView> + 'a {
        let rs: &'a [ResourceView] = self.resources;
        let ix: &'a CandidateIndex = self.candidates;
        ix.eligible_ids().map(move |rid| view_in(rs, rid))
    }
}

/// Target in-flight jobs per resource. Resources absent from the map get 0
/// (drain: no new submissions, running jobs finish normally).
pub type Allocation = BTreeMap<ResourceId, u32>;

/// A scheduling policy (the pluggable "schedule advisor" of Figure 1).
///
/// Policies receive ranked candidate iterators through
/// [`SchedCtx::ranked_by_cost`] / [`SchedCtx::ranked_by_speed`] (et al.)
/// and should consume them lazily — the greedy fills stop after the
/// capacity they need, which is what keeps allocation sub-linear on large
/// grids. Construct policies through
/// [`crate::broker::PolicyRegistry::with_builtins`] (the old
/// `scheduler::by_name` shim is gone).
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    /// Compute the per-resource in-flight targets for this tick.
    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation;
}

/// All built-in policy names (benches and smoke tests iterate these).
///
/// Kept as a const so `no_std`-ish call sites and array iteration stay
/// cheap, but the single source of truth is
/// [`crate::broker::PolicyRegistry::with_builtins`]: the
/// `all_policies_is_exactly_the_registry` test asserts set equality in
/// both directions, so registering a new policy without listing it here
/// (or vice versa) fails the build's test run instead of silently missing
/// benches/smokes.
pub const ALL_POLICIES: [&str; 8] = [
    "cost",
    "time",
    "conservative-time",
    "deadline-only",
    "round-robin",
    "random",
    "perf",
    "fixed-rate",
];

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Build a simple view for policy unit tests.
    pub fn view(id: u32, slots: u32, speed: f64, rate: f64) -> ResourceView {
        ResourceView {
            id: ResourceId(id),
            slots,
            planning_speed: speed,
            rate,
            in_flight: 0,
            measured_jphps: None,
            batch_queue: false,
        }
    }

    /// Rank a hand-built view slice for a unit-test [`SchedCtx`].
    pub fn index_of(views: &[ResourceView]) -> CandidateIndex {
        CandidateIndex::from_views(views)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_policies_is_exactly_the_registry() {
        // The de-drift guard: ALL_POLICIES and the builtin registry must
        // name the same set, both directions, so a policy added to one
        // cannot silently miss the other (benches, smokes, CLI listings).
        let mut listed: Vec<&str> = ALL_POLICIES.to_vec();
        listed.sort_unstable();
        let reg = crate::broker::PolicyRegistry::with_builtins();
        let registered = reg.names(); // BTreeMap keys: already sorted
        assert_eq!(
            listed, registered,
            "scheduler::ALL_POLICIES drifted from PolicyRegistry::with_builtins()"
        );
        // And every listed name constructs a policy answering to it.
        for name in ALL_POLICIES {
            assert_eq!(reg.resolve(name).unwrap().name(), name);
        }
    }

    #[test]
    fn cost_per_job_uses_speed_and_rate() {
        let v = testutil::view(0, 4, 2.0, 1.0);
        // 1 ref-hour at speed 2 = 1800 cpu-s at 1 G$/s.
        assert!((v.cost_per_job(1.0) - 1800.0).abs() < 1e-9);
        let down = ResourceView {
            planning_speed: 0.0,
            ..v
        };
        assert!(down.cost_per_job(1.0).is_infinite());
    }

    #[test]
    fn jphps_prefers_measurement() {
        let mut v = testutil::view(0, 4, 2.0, 1.0);
        assert!((v.jphps(0.5) - 4.0).abs() < 1e-12); // prior: 2 / 0.5
        v.measured_jphps = Some(1.25);
        assert!((v.jphps(0.5) - 1.25).abs() < 1e-12);
    }
}
