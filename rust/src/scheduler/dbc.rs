//! Deadline/budget-constrained (DBC) scheduling algorithms — the paper's
//! computational-economy schedulers (§3).
//!
//! All four share the adaptive loop the paper describes for the Figure-3
//! trial: each tick they re-derive the capacity needed from *remaining* work
//! and *remaining* time, so as the deadline tightens (or machines slow down,
//! fail, or get expensive) the resource set grows, and when the experiment
//! runs ahead of schedule expensive machines are released — "adapts the list
//! of machines it is using depending on competition for them".
//!
//! Selection walks the ranked iterators of the driver's persistent
//! [`crate::scheduler::CandidateIndex`] (cheapest-cost order for the cost
//! optimizer, fastest-speed order for the rest) instead of sorting the
//! view table: the greedy capacity fills consume only as many candidates
//! as the required rate demands, so a tick's allocation cost no longer
//! scales with grid size.

use super::{
    guarded_window_h, Allocation, CandidateIndex, Policy, ResourceView,
    SchedCtx, DEADLINE_SAFETY,
};
use crate::types::ResourceId;

/// Hours to the deadline after applying a policy's safety factor (the
/// tunable generalization of [`SchedCtx::hours_left`], which fixes the
/// factor at [`DEADLINE_SAFETY`]). Always finite and positive via the
/// shared [`guarded_window_h`] guard.
fn hours_left(ctx: &SchedCtx<'_>, safety: f64) -> f64 {
    guarded_window_h(ctx.now, ctx.deadline, safety)
}

/// Aggregate throughput (jobs/hour) needed to finish inside the
/// safety-discounted window. Finite by construction of [`hours_left`].
fn required_rate_jph(ctx: &SchedCtx<'_>, safety: f64) -> f64 {
    let rate = ctx.remaining_jobs as f64 / hours_left(ctx, safety);
    debug_assert!(rate.is_finite(), "required rate must be finite");
    rate
}

/// Tail-feasibility filter: a resource is only eligible while one of its
/// slots can still finish a whole job inside the remaining window —
/// otherwise tail jobs get stranded on cheap-but-slow machines and the
/// deadline slips (the classic straggler failure the adaptive loop exists
/// to avoid).
fn finishes_in_window(r: &ResourceView, ctx: &SchedCtx<'_>, safety: f64) -> bool {
    r.jphps(ctx.job_work_ref_h) * hours_left(ctx, safety) >= 1.0
}

/// Greedy capacity fill: walk `ordered`, allocating slots until the
/// aggregate planned throughput reaches `needed_jph` (or candidates run
/// out). Never allocates more total slots than `remaining_jobs` (no point
/// holding capacity that can't receive a job). The iterator is consumed
/// lazily — once the target rate is met no further candidates are pulled,
/// which is what makes index-backed allocation sub-linear. Returns the
/// allocation plus the resources it landed on, in ranked order (the
/// cost optimizer's budget shed walks that list backwards).
fn fill_capacity<'a>(
    ordered: impl Iterator<Item = &'a ResourceView>,
    needed_jph: f64,
    remaining_jobs: u32,
    job_work_ref_h: f64,
) -> (Allocation, Vec<&'a ResourceView>) {
    let mut alloc = Allocation::new();
    let mut used: Vec<&ResourceView> = Vec::new();
    let mut rate = 0.0;
    let mut slots_total = 0u32;
    for r in ordered {
        if rate >= needed_jph || slots_total >= remaining_jobs {
            break;
        }
        let per_slot = r.jphps(job_work_ref_h);
        if per_slot <= 0.0 {
            continue;
        }
        // Slots needed from this resource to close the gap. A non-finite
        // demand (a NaN gap stalls the greedy fill: `NaN as u32` is 0)
        // must saturate instead — take everything this resource has.
        let gap = (needed_jph - rate) / per_slot;
        let want = if gap.is_finite() {
            gap.ceil().max(0.0) as u32
        } else {
            u32::MAX
        };
        let take = want
            .min(r.slots)
            .min(remaining_jobs.saturating_sub(slots_total));
        if take == 0 {
            continue;
        }
        alloc.insert(r.id, take);
        used.push(r);
        rate += take as f64 * per_slot;
        slots_total += take;
    }
    (alloc, used)
}

/// **Cost-optimizing DBC** — the paper's headline scheduler: select the
/// cheapest set of resources whose aggregate rate still meets the deadline;
/// re-evaluated every tick. With a budget, expensive resources are skipped
/// once the projected spend of the tentative allocation exceeds headroom.
#[derive(Debug)]
pub struct CostOpt {
    /// Fraction of the remaining window to plan into: lower values leave
    /// more slack for estimate error and stragglers at higher cost.
    /// Tunable via the policy spec `cost?safety=0.9`.
    pub safety: f64,
}

impl Default for CostOpt {
    fn default() -> Self {
        CostOpt {
            safety: DEADLINE_SAFETY,
        }
    }
}

impl Policy for CostOpt {
    fn name(&self) -> &'static str {
        "cost"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let needed = required_rate_jph(ctx, self.safety);
        let safety = self.safety;
        // Cheapest-first (the index's cost ranking), feasible-in-window
        // machines only. An empty result means the deadline is infeasible
        // on every machine: re-fill best-effort over all eligible machines
        // rather than stall (the user renegotiates the deadline, §3).
        let (mut alloc, mut used) = fill_capacity(
            ctx.ranked_by_cost()
                .filter(|r| finishes_in_window(r, ctx, safety)),
            needed,
            ctx.remaining_jobs,
            ctx.job_work_ref_h,
        );
        if alloc.is_empty() {
            (alloc, used) = fill_capacity(
                ctx.ranked_by_cost(),
                needed,
                ctx.remaining_jobs,
                ctx.job_work_ref_h,
            );
        }
        // Budget guard: projected spend for remaining jobs under this
        // allocation must fit in the headroom; if it does not, shed the
        // most expensive allocated resources — the tail of the ranked
        // fill, walked backwards (jobs they would have taken run later on
        // cheaper machines; the deadline may slip, which is the correct
        // economic outcome when the budget binds). Exact-tie order is
        // intentionally the reverse of the ranked fill: equal-cost
        // resources shed slower/higher-id first, where the pre-index code
        // (a second stable descending sort) shed faster-first. Traces are
        // bit-exact against the `set_full_allocation_sort` baseline, not
        // against pre-index recorded runs in cost-tie cases.
        if let Some(headroom) = ctx.budget_headroom {
            let mut projected = projected_spend(ctx, &alloc);
            for r in used.iter().rev() {
                if projected <= headroom {
                    break;
                }
                let slots = alloc.remove(&r.id).unwrap_or(0);
                let share = share_of(ctx, r, slots, &alloc);
                projected -= share * r.cost_per_job(ctx.job_work_ref_h);
            }
        }
        alloc
    }
}

/// Projected spend: remaining jobs split across the allocation
/// proportionally to throughput, each priced at its resource. O(allocated),
/// not O(resources).
fn projected_spend(ctx: &SchedCtx<'_>, alloc: &Allocation) -> f64 {
    let total_rate: f64 = alloc
        .iter()
        .map(|(rid, &n)| n as f64 * ctx.view(*rid).jphps(ctx.job_work_ref_h))
        .sum();
    if total_rate <= 0.0 {
        return 0.0;
    }
    alloc
        .iter()
        .map(|(rid, &n)| {
            let r = ctx.view(*rid);
            let share = n as f64 * r.jphps(ctx.job_work_ref_h) / total_rate;
            share * ctx.remaining_jobs as f64 * r.cost_per_job(ctx.job_work_ref_h)
        })
        .sum()
}

/// Job share a resource would take under the allocation (for shed math).
fn share_of(
    ctx: &SchedCtx<'_>,
    r: &ResourceView,
    slots: u32,
    rest: &Allocation,
) -> f64 {
    let r_rate = slots as f64 * r.jphps(ctx.job_work_ref_h);
    let rest_rate: f64 = rest
        .iter()
        .map(|(rid, &n)| n as f64 * ctx.view(*rid).jphps(ctx.job_work_ref_h))
        .sum();
    if r_rate + rest_rate <= 0.0 {
        0.0
    } else {
        r_rate / (r_rate + rest_rate) * ctx.remaining_jobs as f64
    }
}

/// **Time-optimizing DBC**: finish as early as possible — saturate resources
/// fastest-first (within budget if one is set). The deadline only matters as
/// a feasibility check; capacity is not trimmed to it.
#[derive(Debug, Default)]
pub struct TimeOpt;

impl Policy for TimeOpt {
    fn name(&self) -> &'static str {
        "time"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let mut alloc = Allocation::new();
        let mut slots_total = 0u32;
        let mut projected = 0.0;
        for r in ctx.ranked_by_speed() {
            if slots_total >= ctx.remaining_jobs {
                break;
            }
            let take = r.slots.min(ctx.remaining_jobs - slots_total);
            if let Some(headroom) = ctx.budget_headroom {
                // Rough guard: average cost of jobs placed here.
                let add = take as f64 * r.cost_per_job(ctx.job_work_ref_h);
                if projected + add > headroom {
                    continue;
                }
                projected += add;
            }
            alloc.insert(r.id, take);
            slots_total += take;
        }
        alloc
    }
}

/// **Conservative-time DBC**: time-optimizing, but each job is only placed
/// where its expected cost stays within an equal per-job share of the
/// remaining budget — guaranteeing unprocessed jobs keep their funding (the
/// conservative variant described in the Nimrod/G economy papers).
#[derive(Debug, Default)]
pub struct ConservativeTime;

impl Policy for ConservativeTime {
    fn name(&self) -> &'static str {
        "conservative-time"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let per_job_cap = ctx
            .budget_headroom
            .map(|h| h / ctx.remaining_jobs.max(1) as f64);
        let mut alloc = Allocation::new();
        let mut slots_total = 0u32;
        for r in ctx.ranked_by_speed() {
            if slots_total >= ctx.remaining_jobs {
                break;
            }
            if let Some(cap) = per_job_cap {
                if r.cost_per_job(ctx.job_work_ref_h) > cap {
                    continue;
                }
            }
            let take = r.slots.min(ctx.remaining_jobs - slots_total);
            alloc.insert(r.id, take);
            slots_total += take;
        }
        alloc
    }
}

/// **Deadline-only** — the first-generation Nimrod/G scheduler ("tries to
/// find sufficient resources to meet the user's deadline" without a real
/// economy): identical capacity sizing to cost-opt but ordered by speed, so
/// it grabs the fastest sufficient set regardless of price.
#[derive(Debug)]
pub struct DeadlineOnly {
    /// Planning safety factor (see [`CostOpt::safety`]); tunable via
    /// `deadline-only?safety=0.9`.
    pub safety: f64,
}

impl Default for DeadlineOnly {
    fn default() -> Self {
        DeadlineOnly {
            safety: DEADLINE_SAFETY,
        }
    }
}

impl Policy for DeadlineOnly {
    fn name(&self) -> &'static str {
        "deadline-only"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let needed = required_rate_jph(ctx, self.safety);
        let safety = self.safety;
        let (mut alloc, _) = fill_capacity(
            ctx.ranked_by_speed()
                .filter(|r| finishes_in_window(r, ctx, safety)),
            needed,
            ctx.remaining_jobs,
            ctx.job_work_ref_h,
        );
        if alloc.is_empty() {
            // Deadline infeasible everywhere: best-effort over every
            // eligible machine, fastest first.
            alloc = fill_capacity(
                ctx.ranked_by_speed(),
                needed,
                ctx.remaining_jobs,
                ctx.job_work_ref_h,
            )
            .0;
        }
        alloc
    }
}

/// Candidate resource sets for the reserve-ahead move: greedy
/// `want_slots`-deep prefixes of up to `max_sets` of the candidate index's
/// ranked orderings (cheapest-cost, fastest-speed, lowest-rate, best
/// service history — distinct lenses on the same grid, so the shadow
/// scheduler has genuinely different plans to price against each other).
/// Slots per member are capped at the view's visible slots; empty
/// prefixes (a dead grid) are dropped. Deterministic: pure reads of the
/// index and views, no RNG.
pub fn reservation_candidate_sets(
    views: &[ResourceView],
    candidates: &CandidateIndex,
    want_slots: u32,
    max_sets: usize,
) -> Vec<Vec<(ResourceId, u32)>> {
    let prefix = |ordered: &mut dyn Iterator<Item = ResourceId>| {
        let mut set: Vec<(ResourceId, u32)> = Vec::new();
        let mut remaining = want_slots;
        for rid in ordered {
            if remaining == 0 {
                break;
            }
            let Some(v) = views.get(rid.0 as usize) else {
                continue;
            };
            let take = v.slots.min(remaining);
            if take == 0 {
                continue;
            }
            set.push((rid, take));
            remaining -= take;
        }
        set
    };
    let mut sets = Vec::new();
    let orderings: [&mut dyn Iterator<Item = ResourceId>; 4] = [
        &mut candidates.cost_ranked(),
        &mut candidates.speed_ranked(),
        &mut candidates.rate_ranked(),
        &mut candidates.service_ranked(),
    ];
    for ordered in orderings.into_iter().take(max_sets) {
        let set = prefix(ordered);
        if !set.is_empty() {
            sets.push(set);
        }
    }
    sets
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{index_of, view};
    use super::*;
    use crate::scheduler::CandidateIndex;
    use crate::types::{ResourceId, HOUR};
    use crate::util::rng::Rng;

    fn ctx<'a>(
        resources: &'a [ResourceView],
        candidates: &'a CandidateIndex,
        rng: &'a mut Rng,
        deadline_h: f64,
        jobs: u32,
        budget: Option<f64>,
    ) -> SchedCtx<'a> {
        SchedCtx {
            now: 0.0,
            deadline: deadline_h * HOUR,
            budget_headroom: budget,
            remaining_jobs: jobs,
            job_work_ref_h: 1.0,
            resources,
            candidates,
            rng,
        }
    }

    #[test]
    fn cost_opt_prefers_cheap_resources() {
        // cheap-slow vs dear-fast; relaxed deadline ⇒ cheap only.
        let rs = vec![view(0, 10, 1.0, 0.5), view(1, 10, 2.0, 5.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 20.0, 10, None);
        let alloc = CostOpt::default().allocate(&mut c);
        assert!(alloc.contains_key(&ResourceId(0)));
        assert!(!alloc.contains_key(&ResourceId(1)), "{alloc:?}");
    }

    #[test]
    fn cost_opt_adds_resources_as_deadline_tightens() {
        let rs = vec![view(0, 4, 1.0, 0.5), view(1, 8, 1.0, 2.0), view(2, 8, 1.0, 6.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut loose = ctx(&rs, &ix, &mut rng, 40.0, 40, None);
        let a_loose: u32 = CostOpt::default().allocate(&mut loose).values().sum();
        let mut rng = Rng::new(1);
        let mut tight = ctx(&rs, &ix, &mut rng, 4.0, 40, None);
        let a_tight: u32 = CostOpt::default().allocate(&mut tight).values().sum();
        assert!(
            a_tight > a_loose,
            "tight {a_tight} should use more slots than loose {a_loose}"
        );
    }

    #[test]
    fn cost_opt_respects_budget() {
        let rs = vec![view(0, 2, 1.0, 0.001), view(1, 50, 1.0, 10.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        // Tight deadline wants the expensive machine, but the budget can
        // only carry the cheap one (100 jobs × 36000 G$/job ≫ 1000).
        let mut c = ctx(&rs, &ix, &mut rng, 1.0, 100, Some(1000.0));
        let alloc = CostOpt::default().allocate(&mut c);
        assert!(alloc.contains_key(&ResourceId(0)));
        assert!(
            !alloc.contains_key(&ResourceId(1)),
            "budget must exclude the dear machine: {alloc:?}"
        );
    }

    #[test]
    fn time_opt_saturates_fastest_first() {
        let rs = vec![view(0, 4, 1.0, 0.1), view(1, 4, 3.0, 9.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 10.0, 100, None);
        let alloc = TimeOpt.allocate(&mut c);
        assert_eq!(alloc[&ResourceId(1)], 4); // fastest fully used
        assert_eq!(alloc[&ResourceId(0)], 4);
    }

    #[test]
    fn time_opt_never_allocates_beyond_remaining_jobs() {
        let rs = vec![view(0, 64, 1.0, 1.0), view(1, 64, 2.0, 1.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 10.0, 5, None);
        let alloc = TimeOpt.allocate(&mut c);
        let total: u32 = alloc.values().sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn conservative_time_filters_by_per_job_share() {
        // Budget 100 over 10 jobs ⇒ 10 G$/job cap. Machine 1 costs 36 G$/job.
        let rs = vec![view(0, 8, 1.0, 0.001), view(1, 8, 1.0, 0.01)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 10.0, 10, Some(100.0));
        let alloc = ConservativeTime.allocate(&mut c);
        assert!(alloc.contains_key(&ResourceId(0)));
        assert!(!alloc.contains_key(&ResourceId(1)), "{alloc:?}");
    }

    #[test]
    fn deadline_only_ignores_price() {
        // Same speeds, wildly different prices: deadline-only picks by speed
        // order, so the expensive-fast machine is first.
        let rs = vec![view(0, 8, 1.0, 0.001), view(1, 8, 2.0, 100.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = ctx(&rs, &ix, &mut rng, 2.0, 8, None);
        let alloc = DeadlineOnly::default().allocate(&mut c);
        assert!(alloc.contains_key(&ResourceId(1)), "{alloc:?}");
    }

    #[test]
    fn allocations_shrink_when_ahead_of_schedule() {
        let rs = vec![view(0, 16, 1.0, 1.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        // 16 jobs, 16 hours: needs ~1 job/h ⇒ 2 slots at 1 jph/slot (ceil).
        let mut c = ctx(&rs, &ix, &mut rng, 16.0, 16, None);
        let alloc = CostOpt::default().allocate(&mut c);
        let total: u32 = alloc.values().sum();
        assert!(total <= 3, "should not saturate: {alloc:?}");
        // Down to 2 remaining jobs with 10 h left: 1 slot suffices.
        let mut rng = Rng::new(1);
        let mut c2 = SchedCtx {
            now: 6.0 * HOUR,
            deadline: 16.0 * HOUR,
            budget_headroom: None,
            remaining_jobs: 2,
            job_work_ref_h: 1.0,
            resources: &rs,
            candidates: &ix,
            rng: &mut rng,
        };
        let alloc2 = CostOpt::default().allocate(&mut c2);
        let total2: u32 = alloc2.values().sum();
        assert!(total2 <= total);
        assert!(total2 >= 1);
    }

    #[test]
    fn past_deadline_degrades_to_best_effort() {
        // Regression: with now past the deadline the window math used to
        // blow up and fill_capacity allocated nothing, stalling the run.
        // The guarded window must instead saturate eligible capacity so
        // the experiment finishes late rather than never.
        let rs = vec![view(0, 4, 1.0, 1.0), view(1, 4, 2.0, 3.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(1);
        let mut c = SchedCtx {
            now: 20.0 * HOUR,
            deadline: 15.0 * HOUR,
            budget_headroom: None,
            remaining_jobs: 6,
            job_work_ref_h: 1.0,
            resources: &rs,
            candidates: &ix,
            rng: &mut rng,
        };
        let alloc = CostOpt::default().allocate(&mut c);
        let total: u32 = alloc.values().sum();
        assert_eq!(total, 6, "must saturate, not stall: {alloc:?}");

        let mut rng = Rng::new(1);
        let mut c2 = SchedCtx {
            now: 20.0 * HOUR,
            deadline: 15.0 * HOUR,
            budget_headroom: None,
            remaining_jobs: 100,
            job_work_ref_h: 1.0,
            resources: &rs,
            candidates: &ix,
            rng: &mut rng,
        };
        let alloc2 = DeadlineOnly::default().allocate(&mut c2);
        let total2: u32 = alloc2.values().sum();
        assert_eq!(total2, 8, "every slot in play past the deadline");
    }

    #[test]
    fn non_finite_window_inputs_are_guarded() {
        // inf - inf = NaN in the window math; the guard must keep the
        // required rate finite and still hand out capacity.
        let rs = vec![view(0, 2, 1.0, 1.0)];
        let ix = index_of(&rs);
        let mut rng = Rng::new(2);
        let mut c = SchedCtx {
            now: f64::INFINITY,
            deadline: f64::INFINITY,
            budget_headroom: None,
            remaining_jobs: 5,
            job_work_ref_h: 1.0,
            resources: &rs,
            candidates: &ix,
            rng: &mut rng,
        };
        assert!(required_rate_jph(&c, DEADLINE_SAFETY).is_finite());
        let alloc = CostOpt::default().allocate(&mut c);
        assert_eq!(alloc.values().sum::<u32>(), 2, "{alloc:?}");
        assert!(c.hours_left().is_finite());
    }

    #[test]
    fn reservation_candidate_sets_follow_distinct_orderings() {
        // cheap-slow machine 0, dear-fast machine 1: the cost-ranked
        // prefix leads with 0, the speed-ranked prefix with 1.
        let rs = vec![view(0, 4, 1.0, 0.1), view(1, 4, 4.0, 5.0)];
        let ix = index_of(&rs);
        let sets = reservation_candidate_sets(&rs, &ix, 6, 2);
        assert_eq!(sets.len(), 2);
        assert_eq!(sets[0][0], (ResourceId(0), 4));
        assert_eq!(sets[0][1], (ResourceId(1), 2));
        assert_eq!(sets[1][0], (ResourceId(1), 4));
        // Slots never exceed the ask.
        for set in &sets {
            assert_eq!(set.iter().map(|&(_, n)| n).sum::<u32>(), 6);
        }
        // A dead grid yields no sets at all.
        let dead = vec![view(0, 0, 0.0, 0.1)];
        let ix = index_of(&dead);
        assert!(reservation_candidate_sets(&dead, &ix, 4, 3).is_empty());
    }

    #[test]
    fn down_resources_never_allocated() {
        let mut down = view(0, 8, 0.0, 0.1);
        down.planning_speed = 0.0;
        let rs = vec![down, view(1, 2, 1.0, 1.0)];
        let ix = index_of(&rs);
        for name in ["cost", "time", "conservative-time", "deadline-only"] {
            let mut rng = Rng::new(1);
            let mut c = ctx(&rs, &ix, &mut rng, 1.0, 50, None);
            let alloc = crate::broker::PolicyRegistry::with_builtins()
                .resolve(name)
                .unwrap()
                .allocate(&mut c);
            assert!(
                !alloc.contains_key(&ResourceId(0)),
                "{name} allocated a down resource"
            );
        }
    }
}
