//! The Nimrod/G resource broker — the crate's public entry point for
//! composing and running experiments.
//!
//! The paper's §2 architecture is component-based: a client hands the
//! parametric engine an experiment, a *schedule advisor* picks resources,
//! a dispatcher farms jobs out. This module is that seam in code form:
//!
//! * [`ExperimentBuilder`] (via [`Broker::experiment`]) — fluent assembly
//!   of an experiment: plan/workload, deadline, budget, policy spec,
//!   testbed, seed — finished with [`ExperimentBuilder::simulate`]
//!   (virtual time) or [`ExperimentBuilder::live`] (real PJRT execution).
//!   Compose co-scheduled tenants with [`ExperimentBuilder::tenant`] and
//!   finish with [`ExperimentBuilder::world`] /
//!   [`ExperimentBuilder::run_world`] to put N competing experiments on
//!   one shared grid ([`crate::sim::GridWorld`]), optionally with
//!   demand-responsive pricing
//!   ([`ExperimentBuilder::demand_pricing`]) and a pluggable market —
//!   posted prices by default, or periodic GRACE tender/bid auctions via
//!   [`ExperimentBuilder::grace_market`], and optionally the advance
//!   reservation subsystem (probe → reserve → commit) via
//!   [`ExperimentBuilder::reservations`];
//! * [`ScheduleAdvisor`] — the shared per-tick
//!   discovery → selection → assignment pipeline both drivers delegate to;
//! * [`PolicyRegistry`] — open, parameterized policy construction
//!   (`"cost?safety=0.9"`), extensible from outside the crate;
//! * [`scenarios`] — a catalog of named, seedable experiment presets
//!   (`gusto`, `peak-offpeak`, `flash-crowd`, `cheap-but-flaky`, …).
//!
//! ```
//! use nimrod_g::broker::Broker;
//!
//! let report = Broker::experiment()
//!     .deadline_h(20.0)
//!     .budget(2.0e6)
//!     .policy("cost?safety=0.9")
//!     .seed(42)
//!     .run()
//!     .unwrap();
//! assert_eq!(report.jobs_total, 165);
//! ```

pub mod advisor;
pub mod registry;
pub mod scenarios;

pub use advisor::{ScheduleAdvisor, TickCtx};
pub use registry::{PolicyFactory, PolicyParams, PolicyRegistry};

use crate::client::StatusBoard;
use crate::config::{ExperimentConfig, WorkloadConfig};
use crate::economy::market::{GraceConfig, MarketKind};
use crate::economy::reservation::ReservationConfig;
use crate::engine::Experiment;
use crate::grid::competition::CompetitionModel;
use crate::grid::Testbed;
use crate::metrics::{Report, WorldReport};
use crate::plan::{expand, JobSpec, Plan};
use crate::sim::live::{LiveOutcome, LiveRunner};
use crate::sim::{GridSimulation, GridWorld, TenantSetup};
use crate::types::{GridDollars, SimTime, HOUR};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Work-estimate prior for live mode: tiny, so the first tick allocates
/// jobs at all and wall-time history takes over immediately. Shared with
/// [`LiveRunner`]'s legacy construction path so both live entry points
/// plan the first tick identically.
pub const LIVE_WORK_PRIOR_H: f64 = 1e-4;

/// The broker facade. Stateless — it exists to make entry points
/// discoverable: `Broker::experiment()`, `Broker::scenario("gusto")`.
pub struct Broker;

impl Broker {
    /// Start composing an experiment from defaults (the paper-scale
    /// 165-job ionization study on the GUSTO-like testbed).
    pub fn experiment() -> ExperimentBuilder {
        ExperimentBuilder::default()
    }

    /// Start from a named scenario preset (see [`scenarios`]); every
    /// setting can still be overridden afterwards.
    pub fn scenario(name: &str) -> Result<ExperimentBuilder> {
        scenarios::builder(name)
    }
}

/// Where the job list comes from.
enum JobSource {
    /// The paper-scale 165-job ionization calibration study.
    Ionization,
    /// Plan-language source text, expanded at build time with the seed.
    Plan(String),
    /// Pre-expanded job specs.
    Specs(Vec<JobSpec>),
}

/// Where the testbed comes from (simulation drivers only).
enum TestbedSource {
    /// GUSTO-like generated testbed at a machine-count scale.
    Gusto { scale: f64 },
    /// Regular synthetic grid: `sites` × `resources_per_site` machines
    /// (see [`Testbed::synthetic`]) — for grids beyond GUSTO scale.
    Synthetic {
        sites: usize,
        resources_per_site: usize,
    },
    /// An explicit, caller-built testbed.
    Explicit(Testbed),
}

/// One additional co-scheduled tenant: its envelope/identity, job source
/// and (optionally) custom policy registry, absorbed from another builder
/// by [`ExperimentBuilder::tenant`]. Testbed/tweak/competition settings of
/// the absorbed builder are ignored — the grid belongs to the world.
struct TenantDraft {
    cfg: ExperimentConfig,
    jobs: JobSource,
    registry: Option<PolicyRegistry>,
}

/// Fluent experiment assembly. Every setter consumes and returns the
/// builder; finish with [`simulate`](Self::simulate),
/// [`run`](Self::run) or [`live`](Self::live) — or compose additional
/// tenants with [`tenant`](Self::tenant) and finish with
/// [`world`](Self::world) / [`run_world`](Self::run_world) for a
/// multi-tenant shared grid.
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
    jobs: JobSource,
    testbed: TestbedSource,
    tweaks: Vec<Box<dyn Fn(&mut Testbed) + Send + Sync>>,
    registry: Option<PolicyRegistry>,
    resume: Option<Experiment>,
    /// Co-scheduled tenants beyond the primary one this builder describes.
    tenants: Vec<TenantDraft>,
    /// Worker threads for the parallel per-tenant phase of coincident-tick
    /// batches. 1 (the default) is the proven-bit-exact sequential
    /// reference path; any other count replays the identical trace.
    threads: usize,
}

impl Default for ExperimentBuilder {
    fn default() -> Self {
        ExperimentBuilder {
            cfg: ExperimentConfig::default(),
            jobs: JobSource::Ionization,
            testbed: TestbedSource::Gusto { scale: 1.0 },
            tweaks: Vec::new(),
            registry: None,
            resume: None,
            tenants: Vec::new(),
            threads: 1,
        }
    }
}

impl ExperimentBuilder {
    // -- workload ------------------------------------------------------------

    /// Use plan-language source text (expanded with the experiment seed).
    pub fn plan(mut self, src: impl Into<String>) -> Self {
        self.jobs = JobSource::Plan(src.into());
        self
    }

    /// Use pre-expanded job specs.
    pub fn jobs(mut self, specs: Vec<JobSpec>) -> Self {
        self.jobs = JobSource::Specs(specs);
        self
    }

    /// Use the paper-scale 165-job ionization study (the default).
    pub fn ionization_study(mut self) -> Self {
        self.jobs = JobSource::Ionization;
        self
    }

    /// Resume a journal-recovered experiment: its job table (with completed
    /// work preserved) replaces the configured job source.
    pub fn resume(mut self, experiment: Experiment) -> Self {
        self.resume = Some(experiment);
        self
    }

    /// Per-job compute/I-O shape.
    pub fn workload(mut self, w: WorkloadConfig) -> Self {
        self.cfg.workload = w;
        self
    }

    // -- envelope ------------------------------------------------------------

    /// Deadline in hours (virtual hours when simulating, wall hours live).
    pub fn deadline_h(mut self, hours: f64) -> Self {
        self.cfg.deadline = hours * HOUR;
        self
    }

    /// Deadline in seconds.
    pub fn deadline_s(mut self, seconds: SimTime) -> Self {
        self.cfg.deadline = seconds;
        self
    }

    /// Budget in G$.
    pub fn budget(mut self, gd: GridDollars) -> Self {
        self.cfg.budget = Some(gd);
        self
    }

    /// Remove any budget (unconstrained spend).
    pub fn no_budget(mut self) -> Self {
        self.cfg.budget = None;
        self
    }

    // -- scheduling ----------------------------------------------------------

    /// Policy spec: a registered name, optionally with parameters —
    /// `"cost"`, `"cost?safety=0.9"`, `"fixed-rate?max-rate=2"`.
    pub fn policy(mut self, spec: &str) -> Self {
        self.cfg.policy = spec.to_string();
        self
    }

    /// Resolve policies against a custom registry (for out-of-crate
    /// policies) instead of the built-ins.
    pub fn registry(mut self, registry: PolicyRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Scheduler tick period, seconds.
    pub fn tick_period_s(mut self, seconds: f64) -> Self {
        self.cfg.tick_period_s = seconds;
        self
    }

    /// Dispatch attempts per job before it is marked failed.
    pub fn max_attempts(mut self, n: u32) -> Self {
        self.cfg.max_attempts = n;
        self
    }

    // -- identity / environment ----------------------------------------------

    /// Grid identity the experiment runs as.
    pub fn user(mut self, user: &str) -> Self {
        self.cfg.user = user.to_string();
        self
    }

    /// Master RNG seed (fixes testbed, workload jitter, churn, policy RNG).
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// UTC hour-of-day at experiment start (drives time-of-day pricing).
    pub fn start_utc_hour(mut self, hour: f64) -> Self {
        self.cfg.start_utc_hour = hour;
        self
    }

    /// Background competing-experiment process.
    pub fn competition(mut self, model: CompetitionModel) -> Self {
        self.cfg.competition = Some(model);
        self
    }

    /// Remove background competition (the default).
    pub fn no_competition(mut self) -> Self {
        self.cfg.competition = None;
        self
    }

    // -- market --------------------------------------------------------------

    /// Select the market mechanism the world prices resources through.
    /// World-level like [`competition`](Self::competition): in a
    /// multi-tenant world only tenant 0's (the outer builder's) setting is
    /// honoured. The default, [`MarketKind::PostedPrice`], replays
    /// bit-exactly with pre-market traces.
    pub fn market(mut self, market: MarketKind) -> Self {
        self.cfg.market = market;
        self
    }

    /// Run the economy through periodic GRACE tender/bid auctions (paper
    /// §7): at every directory refresh each tenant tenders its remaining
    /// work, owners bid on real utilization, and awards become time-limited
    /// price agreements the scheduler and billing both honour. Shorthand
    /// for [`market`](Self::market) with
    /// [`MarketKind::GraceAuction`].
    pub fn grace_market(self, cfg: GraceConfig) -> Self {
        self.market(MarketKind::GraceAuction(cfg))
    }

    /// Enable the advance-reservation subsystem (probe → reserve → commit
    /// with shadow-schedule costing; see [`crate::economy::reservation`]).
    /// World-level like [`market`](Self::market): in a multi-tenant world
    /// only tenant 0's (the outer builder's) setting is honoured, and every
    /// deadline-driven tenant may reserve ahead. Worlds without this knob
    /// replay bit-exactly with pre-reservation traces.
    pub fn reservations(mut self, cfg: ReservationConfig) -> Self {
        self.cfg.reservations = Some(cfg);
        self
    }

    /// Remove the reservation subsystem (the default).
    pub fn no_reservations(mut self) -> Self {
        self.cfg.reservations = None;
        self
    }

    // -- multi-tenant composition ----------------------------------------

    /// Add a co-scheduled tenant: a whole second experiment (own user,
    /// deadline, budget, policy, workload, journal) competing on **this**
    /// builder's grid. The absorbed builder contributes its envelope and
    /// job source; its testbed, tweaks and competition settings are
    /// ignored. A tenant left on the default seed inherits this builder's
    /// seed (the world seed), so `…seed(s)…run_world()` reseeds the whole
    /// contest. Finish with [`world`](Self::world) or
    /// [`run_world`](Self::run_world).
    pub fn tenant(mut self, other: ExperimentBuilder) -> Self {
        self.tenants.push(TenantDraft {
            cfg: other.cfg,
            jobs: other.jobs,
            registry: other.registry,
        });
        self
    }

    /// Number of tenants the finished world will host (primary included).
    pub fn tenant_count(&self) -> usize {
        1 + self.tenants.len()
    }

    /// Worker threads for the parallel per-tenant phase of the world's
    /// coincident-tick batches (see the three-phase pipeline in
    /// [`crate::sim::GridWorld`]'s module docs). The default of 1 runs the
    /// identical pipeline sequentially and is the reference path; traces
    /// are bit-exact at every count, so this is purely a throughput knob.
    /// Validated by [`world`](Self::world): 0 is an error, and a count
    /// above the tenant total is clamped (with a warning) — extra workers
    /// would only ever idle. Simulation-only, like
    /// [`reservations`](Self::reservations): [`live`](Self::live) refuses
    /// `threads > 1`.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Enable demand-responsive pricing on every resource: owners multiply
    /// their posted rate by `1 + slope × utilization`, where utilization is
    /// the fraction of the machine's CPUs held by tenants' in-flight jobs
    /// plus background competition claims. This is what makes co-tenant
    /// demand move prices (paper §3) without the synthetic competition
    /// process.
    pub fn demand_pricing(self, slope: f64) -> Self {
        self.tweak_testbed(move |tb| {
            for spec in &mut tb.resources {
                spec.price.demand_slope = slope;
            }
        })
    }

    // -- testbed -------------------------------------------------------------

    /// Use an explicit testbed instead of the generated GUSTO one.
    pub fn testbed(mut self, tb: Testbed) -> Self {
        self.testbed = TestbedSource::Explicit(tb);
        self
    }

    /// Scale the generated GUSTO testbed's machine count (1.0 ≈ 70
    /// machines).
    pub fn testbed_scale(mut self, scale: f64) -> Self {
        self.testbed = TestbedSource::Gusto { scale };
        self
    }

    /// Use a generated synthetic grid of `sites` × `resources_per_site`
    /// machines (see [`Testbed::synthetic`]): regular shape, open
    /// authorization, scales to tens of thousands of machines. Seeded from
    /// the experiment seed, so one scenario still yields a family of
    /// trials.
    pub fn synthetic_testbed(
        mut self,
        sites: usize,
        resources_per_site: usize,
    ) -> Self {
        self.testbed = TestbedSource::Synthetic {
            sites,
            resources_per_site,
        };
        self
    }

    /// Apply a transformation to the testbed after generation (scenario
    /// presets use this for e.g. failure-prone or discounted grids).
    pub fn tweak_testbed(
        mut self,
        f: impl Fn(&mut Testbed) + Send + Sync + 'static,
    ) -> Self {
        self.tweaks.push(Box::new(f));
        self
    }

    // -- introspection -------------------------------------------------------

    /// The experiment configuration assembled so far.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    // -- finishers -----------------------------------------------------------

    /// Validate one tenant's envelope and resolve its policy spec into an
    /// advisor (the per-tenant half of builder validation).
    fn validated_advisor(
        cfg: &ExperimentConfig,
        registry: Option<&PolicyRegistry>,
        work_prior_h: f64,
    ) -> Result<ScheduleAdvisor> {
        ensure!(
            cfg.deadline.is_finite() && cfg.deadline > 0.0,
            "deadline must be positive, got {} s",
            cfg.deadline
        );
        ensure!(
            cfg.tick_period_s.is_finite() && cfg.tick_period_s > 0.0,
            "tick period must be positive, got {} s",
            cfg.tick_period_s
        );
        ensure!(cfg.max_attempts >= 1, "max_attempts must be at least 1");
        if let Some(b) = cfg.budget {
            ensure!(
                b.is_finite() && b > 0.0,
                "budget must be positive, got {b} G$ (use no_budget() for unlimited)"
            );
        }
        ensure!(
            (0.0..24.0).contains(&cfg.start_utc_hour),
            "start_utc_hour must be in [0, 24), got {}",
            cfg.start_utc_hour
        );
        let policy = match registry {
            Some(reg) => reg.resolve(&cfg.policy)?,
            None => PolicyRegistry::with_builtins().resolve(&cfg.policy)?,
        };
        Ok(ScheduleAdvisor::new(policy, work_prior_h))
    }

    /// Validate the (world-level) testbed source and market selection.
    fn validate_testbed(&self) -> Result<()> {
        if let TestbedSource::Gusto { scale } = &self.testbed {
            let scale = *scale;
            ensure!(
                scale.is_finite() && scale > 0.0,
                "testbed scale must be positive, got {scale}"
            );
        }
        if let TestbedSource::Synthetic {
            sites,
            resources_per_site,
        } = &self.testbed
        {
            ensure!(
                *sites >= 1 && *resources_per_site >= 1,
                "synthetic testbed needs at least one site and one machine per site, got {sites}×{resources_per_site}"
            );
        }
        self.cfg.market.validate().context("market")?;
        if let Some(r) = &self.cfg.reservations {
            r.validate().context("reservations")?;
        }
        Ok(())
    }

    /// Validate settings and resolve the primary policy spec into an
    /// advisor.
    fn advisor(&self, work_prior_h: f64) -> Result<ScheduleAdvisor> {
        self.validate_testbed()?;
        Self::validated_advisor(&self.cfg, self.registry.as_ref(), work_prior_h)
    }

    /// Expand one tenant's job source with its seed.
    fn expand_specs(jobs: &JobSource, seed: u64) -> Result<Vec<JobSpec>> {
        let specs = match jobs {
            JobSource::Ionization => crate::workload::ionization_jobs(seed),
            JobSource::Plan(src) => {
                let plan = Plan::parse(src).context("parse experiment plan")?;
                expand(&plan, seed).context("expand experiment plan")?
            }
            JobSource::Specs(specs) => specs.clone(),
        };
        ensure!(!specs.is_empty(), "experiment has no jobs");
        Ok(specs)
    }

    /// Expand the primary job source.
    fn specs(&self) -> Result<Vec<JobSpec>> {
        Self::expand_specs(&self.jobs, self.cfg.seed)
    }

    /// Build the testbed (generated or explicit) with tweaks applied.
    fn build_testbed(&self) -> Testbed {
        let mut tb = match &self.testbed {
            // Same seed derivation as the legacy `gusto_ionization` path so
            // builder runs replay identically at equal seeds.
            TestbedSource::Gusto { scale } => {
                Testbed::gusto(self.cfg.seed ^ 0x6057, *scale)
            }
            TestbedSource::Synthetic {
                sites,
                resources_per_site,
            } => Testbed::synthetic(
                *sites,
                *resources_per_site,
                self.cfg.seed ^ 0x9E6A,
            ),
            TestbedSource::Explicit(tb) => tb.clone(),
        };
        for tweak in &self.tweaks {
            tweak(&mut tb);
        }
        tb
    }

    /// Finish as a (single-tenant) virtual-time simulation driver.
    pub fn simulate(mut self) -> Result<GridSimulation> {
        ensure!(
            self.tenants.is_empty(),
            "builder has {} tenants: finish multi-tenant experiments with world()/run_world()",
            self.tenant_count()
        );
        ensure!(
            self.threads <= 1,
            "threads({}) needs the world() driver — a single-tenant simulation never coalesces a multi-member tick batch, so extra workers would be a silent no-op",
            self.threads
        );
        let advisor = self.advisor(self.cfg.workload.job_work_ref_h)?;
        let resume = self.resume.take();
        // A resumed experiment carries its own job table.
        let specs = if resume.is_some() { Vec::new() } else { self.specs()? };
        let tb = self.build_testbed();
        let sim = GridSimulation::with_advisor(tb, specs, self.cfg, advisor);
        Ok(match resume {
            Some(exp) => sim.with_experiment(exp),
            None => sim,
        })
    }

    /// Convenience: simulate to completion and return the report.
    pub fn run(self) -> Result<Report> {
        Ok(self.simulate()?.run())
    }

    /// Finish as a multi-tenant shared-grid world: this builder's
    /// experiment is tenant 0 and every [`tenant`](Self::tenant) rides
    /// along on the same testbed, event queue and economy. Works for
    /// N = 1 too (a world with a single tenant is exactly
    /// [`simulate`](Self::simulate)'s driver).
    pub fn world(mut self) -> Result<GridWorld> {
        ensure!(
            self.resume.is_none(),
            "resume() is only supported by the single-tenant simulate() driver"
        );
        ensure!(
            self.threads >= 1,
            "threads(0) would leave the parallel tick phase with no workers — use threads(1) for the sequential reference path"
        );
        let threads = if self.threads > self.tenant_count() {
            // One warning per world, naming the count actually used —
            // the worker pool is sized once per world from this value.
            eprintln!(
                "warning: threads({}) exceeds the {} tenant(s) — clamping to {} worker(s) (a batch never has more members than tenants, so extra workers would only idle)",
                self.threads,
                self.tenant_count(),
                self.tenant_count()
            );
            self.tenant_count()
        } else {
            self.threads
        };
        self.validate_testbed()?;
        let default_seed = ExperimentConfig::default().seed;
        let mut setups = Vec::with_capacity(self.tenant_count());
        let advisor = Self::validated_advisor(
            &self.cfg,
            self.registry.as_ref(),
            self.cfg.workload.job_work_ref_h,
        )
        .context("tenant 0")?;
        setups.push(TenantSetup {
            specs: self.specs().context("tenant 0")?,
            cfg: self.cfg.clone(),
            advisor,
        });
        for (i, draft) in self.tenants.drain(..).enumerate() {
            let TenantDraft {
                mut cfg,
                jobs,
                registry,
            } = draft;
            // Tenants that kept the default seed inherit the world seed, so
            // reseeding the outer builder reseeds the whole contest.
            if cfg.seed == default_seed {
                cfg.seed = self.cfg.seed;
            }
            let advisor = Self::validated_advisor(
                &cfg,
                registry.as_ref(),
                cfg.workload.job_work_ref_h,
            )
            .with_context(|| format!("tenant {}", i + 1))?;
            let specs = Self::expand_specs(&jobs, cfg.seed)
                .with_context(|| format!("tenant {}", i + 1))?;
            setups.push(TenantSetup { cfg, specs, advisor });
        }
        let tb = self.build_testbed();
        let mut world = GridWorld::new(tb, setups);
        world.set_threads(threads);
        Ok(world)
    }

    /// Convenience: run the multi-tenant world to completion and return
    /// the per-tenant + cross-tenant report.
    pub fn run_world(self) -> Result<WorldReport> {
        Ok(self.world()?.run_world())
    }

    /// Finish as a live (real PJRT execution) experiment on `workers`
    /// worker threads under `workdir`. The deadline/budget envelope applies
    /// on the wall clock.
    pub fn live(self, workers: usize, workdir: &Path) -> Result<LiveExperiment> {
        ensure!(workers >= 1, "live mode needs at least one worker");
        ensure!(
            self.resume.is_none(),
            "resume() is only supported by the simulation driver"
        );
        ensure!(
            self.tenants.is_empty(),
            "multi-tenant brokering is simulation-only (use world()/run_world())"
        );
        ensure!(
            self.cfg.market == MarketKind::PostedPrice,
            "GRACE auction markets are simulation-only (the live driver has no shared-grid economy)"
        );
        ensure!(
            self.cfg.reservations.is_none(),
            "advance reservations are simulation-only (the live driver has no shared-grid economy)"
        );
        ensure!(
            self.threads <= 1,
            "threads() is simulation-only (the batched tick is a world concept; live parallelism is the `workers` argument)"
        );
        let advisor = self.advisor(LIVE_WORK_PRIOR_H)?;
        let specs = self.specs()?;
        let runner =
            LiveRunner::new(workers, self.cfg, workdir).with_advisor(advisor);
        Ok(LiveExperiment { runner, specs })
    }
}

/// A fully-assembled live experiment: a configured [`LiveRunner`] plus the
/// jobs it will execute. Produced by [`ExperimentBuilder::live`].
pub struct LiveExperiment {
    runner: LiveRunner,
    specs: Vec<JobSpec>,
}

impl LiveExperiment {
    /// Attach a status board shared with a
    /// [`crate::client::StatusServer`].
    pub fn with_board(mut self, board: Arc<StatusBoard>) -> Self {
        self.runner = self.runner.with_board(board);
        self
    }

    /// Number of jobs the experiment will run.
    pub fn job_count(&self) -> usize {
        self.specs.len()
    }

    /// Execute to completion on real PJRT workers.
    pub fn run(self) -> Result<LiveOutcome> {
        self.runner.run(self.specs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_match_config_defaults() {
        let b = Broker::experiment();
        let d = ExperimentConfig::default();
        assert_eq!(b.config().policy, d.policy);
        assert_eq!(b.config().seed, d.seed);
        assert_eq!(b.config().deadline, d.deadline);
        assert_eq!(b.config().budget, None);
    }

    #[test]
    fn builder_validation_rejects_nonsense() {
        assert!(Broker::experiment().deadline_h(-1.0).simulate().is_err());
        assert!(Broker::experiment().budget(0.0).simulate().is_err());
        assert!(Broker::experiment().policy("nope").simulate().is_err());
        assert!(Broker::experiment()
            .policy("cost?bogus=1")
            .simulate()
            .is_err());
        assert!(Broker::experiment().tick_period_s(0.0).simulate().is_err());
        assert!(Broker::experiment().max_attempts(0).simulate().is_err());
        assert!(Broker::experiment().testbed_scale(0.0).simulate().is_err());
        assert!(Broker::experiment().start_utc_hour(24.5).simulate().is_err());
        assert!(Broker::experiment().jobs(Vec::new()).simulate().is_err());
    }

    #[test]
    fn tenant_composition_validates_and_counts() {
        let b = Broker::experiment()
            .tenant(Broker::experiment().user("davida").policy("time"))
            .tenant(Broker::experiment().user("astro").policy("deadline-only"));
        assert_eq!(b.tenant_count(), 3);
        // Multi-tenant builders refuse the single-tenant finishers...
        assert!(Broker::experiment()
            .tenant(Broker::experiment())
            .simulate()
            .is_err());
        // ...and tenant validation errors surface with the tenant index.
        let err = Broker::experiment()
            .tenant(Broker::experiment().policy("nope"))
            .world()
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("tenant 1"), "{err:#}");
        assert!(Broker::experiment()
            .tenant(Broker::experiment().deadline_h(-4.0))
            .world()
            .is_err());
        // A single-tenant world is fine.
        assert!(Broker::experiment().world().is_ok());
    }

    #[test]
    fn market_selection_validates_and_defaults_posted() {
        assert_eq!(
            Broker::experiment().config().market,
            MarketKind::PostedPrice
        );
        // Grace market flows into the config and validates its tuning.
        let b = Broker::experiment().grace_market(GraceConfig::default());
        assert!(matches!(b.config().market, MarketKind::GraceAuction(_)));
        assert!(Broker::experiment()
            .grace_market(GraceConfig {
                escalation: 0.5,
                ..GraceConfig::default()
            })
            .world()
            .is_err());
        assert!(Broker::experiment()
            .grace_market(GraceConfig {
                agreement_ttl_s: -1.0,
                ..GraceConfig::default()
            })
            .simulate()
            .is_err());
        // The live driver has no shared-grid economy to auction over.
        assert!(Broker::experiment()
            .grace_market(GraceConfig::default())
            .live(1, std::path::Path::new("/tmp/nimrod-live-test"))
            .is_err());
    }

    #[test]
    fn reservation_selection_validates_and_defaults_off() {
        assert!(Broker::experiment().config().reservations.is_none());
        let b = Broker::experiment().reservations(ReservationConfig::default());
        assert!(b.config().reservations.is_some());
        assert!(b.no_reservations().config().reservations.is_none());
        // Bad tuning is rejected with the reservations context...
        let err = Broker::experiment()
            .reservations(ReservationConfig {
                cancel_penalty: 2.0,
                ..ReservationConfig::default()
            })
            .world()
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("reservations"), "{err:#}");
        assert!(Broker::experiment()
            .reservations(ReservationConfig {
                commit_timeout_s: -5.0,
                ..ReservationConfig::default()
            })
            .simulate()
            .is_err());
        // ...and the live driver refuses reservation configs outright.
        assert!(Broker::experiment()
            .reservations(ReservationConfig::default())
            .live(1, std::path::Path::new("/tmp/nimrod-live-test"))
            .is_err());
    }

    #[test]
    fn thread_selection_validates_and_clamps() {
        // Default is the sequential reference path.
        assert_eq!(Broker::experiment().world().unwrap().threads(), 1);
        // 0 workers is a config error, surfaced by world().
        let err = Broker::experiment()
            .threads(0)
            .world()
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("threads"), "{err:#}");
        // A sensible count flows through to the world...
        let world = Broker::experiment()
            .tenant(Broker::experiment().user("davida"))
            .tenant(Broker::experiment().user("astro"))
            .threads(3)
            .world()
            .unwrap();
        assert_eq!(world.threads(), 3);
        // ...and a count beyond the tenant total clamps (with a warning).
        let world = Broker::experiment()
            .tenant(Broker::experiment().user("davida"))
            .threads(8)
            .world()
            .unwrap();
        assert_eq!(world.threads(), 2);
        // The live driver refuses parallel ticks outright, like
        // reservations — simulation-only machinery.
        assert!(Broker::experiment()
            .threads(4)
            .live(1, std::path::Path::new("/tmp/nimrod-live-test"))
            .is_err());
        assert!(Broker::experiment()
            .threads(1)
            .world()
            .is_ok());
    }

    #[test]
    fn tenants_inherit_world_seed_unless_set() {
        let world = Broker::experiment()
            .seed(77)
            .tenant(Broker::experiment().user("davida"))
            .tenant(Broker::experiment().user("astro").seed(5))
            .world()
            .unwrap();
        assert_eq!(world.tenant_cfg(0).seed, 77);
        assert_eq!(world.tenant_cfg(1).seed, 77, "default seed inherits");
        assert_eq!(world.tenant_cfg(2).seed, 5, "explicit seed sticks");
    }

    #[test]
    fn small_builder_run_completes() {
        let report = Broker::experiment()
            .plan(
                "parameter v float range from 100 to 1000 step 300\n\
                 task main\nexecute icc -v $v\nendtask",
            )
            .deadline_h(20.0)
            .policy("cost")
            .testbed_scale(0.3)
            .seed(7)
            .run()
            .unwrap();
        assert_eq!(report.jobs_total, 4);
        assert_eq!(report.jobs_completed + report.jobs_failed, 4);
    }
}
