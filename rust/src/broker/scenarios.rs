//! Named, seedable experiment presets.
//!
//! Each scenario composes testbed, dynamics, competition and envelope
//! settings into a reproducible starting point; everything is still
//! overridable on the returned builder (in particular
//! [`crate::broker::ExperimentBuilder::seed`], so one scenario yields a
//! whole family of trials). Run from the CLI with
//! `nimrod run --scenario <name>`, list with `nimrod scenarios`.
//!
//! Multi-tenant presets (`contested-gusto`, `auction-rush`) compose extra
//! tenants via [`crate::broker::ExperimentBuilder::tenant`]; finish them
//! with `run_world()` (the CLI does this automatically when a scenario has
//! more than one tenant).

use super::{Broker, ExperimentBuilder};
use crate::config::WorkloadConfig;
use crate::economy::market::GraceConfig;
use crate::economy::reservation::ReservationConfig;
use crate::grid::competition::CompetitionModel;
use anyhow::{bail, Result};

/// One catalog entry.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

/// The preset catalog.
pub const CATALOG: [ScenarioInfo; 14] = [
    ScenarioInfo {
        name: "gusto",
        summary: "the paper's Figure-3 trial: 165-job ionization study, \
                  ~70-machine GUSTO testbed, 15 h deadline, cost-optimizing DBC",
    },
    ScenarioInfo {
        name: "peak-offpeak",
        summary: "same study launched at the US owners' business peak \
                  (15:00 UTC): time-of-day pricing forces the cost \
                  optimizer to route around peak-priced sites",
    },
    ScenarioInfo {
        name: "flash-crowd",
        summary: "a busy grid: competing experiments arrive every ~30 min, \
                  claiming CPUs and triggering demand premiums (paper §3)",
    },
    ScenarioInfo {
        name: "cheap-but-flaky",
        summary: "every machine is half price but fails every ~2 h; \
                  time-optimizing with 8 retry attempts rides out the churn",
    },
    ScenarioInfo {
        name: "tight-budget",
        summary: "a binding 0.5 MG$ budget: the cost optimizer trades the \
                  deadline for staying inside the envelope",
    },
    ScenarioInfo {
        name: "global-scale",
        summary: "4x-GUSTO testbed (~280 machines) under a tight 10 h \
                  deadline with the time-optimizing scheduler",
    },
    ScenarioInfo {
        name: "mega-grid",
        summary: "scale stress: 5,400-machine synthetic grid (120 sites), \
                  50,000-job sweep, time-optimizing DBC — exercises the \
                  incremental O(changed) tick pipeline",
    },
    ScenarioInfo {
        name: "contested-gusto",
        summary: "multi-tenant: cost- vs time- vs deadline-only brokers \
                  race their own 165-job studies on ONE shared GUSTO grid \
                  — real contention, not the synthetic Poisson load \
                  (finish with run --scenario or run_world())",
    },
    ScenarioInfo {
        name: "auction-rush",
        summary: "multi-tenant: 8 brokers with staggered 6-20 h deadlines \
                  pile onto a demand-priced grid — owners reprice with \
                  utilization, so every tenant's demand moves everyone's \
                  quotes",
    },
    ScenarioInfo {
        name: "grace-auction",
        summary: "GRACE market (paper §7): 3 tenants tender their remaining \
                  work at every directory refresh, owners bid on real \
                  utilization, and awards become time-limited price \
                  agreements DBC schedules and settles against",
    },
    ScenarioInfo {
        name: "grace-rush",
        summary: "GRACE at rush hour: the 8-tenant staggered-deadline crowd \
                  of auction-rush, but bidding through the tender/bid \
                  market instead of taking posted demand prices",
    },
    ScenarioInfo {
        name: "reserve-ahead",
        summary: "advance reservations: 3 tenants on a contested, \
                  demand-priced GUSTO grid; near their deadlines brokers \
                  shadow-price several candidate resource sets, commit the \
                  cheapest as a binding hold (cancellation penalty) and \
                  dispatch into the reserved slots at locked rates",
    },
    ScenarioInfo {
        name: "index-storm",
        summary: "candidate-index stress: 4 tenants on a 10,000-machine \
                  synthetic grid with heavy churn and demand repricing — \
                  the dirty-view firehose where per-tick full sorts are \
                  worst and incremental re-keying must stay O(changed)",
    },
    ScenarioInfo {
        name: "world-storm",
        summary: "tenant-population stress: 256 small brokers share one \
                  demand-priced 128-machine grid on a common tick period, \
                  so every tick is a 256-member batch — the parallel-tick \
                  worker pool's worst case (pair with run --threads N)",
    },
];

/// Names of all presets, in catalog order.
pub fn names() -> Vec<&'static str> {
    CATALOG.iter().map(|s| s.name).collect()
}

/// Catalog entry for `name`, if it exists.
pub fn describe(name: &str) -> Option<&'static ScenarioInfo> {
    CATALOG.iter().find(|s| s.name == name)
}

/// A builder pre-configured for the named scenario.
pub fn builder(name: &str) -> Result<ExperimentBuilder> {
    let b = Broker::experiment();
    Ok(match name {
        // Defaults *are* the paper trial; spelled out for readability.
        "gusto" => b.ionization_study().deadline_h(15.0).policy("cost"),
        "peak-offpeak" => b.deadline_h(15.0).policy("cost").start_utc_hour(15.0),
        "flash-crowd" => b.deadline_h(20.0).policy("cost").competition(
            CompetitionModel {
                mean_interarrival_s: 1800.0,
                mean_duration_s: 4.0 * 3600.0,
                mean_cpus: 60.0,
            },
        ),
        "cheap-but-flaky" => b
            .deadline_h(40.0)
            .policy("time")
            .max_attempts(8)
            .tweak_testbed(|tb| {
                for spec in &mut tb.resources {
                    spec.price.base_rate *= 0.5;
                    spec.mtbf_s = 2.0 * 3600.0;
                    spec.mttr_s = 0.5 * 3600.0;
                }
            }),
        "tight-budget" => b.deadline_h(15.0).policy("cost").budget(5.0e5),
        "global-scale" => b.deadline_h(10.0).policy("time").testbed_scale(4.0),
        // Far beyond GUSTO: the paper's architecture at the scale the
        // ROADMAP asks for. Light jobs, long tick, huge open grid — the
        // incremental view table is what keeps this tractable.
        "mega-grid" => b
            .plan(
                "parameter point integer range from 1 to 50000\n\
                 task main\nexecute chamber -p $point\nendtask",
            )
            .synthetic_testbed(120, 45)
            .deadline_h(12.0)
            .policy("time")
            .tick_period_s(300.0)
            .workload(WorkloadConfig {
                job_work_ref_h: 0.25,
                ..WorkloadConfig::default()
            }),
        // Three brokers, three policies, one grid: contention is real
        // co-scheduled demand, and realized cost/makespan diverge by
        // policy (the acceptance experiment for GridWorld).
        "contested-gusto" => b
            .ionization_study()
            .deadline_h(15.0)
            .policy("cost")
            .user("rajkumar")
            .tenant(
                Broker::experiment()
                    .ionization_study()
                    .deadline_h(10.0)
                    .policy("time")
                    .user("davida"),
            )
            .tenant(
                Broker::experiment()
                    .ionization_study()
                    .deadline_h(12.0)
                    .policy("deadline-only")
                    .user("john"),
            ),
        // Eight brokers with staggered deadlines rushing a demand-priced
        // grid: owners reprice with utilization (demand_slope), so each
        // arrival raises everyone's quotes — the companion economy paper's
        // "cost changes as competing experiments are put on the grid",
        // driven by real tenants instead of a Poisson process.
        "auction-rush" => {
            let rush_plan = "parameter point integer range from 1 to 48\n\
                             task main\nexecute chamber -p $point\nendtask";
            let policies =
                ["time", "cost", "deadline-only", "conservative-time"];
            let mut b = b
                .plan(rush_plan)
                .deadline_h(6.0)
                .policy("time")
                .user("trader0")
                .demand_pricing(0.8);
            for k in 1..8usize {
                b = b.tenant(
                    Broker::experiment()
                        .plan(rush_plan)
                        .deadline_h(6.0 + 2.0 * k as f64)
                        .policy(policies[k % policies.len()])
                        .user(&format!("trader{k}")),
                );
            }
            b
        }
        // The §7 economy end to end: three brokers tender their remaining
        // work at every MDS refresh, per-owner bid servers quote on real
        // utilization (demand slope 0.6), and awards become time-limited
        // price agreements that override posted rates for the winner —
        // WorldReport carries the clearing-price trajectory and per-tenant
        // award shares.
        "grace-auction" => b
            .ionization_study()
            .deadline_h(15.0)
            .policy("cost")
            .user("rajkumar")
            .demand_pricing(0.6)
            .grace_market(GraceConfig::default())
            .tenant(
                Broker::experiment()
                    .ionization_study()
                    .deadline_h(10.0)
                    .policy("time")
                    .user("davida"),
            )
            .tenant(
                Broker::experiment()
                    .ionization_study()
                    .deadline_h(12.0)
                    .policy("deadline-only")
                    .user("john"),
            ),
        // auction-rush's staggered 8-tenant crowd, bidding instead of
        // taking posted demand prices: the multi-tenant stress case for the
        // market layer.
        "grace-rush" => {
            let rush_plan = "parameter point integer range from 1 to 48\n\
                             task main\nexecute chamber -p $point\nendtask";
            let policies =
                ["time", "cost", "deadline-only", "conservative-time"];
            let mut b = b
                .plan(rush_plan)
                .deadline_h(6.0)
                .policy("time")
                .user("trader0")
                .demand_pricing(0.8)
                .grace_market(GraceConfig::default());
            for k in 1..8usize {
                b = b.tenant(
                    Broker::experiment()
                        .plan(rush_plan)
                        .deadline_h(6.0 + 2.0 * k as f64)
                        .policy(policies[k % policies.len()])
                        .user(&format!("trader{k}")),
                );
            }
            b
        }
        // The reservation subsystem end to end: three brokers on one
        // demand-priced, contested GUSTO grid. Once a tenant is past 40 %
        // of its deadline with work still undispatched, it shadow-prices
        // several candidate resource sets off its live views, commits the
        // cheapest feasible one as a binding hold (free-cancelling the
        // runner-up) and dispatches into the held slots at the locked
        // rate — capacity assurance the posted-price and GRACE economies
        // cannot give.
        "reserve-ahead" => b
            .ionization_study()
            .deadline_h(15.0)
            .policy("cost")
            .user("rajkumar")
            .budget(2.0e6)
            .demand_pricing(0.6)
            .competition(CompetitionModel {
                mean_interarrival_s: 2400.0,
                mean_duration_s: 3.0 * 3600.0,
                mean_cpus: 40.0,
            })
            .reservations(ReservationConfig::default())
            .tenant(
                Broker::experiment()
                    .ionization_study()
                    .deadline_h(10.0)
                    .policy("time")
                    .user("davida")
                    .budget(2.0e6),
            )
            .tenant(
                Broker::experiment()
                    .ionization_study()
                    .deadline_h(12.0)
                    .policy("deadline-only")
                    .user("john")
                    .budget(2.0e6),
            ),
        // The allocation-scaling stress case: a 10,000-machine open grid
        // whose views churn constantly (2.5 h MTBF availability churn plus
        // demand repricing on every occupancy move), shared by four
        // brokers. Full per-tick sorts pay 4 × 10,000 log 10,000 here;
        // the candidate index re-keys only the dirtied entries — this is
        // the preset the grid_scaling bench and CI smoke lean on to keep
        // that property honest.
        "index-storm" => {
            let storm_plan = "parameter point integer range from 1 to 600\n\
                              task main\nexecute chamber -p $point\nendtask";
            let light = WorkloadConfig {
                job_work_ref_h: 0.25,
                ..WorkloadConfig::default()
            };
            let policies = ["time", "cost", "deadline-only"];
            let mut b = b
                .plan(storm_plan)
                .workload(light.clone())
                .synthetic_testbed(100, 100)
                .deadline_h(8.0)
                .policy("cost")
                .user("storm0")
                .tick_period_s(300.0)
                .demand_pricing(0.7)
                .tweak_testbed(|tb| {
                    for spec in &mut tb.resources {
                        spec.mtbf_s = 2.5 * 3600.0;
                        spec.mttr_s = 0.5 * 3600.0;
                    }
                });
            for k in 1..4usize {
                b = b.tenant(
                    Broker::experiment()
                        .plan(storm_plan)
                        .workload(light.clone())
                        .deadline_h(8.0 + 2.0 * k as f64)
                        .policy(policies[k - 1])
                        .user(&format!("storm{k}")),
                );
            }
            b
        }
        // The tenant-population stress case: 256 small brokers (the id
        // space's full width) on one modest demand-priced grid, all on the
        // same tick period so every tick coalesces into a 256-member
        // batch. Where index-storm stresses per-tenant view volume, this
        // stresses batch *width* — snapshot fan-out, pool scatter and the
        // ordered merge barrier — which is exactly what the thread sweep
        // and `parallel_equivalence.rs` replay it for.
        "world-storm" => {
            let swarm_plan = "parameter point integer range from 1 to 6\n\
                              task main\nexecute chamber -p $point\nendtask";
            let light = WorkloadConfig {
                job_work_ref_h: 0.25,
                ..WorkloadConfig::default()
            };
            let policies = ["time", "cost", "deadline-only", "conservative-time"];
            let mut b = b
                .plan(swarm_plan)
                .workload(light.clone())
                .synthetic_testbed(8, 16)
                .deadline_h(8.0)
                .policy("cost")
                .user("swarm0")
                .tick_period_s(600.0)
                .demand_pricing(0.7);
            for k in 1..256usize {
                b = b.tenant(
                    Broker::experiment()
                        .plan(swarm_plan)
                        .workload(light.clone())
                        .deadline_h(8.0 + (k % 4) as f64)
                        .policy(policies[k % policies.len()])
                        .user(&format!("swarm{k}"))
                        // Same period as tenant 0: every tick stays one
                        // world-wide batch instead of fragmenting.
                        .tick_period_s(600.0),
                );
            }
            b
        }
        other => bail!(
            "unknown scenario `{other}` (available: {})",
            names().join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_and_builder_agree() {
        for info in &CATALOG {
            assert!(
                builder(info.name).is_ok(),
                "catalog entry `{}` has no builder",
                info.name
            );
        }
        assert!(builder("does-not-exist").is_err());
    }

    #[test]
    fn scenarios_stay_seedable() {
        let a = builder("gusto").unwrap().seed(9).config().seed;
        assert_eq!(a, 9);
    }

    #[test]
    fn multi_tenant_presets_compose_tenants() {
        assert_eq!(builder("contested-gusto").unwrap().tenant_count(), 3);
        assert_eq!(builder("auction-rush").unwrap().tenant_count(), 8);
        assert_eq!(builder("grace-auction").unwrap().tenant_count(), 3);
        assert_eq!(builder("grace-rush").unwrap().tenant_count(), 8);
        assert_eq!(builder("reserve-ahead").unwrap().tenant_count(), 3);
        assert_eq!(builder("index-storm").unwrap().tenant_count(), 4);
        // The id space's full width — GridWorld::new accepts exactly 256.
        assert_eq!(builder("world-storm").unwrap().tenant_count(), 256);
        assert_eq!(builder("gusto").unwrap().tenant_count(), 1);
    }

    #[test]
    fn grace_presets_select_the_auction_market() {
        use crate::economy::market::MarketKind;
        for name in ["grace-auction", "grace-rush"] {
            let b = builder(name).unwrap();
            assert!(
                matches!(b.config().market, MarketKind::GraceAuction(_)),
                "{name} must run the GRACE market"
            );
        }
        assert_eq!(
            builder("gusto").unwrap().config().market,
            MarketKind::PostedPrice
        );
    }

    #[test]
    fn reserve_ahead_preset_enables_reservations() {
        let b = builder("reserve-ahead").unwrap();
        assert!(b.config().reservations.is_some());
        // Reservations are world-level: off everywhere else.
        for name in ["gusto", "grace-auction", "index-storm", "world-storm"] {
            assert!(
                builder(name).unwrap().config().reservations.is_none(),
                "{name} must not reserve"
            );
        }
    }
}
