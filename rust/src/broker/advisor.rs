//! The shared schedule-advisor component (paper §2, Figure 1).
//!
//! Both experiment drivers — virtual-time [`crate::sim::GridSimulation`]
//! and real-execution [`crate::sim::live::LiveRunner`] — used to hand-wire
//! the same per-tick pipeline: estimate per-job work, build a
//! [`SchedCtx`], run the [`Policy`], and reconcile through
//! [`crate::dispatcher::plan_actions`]. [`ScheduleAdvisor`] owns that
//! pipeline (policy + historical rate estimator + work prior) so the
//! drivers only assemble their driver-specific [`ResourceView`]s and apply
//! the returned [`Action`]s.

use crate::dispatcher::{plan_actions, Action};
use crate::engine::Experiment;
use crate::scheduler::{
    CandidateIndex, Policy, RateEstimator, ResourceView, SchedCtx,
};
use crate::types::{GridDollars, ResourceId, SimTime};
use crate::util::rng::Rng;
use anyhow::Result;

/// Driver-agnostic inputs for one scheduling tick. The views carry
/// everything discovery produced (MDS capability, GRAM slots, economy
/// quotes); the candidate index carries the ranked orderings the driver
/// maintains over those views (see [`crate::scheduler::index`]);
/// experiment state is read from the engine directly.
#[derive(Debug)]
pub struct TickCtx<'a> {
    /// Current time (virtual seconds or wall seconds since start).
    pub now: SimTime,
    /// Experiment deadline on the same clock.
    pub deadline: SimTime,
    /// Remaining budget headroom from the ledger (None = unlimited).
    pub budget_headroom: Option<GridDollars>,
    /// Discovered resources, one view per schedulable machine.
    pub views: &'a [ResourceView],
    /// Ranked orderings over `views` — the driver must keep this in
    /// lockstep with the view table (every rebuilt entry goes through
    /// [`CandidateIndex::update`]).
    pub candidates: &'a CandidateIndex,
}

/// The schedule advisor: the pluggable selection component plus the
/// historical information it learns from (job consumption rates, per-job
/// work). Constructed from a policy spec via [`ScheduleAdvisor::resolve`]
/// or handed a custom [`Policy`] with [`ScheduleAdvisor::new`].
pub struct ScheduleAdvisor {
    policy: Box<dyn Policy>,
    estimator: RateEstimator,
    /// Prior for per-job work (reference CPU-hours) before history exists.
    work_prior_h: f64,
}

impl ScheduleAdvisor {
    /// Wrap an already-constructed policy.
    pub fn new(policy: Box<dyn Policy>, work_prior_h: f64) -> ScheduleAdvisor {
        ScheduleAdvisor {
            policy,
            estimator: RateEstimator::default(),
            work_prior_h,
        }
    }

    /// Resolve a `name?key=value` policy spec against the built-in
    /// registry.
    pub fn resolve(spec: &str, work_prior_h: f64) -> Result<ScheduleAdvisor> {
        let policy = super::PolicyRegistry::with_builtins().resolve(spec)?;
        Ok(ScheduleAdvisor::new(policy, work_prior_h))
    }

    /// Name of the active policy.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// The learned historical information.
    pub fn estimator(&self) -> &RateEstimator {
        &self.estimator
    }

    /// Current per-job work estimate (reference CPU-hours): measured EWMA
    /// if history exists, else the prior.
    pub fn job_work_ref_h(&self) -> f64 {
        self.estimator.job_work_ref_h(self.work_prior_h)
    }

    /// Update the work prior (live mode recalibrates from wall time).
    pub fn set_work_prior_h(&mut self, prior: f64) {
        self.work_prior_h = prior;
    }

    /// Measured jobs/hour/slot for a resource, if history exists.
    pub fn measured_jphps(&self, rid: ResourceId) -> Option<f64> {
        self.estimator.measured_jphps(rid)
    }

    /// Feed back a completion (service wall seconds + measured work).
    pub fn observe_complete(
        &mut self,
        rid: ResourceId,
        service_s: SimTime,
        work_ref_h: f64,
    ) {
        self.estimator.on_complete(rid, service_s, work_ref_h);
    }

    /// Feed back a failure.
    pub fn observe_failure(&mut self, rid: ResourceId) {
        self.estimator.on_failure(rid);
    }

    /// One scheduling tick: selection (policy allocation over the views)
    /// followed by assignment planning (dispatcher reconciliation). Returns
    /// the submit/cancel actions the driver must apply.
    pub fn advise(
        &mut self,
        tick: TickCtx<'_>,
        exp: &Experiment,
        rng: &mut Rng,
    ) -> Vec<Action> {
        let job_work = self.job_work_ref_h();
        let alloc = {
            let mut ctx = SchedCtx {
                now: tick.now,
                deadline: tick.deadline,
                budget_headroom: tick.budget_headroom,
                remaining_jobs: exp.remaining(),
                job_work_ref_h: job_work,
                resources: tick.views,
                candidates: tick.candidates,
                rng,
            };
            self.policy.allocate(&mut ctx)
        };
        plan_actions(&alloc, exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{expand, Plan};
    use crate::types::HOUR;

    fn experiment(n: usize) -> Experiment {
        let src = format!(
            "parameter i integer range from 1 to {n}\ntask main\nexecute run $i\nendtask"
        );
        let specs = expand(&Plan::parse(&src).unwrap(), 0).unwrap();
        Experiment::new(specs, 10.0 * HOUR, None, "u", 3)
    }

    fn view(id: u32, slots: u32) -> ResourceView {
        ResourceView {
            id: ResourceId(id),
            slots,
            planning_speed: 1.0,
            rate: 1.0,
            in_flight: 0,
            measured_jphps: None,
            batch_queue: false,
        }
    }

    #[test]
    fn advise_produces_submissions_for_idle_grid() {
        let exp = experiment(6);
        let mut adv = ScheduleAdvisor::resolve("time", 1.0).unwrap();
        let views = vec![view(0, 4), view(1, 4)];
        let candidates = CandidateIndex::from_views(&views);
        let mut rng = Rng::new(1);
        let actions = adv.advise(
            TickCtx {
                now: 0.0,
                deadline: 10.0 * HOUR,
                budget_headroom: None,
                views: &views,
                candidates: &candidates,
            },
            &exp,
            &mut rng,
        );
        let submits = actions
            .iter()
            .filter(|a| matches!(a, Action::Submit { .. }))
            .count();
        assert_eq!(submits, 6, "{actions:?}");
    }

    #[test]
    fn engine_in_flight_counters_track_transitions() {
        // Drivers read per-resource in-flight counts straight off the
        // engine's incremental counters; they must track transitions.
        let mut exp = experiment(4);
        exp.dispatch(crate::types::JobId(0), ResourceId(1), 0.0).unwrap();
        exp.dispatch(crate::types::JobId(1), ResourceId(1), 0.0).unwrap();
        exp.dispatch(crate::types::JobId(2), ResourceId(0), 0.0).unwrap();
        exp.start(crate::types::JobId(2), 1.0).unwrap();
        assert_eq!(exp.in_flight_on(ResourceId(0)), 1);
        assert_eq!(exp.in_flight_on(ResourceId(1)), 2);
        assert_eq!(exp.in_flight_on(ResourceId(2)), 0);
    }

    #[test]
    fn work_estimate_prefers_history() {
        let mut adv = ScheduleAdvisor::resolve("cost", 2.0).unwrap();
        assert!((adv.job_work_ref_h() - 2.0).abs() < 1e-12);
        adv.observe_complete(ResourceId(0), 1800.0, 0.5);
        assert!((adv.job_work_ref_h() - 0.5).abs() < 1e-12);
        assert!(adv.measured_jphps(ResourceId(0)).is_some());
    }
}
