//! Open policy registry with parameterized construction.
//!
//! Replaces the closed `match` (and the long-gone `scheduler::by_name`
//! shim) that policy construction used to run through: policies are looked
//! up by name in a registry that out-of-crate code can extend with
//! [`PolicyRegistry::register`], and each factory receives the parameters
//! parsed from a `name?key=value&key2=value2` spec, so tunables like the
//! cost-optimizer's deadline safety factor can be set per experiment
//! without recompiling:
//!
//! ```
//! use nimrod_g::broker::PolicyRegistry;
//! let reg = PolicyRegistry::with_builtins();
//! assert!(reg.resolve("cost?safety=0.9").is_ok());
//! assert!(reg.resolve("cost?typo=1").is_err()); // unknown keys are errors
//! ```
//!
//! Unknown policy names and unknown (or malformed) parameter keys are hard
//! errors — a typo must never silently fall back to defaults.

use crate::scheduler::{baselines, dbc, Policy, DEADLINE_SAFETY};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Parameters parsed from the query part of a policy spec. Factories *take*
/// the keys they understand; [`PolicyRegistry::resolve`] rejects the spec
/// if any key is left over.
#[derive(Debug, Default)]
pub struct PolicyParams {
    map: BTreeMap<String, String>,
}

impl PolicyParams {
    /// Parse a `key=value&key2=value2` query string (empty is fine).
    pub fn parse(query: &str) -> Result<PolicyParams> {
        let mut map = BTreeMap::new();
        for part in query.split('&').filter(|p| !p.is_empty()) {
            let Some((key, value)) = part.split_once('=') else {
                bail!("policy parameter `{part}` must be key=value");
            };
            ensure!(!key.is_empty(), "policy parameter `{part}` has an empty key");
            if map.insert(key.to_string(), value.to_string()).is_some() {
                bail!("duplicate policy parameter `{key}`");
            }
        }
        Ok(PolicyParams { map })
    }

    /// Remove and return a raw parameter value.
    pub fn take(&mut self, key: &str) -> Option<String> {
        self.map.remove(key)
    }

    /// Remove and parse a float parameter.
    pub fn take_f64(&mut self, key: &str) -> Result<Option<f64>> {
        match self.map.remove(key) {
            None => Ok(None),
            Some(v) => {
                let parsed = v
                    .parse::<f64>()
                    .ok()
                    .filter(|x| x.is_finite())
                    .with_context(|| format!("parameter `{key}={v}` is not a number"))?;
                Ok(Some(parsed))
            }
        }
    }

    /// Keys no factory has consumed.
    pub fn remaining_keys(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// A policy constructor: receives the parsed parameters, takes the ones it
/// understands, returns the policy.
pub type PolicyFactory =
    Box<dyn Fn(&mut PolicyParams) -> Result<Box<dyn Policy>> + Send + Sync>;

/// Name → factory table. The single source of policy construction (the
/// deprecated `scheduler::by_name` shim that used to wrap it is removed).
pub struct PolicyRegistry {
    factories: BTreeMap<String, PolicyFactory>,
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        PolicyRegistry::with_builtins()
    }
}

impl PolicyRegistry {
    /// A registry with no entries (for fully custom policy sets).
    pub fn empty() -> PolicyRegistry {
        PolicyRegistry {
            factories: BTreeMap::new(),
        }
    }

    /// A registry pre-loaded with the eight in-tree policies
    /// ([`crate::scheduler::ALL_POLICIES`]).
    pub fn with_builtins() -> PolicyRegistry {
        let mut reg = PolicyRegistry::empty();
        reg.register("cost", |p| {
            let safety = p.take_f64("safety")?.unwrap_or(DEADLINE_SAFETY);
            ensure!(
                safety > 0.0 && safety <= 1.0,
                "cost: safety must be in (0, 1], got {safety}"
            );
            Ok(Box::new(dbc::CostOpt { safety }))
        });
        reg.register("time", |_| Ok(Box::new(dbc::TimeOpt)));
        reg.register("conservative-time", |_| Ok(Box::new(dbc::ConservativeTime)));
        reg.register("deadline-only", |p| {
            let safety = p.take_f64("safety")?.unwrap_or(DEADLINE_SAFETY);
            ensure!(
                safety > 0.0 && safety <= 1.0,
                "deadline-only: safety must be in (0, 1], got {safety}"
            );
            Ok(Box::new(dbc::DeadlineOnly { safety }))
        });
        reg.register("round-robin", |_| {
            Ok(Box::new(baselines::RoundRobin::default()))
        });
        reg.register("random", |_| Ok(Box::new(baselines::RandomPick)));
        reg.register("perf", |_| Ok(Box::new(baselines::PerfOnly)));
        reg.register("fixed-rate", |p| {
            let max_rate = p.take_f64("max-rate")?.unwrap_or(1.0);
            ensure!(
                max_rate > 0.0,
                "fixed-rate: max-rate must be positive, got {max_rate}"
            );
            Ok(Box::new(baselines::FixedRate { max_rate }))
        });
        reg
    }

    /// Register (or replace) a policy factory under `name`.
    pub fn register<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&mut PolicyParams) -> Result<Box<dyn Policy>> + Send + Sync + 'static,
    {
        self.factories.insert(name.to_string(), Box::new(factory));
    }

    /// True if `name` (without parameters) is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains_key(name)
    }

    /// Registered policy names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.factories.keys().map(String::as_str).collect()
    }

    /// Construct a policy from a `name` or `name?key=value&...` spec.
    pub fn resolve(&self, spec: &str) -> Result<Box<dyn Policy>> {
        let (name, query) = match spec.split_once('?') {
            Some((n, q)) => (n, q),
            None => (spec, ""),
        };
        ensure!(!name.is_empty(), "empty policy name in spec `{spec}`");
        let Some(factory) = self.factories.get(name) else {
            bail!(
                "unknown policy `{name}` (registered: {})",
                self.names().join(", ")
            );
        };
        let mut params = PolicyParams::parse(query)?;
        let policy = factory(&mut params)
            .with_context(|| format!("constructing policy `{name}`"))?;
        if !params.is_empty() {
            bail!(
                "policy `{name}` does not understand parameter(s): {}",
                params.remaining_keys().join(", ")
            );
        }
        Ok(policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::ALL_POLICIES;

    #[test]
    fn builtins_cover_all_policies() {
        let reg = PolicyRegistry::with_builtins();
        for name in ALL_POLICIES {
            let p = reg
                .resolve(name)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(p.name(), name);
        }
        assert_eq!(reg.names().len(), ALL_POLICIES.len());
    }

    #[test]
    fn parameterized_spec_parses() {
        let reg = PolicyRegistry::with_builtins();
        assert!(reg.resolve("cost?safety=0.9").is_ok());
        assert!(reg.resolve("fixed-rate?max-rate=2.5").is_ok());
        assert!(reg.resolve("cost?").is_ok(), "empty query is allowed");
    }

    #[test]
    fn unknown_names_and_keys_rejected() {
        let reg = PolicyRegistry::with_builtins();
        assert!(reg.resolve("nope").is_err());
        assert!(reg.resolve("cost?nope=1").is_err());
        assert!(reg.resolve("time?safety=0.9").is_err(), "time takes no params");
        assert!(reg.resolve("cost?safety=high").is_err(), "non-numeric value");
        assert!(reg.resolve("cost?safety=0.9&safety=0.8").is_err(), "duplicate");
        assert!(reg.resolve("cost?safety").is_err(), "missing =value");
        assert!(reg.resolve("cost?safety=2.0").is_err(), "out of range");
        assert!(reg.resolve("").is_err(), "empty spec");
    }

    #[test]
    fn params_take_semantics() {
        let mut p = PolicyParams::parse("a=1&b=x").unwrap();
        assert_eq!(p.take_f64("a").unwrap(), Some(1.0));
        assert_eq!(p.take("b").as_deref(), Some("x"));
        assert!(p.is_empty());
        assert_eq!(p.take_f64("a").unwrap(), None);
    }
}
