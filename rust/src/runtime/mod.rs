//! The PJRT runtime bridge: load AOT HLO-text artifacts and execute them
//! from the Rust request path (Python is never involved at runtime).
//!
//! `make artifacts` lowers the L2 JAX chamber model (with its L1 Pallas
//! kernels inlined) to `artifacts/*.hlo.txt` plus a `manifest.json`
//! describing shapes and carrying golden probe outputs. [`ChamberRuntime`]
//! compiles the artifacts once on a PJRT CPU client;
//! [`ChamberRuntime::run`] executes a batch of job parameters, padding the
//! tail batch as needed.
//!
//! Two interchange gotchas (see DESIGN.md and python/compile/aot.py):
//! * HLO **text**, not serialized protos — jax ≥ 0.5 emits 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects;
//! * the DST matrix and eigenvalue grid arrive as **runtime inputs** read
//!   from raw f32 files — the HLO text printer elides large constants
//!   (`constant({...})`), which the 0.5.1 text parser reads back as zeros.

use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Result of one chamber-model evaluation (one job).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChamberOutput {
    /// Collected charge (the calibration observable).
    pub response: f32,
    /// Total deposited dose.
    pub dose: f32,
}

/// One compiled artifact.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// The chamber-model runtime: a PJRT CPU client plus the compiled batch and
/// batch-1 executables and the constant operand data.
pub struct ChamberRuntime {
    _client: xla::PjRtClient,
    batched: Compiled,
    single: Option<Compiled>,
    grid_n: usize,
    dst: Vec<f32>,
    lam: Vec<f32>,
    /// Golden probe from the manifest: (params, response, dose).
    golden: Option<(Vec<[f32; 3]>, Vec<f32>, Vec<f32>)>,
    /// Executions performed (metrics).
    pub executions: std::cell::Cell<u64>,
}

impl ChamberRuntime {
    /// Locate the artifacts directory: `$NIMROD_ARTIFACTS`, else
    /// `./artifacts`, else `../artifacts`.
    pub fn default_artifact_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("NIMROD_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// Load and compile the artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<ChamberRuntime> {
        let manifest_path = dir.join("manifest.json");
        let manifest_src = std::fs::read_to_string(&manifest_path).with_context(
            || {
                format!(
                    "read {} (run `make artifacts` first)",
                    manifest_path.display()
                )
            },
        )?;
        let manifest = parse(&manifest_src).context("parse manifest.json")?;
        if manifest.req_str("format")? != "hlo-text" {
            bail!("unsupported artifact format");
        }
        let grid_n = manifest.req_f64("grid_n")? as usize;
        let dst = read_f32_file(&dir.join("dst_matrix.f32"), grid_n * grid_n)?;
        let lam = read_f32_file(&dir.join("laplacian.f32"), grid_n * grid_n)?;

        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let arts = manifest.get("artifacts");
        let batched = Self::compile_one(&client, dir, arts, "chamber.hlo.txt")
            .context("compile chamber.hlo.txt")?;
        // The batch-1 variant is optional (latency path).
        let single =
            Self::compile_one(&client, dir, arts, "chamber_b1.hlo.txt").ok();

        let golden = Self::parse_golden(manifest.get("golden"));

        Ok(ChamberRuntime {
            _client: client,
            batched,
            single,
            grid_n,
            dst,
            lam,
            golden,
            executions: std::cell::Cell::new(0),
        })
    }

    fn parse_golden(g: &Json) -> Option<(Vec<[f32; 3]>, Vec<f32>, Vec<f32>)> {
        let params: Vec<[f32; 3]> = g
            .get("params")
            .as_arr()?
            .iter()
            .filter_map(|row| {
                let r = row.as_arr()?;
                Some([
                    r.first()?.as_f64()? as f32,
                    r.get(1)?.as_f64()? as f32,
                    r.get(2)?.as_f64()? as f32,
                ])
            })
            .collect();
        let vecf = |key: &str| -> Option<Vec<f32>> {
            Some(
                g.get(key)
                    .as_arr()?
                    .iter()
                    .filter_map(|x| x.as_f64().map(|v| v as f32))
                    .collect(),
            )
        };
        Some((params, vecf("response")?, vecf("dose")?))
    }

    fn compile_one(
        client: &xla::PjRtClient,
        dir: &Path,
        arts: &Json,
        name: &str,
    ) -> Result<Compiled> {
        let meta = arts.get(name);
        let batch = meta.req_f64("batch")? as usize;
        let path = dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(Compiled { exe, batch })
    }

    /// Batch size of the main executable.
    pub fn batch_size(&self) -> usize {
        self.batched.batch
    }

    /// Golden-parity check: run the manifest's probe batch and compare
    /// against the jax-computed outputs. Returns the max abs error.
    pub fn verify_golden(&self) -> Result<f32> {
        let Some((params, want_r, want_d)) = self.golden.clone() else {
            bail!("manifest has no golden probe");
        };
        let got = self.run(&params)?;
        let mut max_err = 0f32;
        for (g, (wr, wd)) in got.iter().zip(want_r.iter().zip(&want_d)) {
            max_err = max_err.max((g.response - wr).abs());
            max_err = max_err.max((g.dose - wd).abs());
        }
        Ok(max_err)
    }

    /// Evaluate the chamber model for each `[voltage, pressure, energy]`
    /// row. Inputs are chunked to the artifact batch size; the tail chunk is
    /// padded (padding rows are discarded). Uses the batch-1 executable for
    /// single jobs when available.
    pub fn run(&self, params: &[[f32; 3]]) -> Result<Vec<ChamberOutput>> {
        let mut out = Vec::with_capacity(params.len());
        if params.is_empty() {
            return Ok(out);
        }
        let mut i = 0;
        while i < params.len() {
            let left = params.len() - i;
            let (c, take) = match (&self.single, left) {
                (Some(s), 1) => (s, 1),
                _ => (&self.batched, left.min(self.batched.batch)),
            };
            let chunk = &params[i..i + take];
            let results = self.run_chunk(c, chunk)?;
            out.extend(results);
            i += take;
        }
        Ok(out)
    }

    fn run_chunk(
        &self,
        c: &Compiled,
        chunk: &[[f32; 3]],
    ) -> Result<Vec<ChamberOutput>> {
        debug_assert!(chunk.len() <= c.batch);
        // Pad to the executable's fixed batch.
        let mut flat = Vec::with_capacity(c.batch * 3);
        for row in chunk {
            flat.extend_from_slice(row);
        }
        for _ in chunk.len()..c.batch {
            // Benign mid-range padding values.
            flat.extend_from_slice(&[400.0, 1.0, 10.0]);
        }
        let n = self.grid_n as i64;
        let params_lit = xla::Literal::vec1(&flat).reshape(&[c.batch as i64, 3])?;
        let dst_lit = xla::Literal::vec1(&self.dst).reshape(&[n, n])?;
        let lam_lit = xla::Literal::vec1(&self.lam).reshape(&[n, n])?;
        let result = c
            .exe
            .execute::<xla::Literal>(&[params_lit, dst_lit, lam_lit])?[0][0]
            .to_literal_sync()?;
        self.executions.set(self.executions.get() + 1);
        // jax lowering used return_tuple=True with two outputs.
        let (response, dose) = result.to_tuple2()?;
        let response = response.to_vec::<f32>()?;
        let dose = dose.to_vec::<f32>()?;
        if response.len() < chunk.len() || dose.len() < chunk.len() {
            bail!(
                "artifact returned {} outputs for batch {}",
                response.len(),
                chunk.len()
            );
        }
        Ok(chunk
            .iter()
            .enumerate()
            .map(|(k, _)| ChamberOutput {
                response: response[k],
                dose: dose[k],
            })
            .collect())
    }
}

/// Read a raw little-endian f32 file, checking the element count.
fn read_f32_file(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read {}", path.display()))?;
    if bytes.len() != expect * 4 {
        bail!(
            "{}: expected {} f32s, found {} bytes",
            path.display(),
            expect,
            bytes.len()
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<ChamberRuntime> {
        let dir = ChamberRuntime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: artifacts not built");
            return None;
        }
        Some(ChamberRuntime::load(&dir).expect("load artifacts"))
    }

    #[test]
    fn golden_parity_with_jax() {
        let Some(rt) = runtime() else { return };
        let err = rt.verify_golden().expect("golden probe present");
        assert!(err < 1e-3, "rust-vs-jax divergence {err}");
    }

    #[test]
    fn executes_full_batch() {
        let Some(rt) = runtime() else { return };
        let b = rt.batch_size();
        let params: Vec<[f32; 3]> = (0..b)
            .map(|i| [200.0 + 50.0 * i as f32, 1.0, 5.0 + i as f32])
            .collect();
        let out = rt.run(&params).unwrap();
        assert_eq!(out.len(), b);
        for o in &out {
            assert!(o.response.is_finite() && o.response > 0.0, "{o:?}");
            assert!(o.dose >= o.response - 1e-3, "eta <= 1 ⇒ response <= dose");
        }
    }

    #[test]
    fn tail_padding_discarded() {
        let Some(rt) = runtime() else { return };
        let b = rt.batch_size();
        let params: Vec<[f32; 3]> = (0..b + 3)
            .map(|i| [300.0, 0.8 + 0.05 * i as f32, 10.0])
            .collect();
        let out = rt.run(&params).unwrap();
        assert_eq!(out.len(), b + 3);
    }

    #[test]
    fn single_job_uses_b1_and_matches_batch() {
        let Some(rt) = runtime() else { return };
        let p = [[500.0f32, 1.2, 8.0]];
        let single = rt.run(&p).unwrap()[0];
        // Same parameters inside a full batch give the same numbers.
        let b = rt.batch_size();
        let batch: Vec<[f32; 3]> = std::iter::repeat(p[0]).take(b).collect();
        let batched = rt.run(&batch).unwrap()[0];
        assert!((single.response - batched.response).abs() < 1e-4);
        assert!((single.dose - batched.dose).abs() < 1e-4);
    }

    #[test]
    fn physics_monotonicity_voltage() {
        let Some(rt) = runtime() else { return };
        let out = rt
            .run(&[[150.0, 1.0, 10.0], [900.0, 1.0, 10.0]])
            .unwrap();
        assert!(
            out[1].response > out[0].response,
            "higher voltage must collect more charge: {out:?}"
        );
    }

    #[test]
    fn empty_input_ok() {
        let Some(rt) = runtime() else { return };
        assert!(rt.run(&[]).unwrap().is_empty());
    }
}
