//! The job-wrapper (paper §2): "responsible for staging of application
//! tasks and data; starting execution of the task on the assigned resource
//! and sending results back to the parametric engine via dispatcher".
//!
//! In the live (real-execution) driver each simulated node gets a working
//! directory; the wrapper interprets the job's staged script op by op:
//! `copy` ops move real files between the experiment root store and the
//! node directory, and `execute` runs the AOT-compiled chamber model via
//! PJRT with the job's parameter bindings, writing a real results file for
//! stage-out.

use crate::plan::{JobSpec, TaskOp};
use crate::runtime::{ChamberOutput, ChamberRuntime};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Outcome of one wrapped job.
#[derive(Debug, Clone)]
pub struct WrapperResult {
    pub output: ChamberOutput,
    /// Bytes staged in + out (real file sizes).
    pub bytes_staged: u64,
}

/// A job-wrapper bound to one node directory.
pub struct JobWrapper {
    /// Experiment root storage (the GASS server's backing directory).
    pub root_store: PathBuf,
    /// The node's scratch directory.
    pub node_dir: PathBuf,
}

impl JobWrapper {
    pub fn new(root_store: &Path, node_dir: &Path) -> Result<JobWrapper> {
        std::fs::create_dir_all(root_store)?;
        std::fs::create_dir_all(node_dir)?;
        Ok(JobWrapper {
            root_store: root_store.to_path_buf(),
            node_dir: node_dir.to_path_buf(),
        })
    }

    fn resolve(&self, path: &str) -> PathBuf {
        match path.strip_prefix("node:") {
            Some(rest) => self.node_dir.join(rest),
            None => self.root_store.join(path),
        }
    }

    /// Interpret the job's script. The chamber parameters come from the
    /// job's bindings (`voltage`, `pressure`, `energy`).
    pub fn run(&self, job: &JobSpec, rt: &ChamberRuntime) -> Result<WrapperResult> {
        let mut bytes_staged = 0u64;
        let mut output = None;
        for op in &job.script {
            match op {
                TaskOp::Copy { from, to } => {
                    let src = self.resolve(from);
                    let dst = self.resolve(to);
                    if let Some(parent) = dst.parent() {
                        std::fs::create_dir_all(parent)?;
                    }
                    // Missing declared inputs are created empty (config
                    // files the sweep does not actually populate).
                    if !src.exists() && !from.starts_with("node:") {
                        std::fs::write(&src, b"")?;
                    }
                    let n = std::fs::copy(&src, &dst).with_context(|| {
                        format!("copy {} -> {}", src.display(), dst.display())
                    })?;
                    bytes_staged += n;
                }
                TaskOp::Execute { command } => {
                    let v = job
                        .f64_binding("voltage")
                        .context("job missing `voltage` binding")?;
                    let p = job
                        .f64_binding("pressure")
                        .context("job missing `pressure` binding")?;
                    let e = job
                        .f64_binding("energy")
                        .context("job missing `energy` binding")?;
                    let got = rt.run(&[[v as f32, p as f32, e as f32]])?;
                    let o = got[0];
                    // Produce the results file named in the command's -o
                    // flag (default results.dat) so stage-out is real.
                    let results_name = command
                        .split_whitespace()
                        .skip_while(|w| *w != "-o")
                        .nth(1)
                        .unwrap_or("results.dat");
                    let results = self.node_dir.join(results_name);
                    std::fs::write(
                        &results,
                        format!(
                            "{{\"job\":\"{}\",\"voltage\":{v},\"pressure\":{p},\"energy\":{e},\"response\":{},\"dose\":{}}}\n",
                            job.id, o.response, o.dose
                        ),
                    )?;
                    output = Some(o);
                }
            }
        }
        match output {
            Some(output) => Ok(WrapperResult {
                output,
                bytes_staged,
            }),
            None => bail!("job {} script has no execute op", job.id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ionization_jobs;

    #[test]
    fn wrapper_runs_full_script_end_to_end() {
        let dir = ChamberRuntime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping wrapper test: artifacts not built");
            return;
        }
        let rt = ChamberRuntime::load(&dir).unwrap();
        let tmp = std::env::temp_dir().join(format!("nimrod-w-{}", std::process::id()));
        let root = tmp.join("root");
        let node = tmp.join("node0");
        let w = JobWrapper::new(&root, &node).unwrap();

        let job = &ionization_jobs(3)[7];
        let res = w.run(job, &rt).unwrap();
        assert!(res.output.response > 0.0);
        // Stage-out produced the per-job results file in root storage.
        let out_file = root.join(format!("results.{}.dat", job.id));
        let contents = std::fs::read_to_string(&out_file).unwrap();
        assert!(contents.contains("\"response\":"));
        assert!(res.bytes_staged > 0);
        std::fs::remove_dir_all(&tmp).ok();
    }
}
