//! The dispatcher (paper §2): turns the schedule advisor's allocation into
//! GRAM actions, and hosts the job-wrapper logic shared by the simulated
//! and live execution paths.
//!
//! [`plan_actions`] is pure: given the allocation targets, the engine's job
//! table and per-resource in-flight counts, it emits the submissions and
//! cancellations that reconcile reality with the plan. Cancellation only
//! targets still-queued jobs — running jobs are never pre-empted (matching
//! Nimrod/G, which migrates unstarted jobs when it adapts its resource set).

pub mod wrapper;

use crate::engine::Experiment;
use crate::scheduler::Allocation;
use crate::types::{JobId, ResourceId, SimTime};

/// One reconciliation step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Dispatch a Ready job to a resource.
    Submit { job: JobId, rid: ResourceId },
    /// Withdraw a Dispatched-but-not-Running job from a resource (it goes
    /// back to Ready and will be re-dispatched elsewhere).
    CancelQueued { job: JobId, rid: ResourceId },
}

/// Reconcile in-flight state with the allocation. In-flight counts and
/// queued-job lists come from the engine's incrementally-maintained
/// rollups, so the cost is O(allocation + affected jobs) — no job-table
/// scan (the naive scan is O(resources × jobs) and used to dominate the
/// tick at scale).
pub fn plan_actions(alloc: &Allocation, exp: &Experiment) -> Vec<Action> {
    let mut actions = Vec::new();

    let mut over_allocated: Vec<(ResourceId, u32)> = Vec::new(); // (rid, excess)
    let mut capacity_gap: Vec<(ResourceId, u32)> = Vec::new(); // (rid, free)
    for (&rid, &target) in alloc {
        let current = exp.in_flight_on(rid);
        if current > target {
            over_allocated.push((rid, current - target));
        } else if current < target {
            capacity_gap.push((rid, target - current));
        }
    }
    // Resources with queued jobs but no allocation at all: drain them.
    for rid in exp.resources_with_queued() {
        if !alloc.contains_key(&rid) {
            for (_, job) in exp.queued_on(rid) {
                actions.push(Action::CancelQueued { job, rid });
            }
        }
    }

    // Cancel the excess on over-allocated resources, youngest dispatch
    // first (most likely still deep in the queue).
    for (rid, excess) in over_allocated {
        let mut q: Vec<(SimTime, JobId)> = exp.queued_on(rid).collect();
        q.sort_by(|a, b| b.0.total_cmp(&a.0));
        for (_, job) in q.into_iter().take(excess as usize) {
            actions.push(Action::CancelQueued { job, rid });
        }
    }

    // Fill gaps with Ready jobs in id order.
    let mut ready = exp.ready_jobs();
    'outer: for (rid, free) in capacity_gap {
        for _ in 0..free {
            match ready.next() {
                Some(job) => actions.push(Action::Submit { job, rid }),
                None => break 'outer,
            }
        }
    }
    actions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{expand, Plan};

    fn exp(n: usize) -> Experiment {
        let src = format!(
            "parameter i integer range from 1 to {n}\ntask main\nexecute run $i\nendtask"
        );
        let specs = expand(&Plan::parse(&src).unwrap(), 0).unwrap();
        Experiment::new(specs, 3600.0, None, "u", 3)
    }

    fn alloc(pairs: &[(u32, u32)]) -> Allocation {
        pairs.iter().map(|&(r, n)| (ResourceId(r), n)).collect()
    }

    #[test]
    fn fills_capacity_in_job_order() {
        let e = exp(5);
        let actions = plan_actions(&alloc(&[(0, 2), (1, 1)]), &e);
        assert_eq!(
            actions,
            vec![
                Action::Submit {
                    job: JobId(0),
                    rid: ResourceId(0)
                },
                Action::Submit {
                    job: JobId(1),
                    rid: ResourceId(0)
                },
                Action::Submit {
                    job: JobId(2),
                    rid: ResourceId(1)
                },
            ]
        );
    }

    #[test]
    fn respects_existing_in_flight() {
        let mut e = exp(5);
        e.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
        e.dispatch(JobId(1), ResourceId(0), 0.0).unwrap();
        let actions = plan_actions(&alloc(&[(0, 2)]), &e);
        assert!(actions.is_empty(), "{actions:?}");
    }

    #[test]
    fn cancels_excess_queued_newest_first() {
        let mut e = exp(5);
        e.dispatch(JobId(0), ResourceId(0), 1.0).unwrap();
        e.dispatch(JobId(1), ResourceId(0), 2.0).unwrap();
        e.dispatch(JobId(2), ResourceId(0), 3.0).unwrap();
        // j0 is already running — must never be cancelled.
        e.start(JobId(0), 5.0).unwrap();
        let actions = plan_actions(&alloc(&[(0, 1)]), &e);
        assert_eq!(
            actions,
            vec![
                Action::CancelQueued {
                    job: JobId(2),
                    rid: ResourceId(0)
                },
                Action::CancelQueued {
                    job: JobId(1),
                    rid: ResourceId(0)
                },
            ]
        );
    }

    #[test]
    fn drains_unallocated_resources() {
        let mut e = exp(3);
        e.dispatch(JobId(0), ResourceId(9), 0.0).unwrap();
        e.start(JobId(0), 1.0).unwrap(); // running: stays
        e.dispatch(JobId(1), ResourceId(9), 2.0).unwrap(); // queued: drained
        let actions = plan_actions(&alloc(&[(1, 1)]), &e);
        assert!(actions.contains(&Action::CancelQueued {
            job: JobId(1),
            rid: ResourceId(9)
        }));
        // The running job is untouched and the gap on r1 is filled.
        assert!(actions.contains(&Action::Submit {
            job: JobId(2),
            rid: ResourceId(1)
        }));
        assert_eq!(actions.len(), 2);
    }

    #[test]
    fn no_ready_jobs_no_submissions() {
        let mut e = exp(1);
        e.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
        let actions = plan_actions(&alloc(&[(1, 4)]), &e);
        // r0 lost its allocation, so its queued job is drained — but there
        // are no Ready jobs, so no submissions are planned for r1.
        assert_eq!(
            actions,
            vec![Action::CancelQueued {
                job: JobId(0),
                rid: ResourceId(0)
            }]
        );
    }
}
