//! Virtual time and the discrete-event queue.
//!
//! The grid simulator advances a virtual clock event-by-event, so a 20-hour
//! GUSTO experiment replays in milliseconds while preserving event ordering.
//! Determinism rules:
//!
//! * event times are `f64` seconds compared with `total_cmp`;
//! * ties break on a monotone sequence number (FIFO among simultaneous
//!   events), so two runs with the same seed produce identical traces.

use crate::types::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual instant.
#[derive(Debug)]
struct Scheduled<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Discrete-event queue with a virtual clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute virtual time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let time = if at < self.now { self.now } else { at };
        self.heap.push(Scheduled {
            time,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after a delay from the current instant.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|s| {
            debug_assert!(s.time >= self.now, "time went backwards");
            self.now = s.time;
            self.processed += 1;
            (s.time, s.event)
        })
    }

    /// Peek at the next event time without advancing.
    pub fn next_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.time)
    }

    /// Peek at the next event (time + payload) without popping it — what
    /// lets a driver coalesce consecutive simultaneous events into one
    /// batch while preserving FIFO order for everything else.
    pub fn peek(&self) -> Option<(SimTime, &E)> {
        self.heap.peek().map(|s| (s.time, &s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, 1);
        q.schedule_at(2.0, 2);
        q.schedule_at(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, ());
        q.schedule_at(4.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 1.0);
        // Scheduling "in the past" clamps to now.
        q.schedule_at(0.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        q.pop();
        assert_eq!(q.now(), 4.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "first");
        q.pop();
        q.schedule_in(5.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.peek(), Some((2.0, &"a")));
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.processed(), 0);
        q.pop();
        // FIFO among simultaneous events survives the peek.
        assert_eq!(q.peek(), Some((2.0, &"b")));
        q.pop();
        assert_eq!(q.peek(), None);
    }

    #[test]
    fn counts() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(i as f64, i);
        }
        assert_eq!(q.pending(), 10);
        q.pop();
        q.pop();
        assert_eq!(q.processed(), 2);
        assert_eq!(q.pending(), 8);
    }
}
