//! Live (real-execution) driver: the full Nimrod/G stack with **actual
//! compute** on the request path.
//!
//! Where [`super::GridSimulation`] advances virtual time, the live runner
//! spawns one OS thread per simulated grid node; each node's job-wrapper
//! stages real files and executes the AOT-compiled chamber model through
//! PJRT ([`crate::runtime::ChamberRuntime`]). The engine loop runs the same
//! scheduler policies over worker views, the ledger meters real CPU
//! seconds, and a [`crate::client::StatusServer`] exposes the Clustor
//! protocol so monitor clients (plural — the paper monitors from two
//! continents) can watch and steer the run.
//!
//! Python never executes here: artifacts were compiled by `make artifacts`.

use crate::broker::{ScheduleAdvisor, TickCtx, LIVE_WORK_PRIOR_H};
use crate::client::StatusBoard;
use crate::config::ExperimentConfig;
use crate::dispatcher::wrapper::JobWrapper;
use crate::dispatcher::Action;
use crate::economy::{Ledger, PriceModel};
use crate::engine::Experiment;
use crate::metrics::{Report, ResourceUsage};
use crate::plan::JobSpec;
use crate::runtime::{ChamberOutput, ChamberRuntime};
use crate::scheduler::{CandidateIndex, ResourceView};
use crate::types::{JobId, ResourceId};
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// One simulated grid node backed by a worker thread.
struct Worker {
    rid: ResourceId,
    name: String,
    /// Advertised relative speed (drives scheduling + pricing).
    speed: f64,
    /// Flat G$/CPU-second this node's owner charges.
    rate: f64,
    tx: mpsc::Sender<JobSpec>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// A completed job report from a worker.
struct Completion {
    rid: ResourceId,
    jid: JobId,
    output: ChamberOutput,
    wall_s: f64,
}

/// Outcome of a live run.
pub struct LiveOutcome {
    pub report: Report,
    /// Per-job chamber outputs, indexed by job id.
    pub outputs: BTreeMap<JobId, ChamberOutput>,
}

/// Configuration for the live runner.
pub struct LiveRunner {
    pub workers: usize,
    pub cfg: ExperimentConfig,
    /// Working directory for root storage + node scratch dirs.
    pub workdir: std::path::PathBuf,
    /// Optional status board shared with a StatusServer.
    pub board: Option<Arc<StatusBoard>>,
    /// Pre-resolved schedule advisor (the builder path); `run` resolves
    /// `cfg.policy` against the built-in registry when absent.
    advisor: Option<ScheduleAdvisor>,
}

impl LiveRunner {
    pub fn new(workers: usize, cfg: ExperimentConfig, workdir: &Path) -> Self {
        LiveRunner {
            workers,
            cfg,
            workdir: workdir.to_path_buf(),
            board: None,
            advisor: None,
        }
    }

    pub fn with_board(mut self, board: Arc<StatusBoard>) -> Self {
        self.board = Some(board);
        self
    }

    /// Use an explicitly-constructed schedule advisor (the
    /// [`crate::broker::ExperimentBuilder`] path).
    pub fn with_advisor(mut self, advisor: ScheduleAdvisor) -> Self {
        self.advisor = Some(advisor);
        self
    }

    /// Execute `specs` to completion on real PJRT workers.
    pub fn run(mut self, specs: Vec<JobSpec>) -> Result<LiveOutcome> {
        // Fail early if artifacts are missing (each worker compiles its own
        // copy below: PJRT handles are not Send, and a real grid node runs
        // its own executable anyway).
        let artifact_dir = ChamberRuntime::default_artifact_dir();
        ChamberRuntime::load(&artifact_dir)
            .context("load AOT artifacts (run `make artifacts`)")?;
        let mut advisor = match self.advisor.take() {
            Some(a) => a,
            None => ScheduleAdvisor::resolve(&self.cfg.policy, LIVE_WORK_PRIOR_H)
                .with_context(|| {
                    format!("resolve policy `{}`", self.cfg.policy)
                })?,
        };
        let mut rng = Rng::new(self.cfg.seed);
        let root_store = self.workdir.join("rootstore");
        let (done_tx, done_rx) = mpsc::channel::<Completion>();

        // Spawn workers: heterogeneous speeds/prices from the seed.
        let mut workers: Vec<Worker> = Vec::new();
        for w in 0..self.workers {
            let rid = ResourceId(w as u32);
            let name = format!("node{w}.live");
            let speed = rng.uniform(0.6, 1.6);
            let rate = PriceModel::owner_policy(speed, rng.uniform(0.7, 1.5), 1.0, false)
                .base_rate;
            let (tx, rx) = mpsc::channel::<JobSpec>();
            let done = done_tx.clone();
            let node_dir = self.workdir.join(format!("node{w}"));
            let root = root_store.clone();
            let art_dir = artifact_dir.clone();
            let handle = std::thread::spawn(move || {
                let Ok(rt) = ChamberRuntime::load(&art_dir) else {
                    eprintln!("worker {rid}: failed to load artifacts");
                    return;
                };
                let Ok(wrapper) = JobWrapper::new(&root, &node_dir) else {
                    return;
                };
                while let Ok(job) = rx.recv() {
                    // lint:allow(ND-CLOCK): live driver — worker threads time real process execution
                    let t0 = Instant::now();
                    match wrapper.run(&job, &rt) {
                        Ok(res) => {
                            let _ = done.send(Completion {
                                rid,
                                jid: job.id,
                                output: res.output,
                                wall_s: t0.elapsed().as_secs_f64(),
                            });
                        }
                        Err(e) => {
                            eprintln!("worker {rid}: job {} failed: {e:#}", job.id);
                        }
                    }
                }
            });
            workers.push(Worker {
                rid,
                name,
                speed,
                rate,
                tx,
                handle: Some(handle),
            });
        }
        drop(done_tx);

        let jobs_total = specs.len() as u32;
        let mut exp = Experiment::new(
            specs,
            self.cfg.deadline,
            self.cfg.budget,
            &self.cfg.user,
            self.cfg.max_attempts,
        );
        let mut ledger = Ledger::new(self.cfg.budget);
        let mut report = Report {
            jobs_total,
            deadline_s: self.cfg.deadline,
            ..Default::default()
        };
        let mut outputs = BTreeMap::new();
        let mut busy: BTreeMap<ResourceId, u32> = BTreeMap::new();
        // lint:allow(ND-CLOCK): live driver — the run loop schedules against real wall-clock time, not simtime
        let t0 = Instant::now();

        while !exp.finished() {
            let now = t0.elapsed().as_secs_f64();
            if let Some(board) = &self.board {
                if board.stop_requested.load(Ordering::Relaxed) {
                    break;
                }
                board.jobs_total.store(jobs_total, Ordering::Relaxed);
                board
                    .jobs_completed
                    .store(exp.completed(), Ordering::Relaxed);
                board.jobs_failed.store(exp.failed(), Ordering::Relaxed);
                let running: u32 = busy.values().sum();
                board.jobs_running.store(running, Ordering::Relaxed);
                board.busy_workers.store(running, Ordering::Relaxed);
                board
                    .spent_milli
                    .store((ledger.settled() * 1000.0) as u64, Ordering::Relaxed);
                board
                    .elapsed_ms
                    .store((now * 1000.0) as u64, Ordering::Relaxed);
            }

            // Driver-specific view assembly over the live worker pool; the
            // shared advisor pipeline does selection + assignment. Per-node
            // in-flight counts are O(1) reads of the engine's incremental
            // counters — no job-table scan per tick.
            let views: Vec<ResourceView> = workers
                .iter()
                .map(|w| ResourceView {
                    id: w.rid,
                    slots: 1,
                    planning_speed: w.speed,
                    rate: w.rate,
                    in_flight: exp.in_flight_on(w.rid),
                    measured_jphps: advisor.measured_jphps(w.rid),
                    batch_queue: false,
                })
                .collect();
            // The live pool is tiny and its views are rebuilt wholesale
            // each tick, so the candidate index is simply re-ranked from
            // them (the sim world re-keys its persistent index
            // incrementally instead — see crate::scheduler::index). The
            // re-rank is allocation-phase work, so it runs inside the
            // alloc_ns clock exactly like the sim driver's baseline.
            let job_work = advisor.job_work_ref_h();
            // lint:allow(ND-CLOCK): alloc_ns is wall-clock telemetry about the allocator, same meter as the sim driver
            let alloc_t0 = Instant::now();
            let candidates = CandidateIndex::from_views(&views);
            let actions = advisor.advise(
                TickCtx {
                    now,
                    deadline: self.cfg.deadline,
                    budget_headroom: ledger.headroom(),
                    views: &views,
                    candidates: &candidates,
                },
                &exp,
                &mut rng,
            );
            report.alloc_ns += alloc_t0.elapsed().as_nanos() as u64;
            report.ticks += 1;
            for action in actions {
                match action {
                    Action::Submit { job, rid } => {
                        let w = &workers[rid.0 as usize];
                        let est = w.rate * job_work / w.speed * 3600.0;
                        if !ledger.commit(job, est) {
                            continue;
                        }
                        // lint:allow(PANIC-BUDGET): the advisor only proposes Ready jobs, so the transition is legal
                        exp.dispatch(job, rid, now).expect("legal dispatch");
                        // lint:allow(PANIC-BUDGET): dispatch succeeded one line up, so Dispatched → Running is legal
                        exp.start(job, now).expect("legal start");
                        *busy.entry(rid).or_insert(0) += 1;
                        let total: u32 = busy.values().sum();
                        report.busy_cpus.record(now, total);
                        w.tx.send(exp.job(job).spec.clone()).ok();
                    }
                    Action::CancelQueued { .. } => {
                        // Live workers start immediately (slots=1), so there
                        // is never a queued-but-unstarted job to withdraw.
                    }
                }
            }

            // Collect completions (blocking briefly keeps the loop cheap).
            match done_rx.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(c) => {
                    let now = t0.elapsed().as_secs_f64();
                    let w = &workers[c.rid.0 as usize];
                    let cpu_s = c.wall_s;
                    let cost = cpu_s * w.rate;
                    ledger.settle(c.jid, cost, &w.name);
                    // lint:allow(PANIC-BUDGET): completions only arrive for jobs this loop started
                    exp.complete(c.jid, now, cpu_s, cost).expect("legal complete");
                    advisor.observe_complete(
                        c.rid,
                        c.wall_s,
                        c.wall_s / 3600.0 * w.speed,
                    );
                    // Calibrate the prior from measured wall time so later
                    // ticks plan with real per-job work.
                    let measured = advisor.job_work_ref_h();
                    advisor.set_work_prior_h(measured);
                    outputs.insert(c.jid, c.output);
                    if let Some(n) = busy.get_mut(&c.rid) {
                        *n = n.saturating_sub(1);
                    }
                    let total: u32 = busy.values().sum();
                    report.busy_cpus.record(now, total);
                    let usage = report
                        .per_resource
                        .entry(w.name.clone())
                        .or_insert_with(ResourceUsage::default);
                    usage.jobs_completed += 1;
                    usage.cpu_seconds += cpu_s;
                    usage.cost += cost;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Shut workers down.
        for w in &mut workers {
            let (tx, _) = mpsc::channel();
            let old = std::mem::replace(&mut w.tx, tx);
            drop(old);
        }
        for w in &mut workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }

        report.makespan_s = t0.elapsed().as_secs_f64();
        report.jobs_completed = exp.completed();
        report.jobs_failed = exp.failed();
        report.total_cost = ledger.settled();
        report.deadline_met = report.jobs_completed == report.jobs_total
            && report.makespan_s <= self.cfg.deadline;
        report.resources_used = report
            .per_resource
            .values()
            .filter(|u| u.jobs_completed > 0)
            .count() as u32;
        Ok(LiveOutcome { report, outputs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ionization_plan;

    #[test]
    fn live_run_executes_real_jobs() {
        let dir = ChamberRuntime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping live test: artifacts not built");
            return;
        }
        let src = ionization_plan(3, 2, 2); // 12 jobs
        let plan = crate::plan::Plan::parse(&src).unwrap();
        let specs = crate::plan::expand(&plan, 5).unwrap();
        let tmp =
            std::env::temp_dir().join(format!("nimrod-live-{}", std::process::id()));
        let cfg = ExperimentConfig {
            deadline: 600.0, // wall seconds
            policy: "time".into(),
            seed: 5,
            ..Default::default()
        };
        let outcome = LiveRunner::new(4, cfg, &tmp).run(specs).unwrap();
        assert_eq!(outcome.report.jobs_completed, 12);
        assert_eq!(outcome.outputs.len(), 12);
        for out in outcome.outputs.values() {
            assert!(out.response > 0.0 && out.response.is_finite());
        }
        assert!(outcome.report.total_cost > 0.0);
        // Real result files landed in root storage via stage-out.
        let results = std::fs::read_dir(tmp.join("rootstore")).unwrap().count();
        assert!(results >= 12, "expected staged results, found {results}");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
