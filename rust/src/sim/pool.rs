//! Persistent worker pool for the parallel per-tenant phase of batched
//! ticks.
//!
//! PR 8 spawned fresh OS threads through `std::thread::scope` on every
//! coincident-tick batch. That is correct but pays thread creation and
//! teardown (tens of microseconds per worker) on *every* batch, which
//! bounds the speedup exactly where parallelism matters most: many small
//! batches. [`WorkerPool`] amortizes that cost across the whole run —
//! workers are spawned once per [`crate::sim::GridWorld`], parked on a
//! condvar between batches, and handed each batch through per-lane claim
//! ranges ([`WorkerPool::scatter`]).
//!
//! **Claim affinity.** By default every round hands each lane a
//! deterministic contiguous range of the item slice (lane 0 — the caller
//! — owns the lowest indices). A lane drains its own range first and only
//! then helps stragglers by stealing from other lanes' ranges (lowest
//! lane first), so the fallback shared claiming kicks in only at the tail
//! of a round. Batch membership is stable across most rounds, so a
//! tenant's shard keeps landing on the same lane and its views and index
//! stay warm in one core's cache. `set_affinity(false)` restores the
//! single shared claim counter of PR 9 for comparison; both modes visit
//! every item exactly once, so traces are unaffected.
//!
//! **Determinism.** The pool moves *where* shard work runs, never *what*
//! it computes: each slice element is claimed by exactly one worker,
//! every element is processed exactly once, and `scatter` does not return
//! until all of them finished. Which worker ran which element is the only
//! thing scheduling affects, and nothing in the shard pipeline depends on
//! it (the `PAR-SHARED` lint rule statically rejects shared-state access
//! in pool-run closures just as it does in `// lint:par-section` fns), so
//! traces stay bit-exact at every worker count.
//!
//! **Streaming hand-off.** [`WorkerPool::scatter_streaming`] adds an
//! in-order commit queue on top of the same claim protocol: the caller is
//! the *sole* committer, applying `commit` to items in ascending index
//! order as soon as each becomes the lowest finished-but-uncommitted item
//! — while higher-indexed items are still running on the worker lanes.
//! Workers never commit; they flag completion under the mutex and wake
//! the caller. When the commit frontier is blocked on an item a worker is
//! still running, the caller claims work itself instead of idling. The
//! `overlapped` flag handed to `commit` records whether any item was
//! still unfinished when that commit started — the merge-overlap
//! telemetry the bench reports.
//!
//! **Lifetimes.** Long-lived workers cannot borrow the per-batch shards
//! directly, so `scatter` erases the item type behind a raw base pointer
//! plus a monomorphized trampoline and acts as its own scope: the caller
//! participates in the claim loop and then blocks until every worker has
//! checked the round in, which is what makes the borrow sound — no worker
//! can touch the batch after `scatter` returns. A panic inside the
//! closure is caught on the worker, aborts the round's remaining claims,
//! and is resumed on the caller thread after the barrier (the pool itself
//! stays usable). Dropping the pool parks no work: it flags shutdown,
//! wakes everyone and joins every worker, so a dropped
//! [`crate::sim::GridWorld`] leaks no threads.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// One published batch: an erased pointer to the caller's stack context,
/// the monomorphized trampoline that reconstitutes it, and the item count
/// workers claim against.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    len: usize,
}

// SAFETY: `data` points at a `Ctx` on the `scatter` caller's stack, and
// `scatter` blocks until every worker has checked the round in before
// returning — the pointee strictly outlives every dereference. Item
// indices are claimed exclusively under the state mutex, so no two
// threads ever touch the same element.
unsafe impl Send for Job {}

/// Shared pool state behind the hand-off mutex.
struct State {
    /// Batch counter; workers run one claim loop per observed increment.
    round: u64,
    /// Next unclaimed item index of the current round (shared-counter
    /// mode, i.e. affinity off).
    next: usize,
    /// Per-lane contiguous claim ranges (affinity mode): lane `l` owns
    /// `lane_next[l]..lane_hi[l]` and steals from other lanes only once
    /// its own range is dry. Empty in shared-counter mode.
    lane_next: Vec<usize>,
    lane_hi: Vec<usize>,
    /// Workers that have not yet checked the current round in.
    remaining: usize,
    /// Streaming rounds only: per-item completion flags (the commit
    /// frontier advances over the ascending prefix of `true`s) and the
    /// count of completed items (the `overlapped` signal).
    done: Vec<bool>,
    finished: usize,
    /// Whether the current round streams commits through the caller.
    streaming: bool,
    job: Option<Job>,
    /// First panic payload caught this round; resumed on the caller.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

impl State {
    /// Claim one item for `lane`: own contiguous range first, then steal
    /// from other lanes ascending (lowest indices first — in streaming
    /// rounds those gate the commit frontier), then the shared counter
    /// (affinity off). Exactly-once is guaranteed by the enclosing mutex.
    fn claim(&mut self, lane: usize) -> Option<usize> {
        let len = self.job?.len;
        if !self.lane_hi.is_empty() {
            if let Some(i) = self.take_lane(lane) {
                return Some(i);
            }
            for l in 0..self.lane_hi.len() {
                if l != lane {
                    if let Some(i) = self.take_lane(l) {
                        return Some(i);
                    }
                }
            }
            return None;
        }
        if self.next >= len {
            return None;
        }
        let i = self.next;
        self.next += 1;
        Some(i)
    }

    fn take_lane(&mut self, l: usize) -> Option<usize> {
        if self.lane_next[l] < self.lane_hi[l] {
            let i = self.lane_next[l];
            self.lane_next[l] += 1;
            Some(i)
        } else {
            None
        }
    }

    /// Publish the claim bookkeeping for a round of `len` items across
    /// `lanes` lanes: contiguous per-lane ranges (affinity) or the shared
    /// counter. Range sizes differ by at most one and lane 0 (the caller)
    /// always owns the lowest indices.
    fn publish_claims(&mut self, len: usize, lanes: usize, affinity: bool) {
        self.next = 0;
        self.lane_next.clear();
        self.lane_hi.clear();
        if affinity && lanes > 1 {
            let base = len / lanes;
            let rem = len % lanes;
            let mut start = 0;
            for l in 0..lanes {
                let size = base + usize::from(l < rem);
                self.lane_next.push(start);
                self.lane_hi.push(start + size);
                start += size;
            }
        }
    }

    /// Cancel every unclaimed item of the round (panic abort).
    fn abort_claims(&mut self) {
        if let Some(job) = self.job {
            self.next = job.len;
        }
        for l in 0..self.lane_hi.len() {
            self.lane_next[l] = self.lane_hi[l];
        }
    }

    fn stash_panic(&mut self, payload: Box<dyn Any + Send>) {
        self.abort_claims();
        if self.panic.is_none() {
            self.panic = Some(payload);
        }
    }
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between rounds.
    work_cv: Condvar,
    /// The caller parks here until `remaining` hits zero; streaming
    /// rounds also pulse it per completed item to advance the commit
    /// frontier.
    done_cv: Condvar,
}

impl Shared {
    fn lock(&self) -> MutexGuard<'_, State> {
        // A poisoned mutex means a thread panicked while holding it; the
        // critical sections below are plain counter bookkeeping (closure
        // panics are caught outside the lock), so the state is still
        // coherent — continue rather than double-panic.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn wait_done<'a>(&'a self, st: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
        self.done_cv.wait(st).unwrap_or_else(|e| e.into_inner())
    }
}

/// A fixed-size pool of long-lived workers created once and reused for
/// every batch. See the module docs for the hand-off protocol.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Sticky lane affinity (default on): rounds are published as
    /// contiguous per-lane ranges instead of one shared counter.
    affinity: bool,
}

/// Typed context `scatter` publishes behind the erased [`Job`] pointer.
struct Ctx<T, F> {
    base: *mut T,
    f: *const F,
}

/// Reconstitute the typed context and run the closure on item `i`. Safety
/// contract is [`Job`]'s: exclusive index claims, caller-outlives-round.
unsafe fn call_one<T, F: Fn(&mut T) + Sync>(data: *const (), i: usize) {
    let ctx = &*(data as *const Ctx<T, F>);
    (*ctx.f)(&mut *ctx.base.add(i));
}

impl WorkerPool {
    /// A pool presenting `workers` total lanes of parallelism. The caller
    /// thread is lane 0 (it claims items alongside the pool in
    /// [`WorkerPool::scatter`]), so `workers - 1` OS threads are spawned;
    /// `new(1)` spawns none and `scatter` degenerates to a plain loop.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                round: 0,
                next: 0,
                lane_next: Vec::new(),
                lane_hi: Vec::new(),
                remaining: 0,
                done: Vec::new(),
                finished: 0,
                streaming: false,
                job: None,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..workers.max(1))
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, lane))
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            affinity: true,
        }
    }

    /// Total parallel lanes (spawned workers + the participating caller).
    pub fn workers(&self) -> usize {
        self.handles.len() + 1
    }

    /// Toggle sticky lane affinity (see the module docs; default on).
    /// Both claim modes visit every item exactly once, so this never
    /// changes results — only cache behaviour.
    pub fn set_affinity(&mut self, on: bool) {
        self.affinity = on;
    }

    /// Whether rounds are published with per-lane claim ranges.
    pub fn affinity(&self) -> bool {
        self.affinity
    }

    /// Publish a round and wake the workers. Caller must hold no lock.
    fn publish(&self, job: Job, streaming: bool) {
        let mut st = self.shared.lock();
        st.round = st.round.wrapping_add(1);
        st.publish_claims(job.len, self.handles.len() + 1, self.affinity);
        st.remaining = self.handles.len();
        st.streaming = streaming;
        st.finished = 0;
        st.done.clear();
        if streaming {
            st.done.resize(job.len, false);
        }
        st.job = Some(job);
        self.shared.work_cv.notify_all();
    }

    /// Wait for every worker to check the round in, unpublish it and
    /// re-raise the round's first panic (if any) on the caller.
    fn barrier(&self) {
        let mut st = self.shared.lock();
        while st.remaining > 0 {
            st = self.shared.wait_done(st);
        }
        st.job = None;
        st.streaming = false;
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run `f` once on every element of `items`, fanned across the pool.
    /// Blocks until every element is done; panics inside `f` are re-raised
    /// here after the round has fully drained. Each element is visited by
    /// exactly one thread; which thread is the only scheduling freedom, so
    /// order-independent per-element work stays deterministic.
    pub fn scatter<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        if self.handles.is_empty() || items.len() <= 1 {
            // Nothing to fan out: the reference path, caller thread only.
            for it in items.iter_mut() {
                f(it);
            }
            return;
        }
        let ctx = Ctx { base: items.as_mut_ptr(), f: &f };
        let job = Job {
            data: (&ctx as *const Ctx<T, F>).cast(),
            call: call_one::<T, F>,
            len: items.len(),
        };
        self.publish(job, false);
        // Lane 0: the caller claims items alongside the woken workers.
        loop {
            let i = {
                let mut st = self.shared.lock();
                match st.claim(0) {
                    Some(i) => i,
                    None => break,
                }
            };
            // SAFETY: index `i` was claimed exclusively above and `ctx`
            // lives until the barrier below.
            let hit = catch_unwind(AssertUnwindSafe(|| unsafe {
                (job.call)(job.data, i)
            }));
            if let Err(payload) = hit {
                self.shared.lock().stash_panic(payload);
            }
        }
        // Barrier: `scatter` must not return (releasing the `items`
        // borrow) while any worker could still be inside an element.
        self.barrier();
    }

    /// [`WorkerPool::scatter`] plus an in-order commit queue: `f` fans
    /// out across the lanes exactly as in `scatter`, and the caller —
    /// the sole committer — applies `commit` to each item in ascending
    /// index order as soon as items `0..=i` have all finished `f`, while
    /// higher-indexed items may still be running. `commit`'s second
    /// argument reports whether any item was still unfinished when that
    /// commit began (the overlap telemetry). When the frontier is blocked
    /// the caller claims `f`-work itself rather than idling.
    ///
    /// Exclusivity: a worker never touches item `i` after flagging it
    /// done, and only the caller runs `commit`, so the `&mut T` handed to
    /// `commit` is unaliased even while other items are mid-`f`. Panics
    /// in `f` or `commit` abort the round's remaining claims and re-raise
    /// here after the barrier; items past the frontier then stay
    /// uncommitted.
    pub fn scatter_streaming<T, F, C>(&self, items: &mut [T], f: F, mut commit: C)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
        C: FnMut(&mut T, bool),
    {
        if self.handles.is_empty() || items.len() <= 1 {
            // Degenerate pipeline: run and commit each item in order on
            // the caller; nothing ever overlaps a commit.
            for it in items.iter_mut() {
                f(it);
                commit(it, false);
            }
            return;
        }
        let len = items.len();
        let base = items.as_mut_ptr();
        let ctx = Ctx { base, f: &f };
        let job = Job {
            data: (&ctx as *const Ctx<T, F>).cast(),
            call: call_one::<T, F>,
            len,
        };
        self.publish(job, true);
        let mut committed = 0usize;
        let mut st = self.shared.lock();
        while committed < len {
            if st.panic.is_some() {
                st.abort_claims();
                break;
            }
            if st.done[committed] {
                // The frontier item is ready: commit it outside the lock.
                let overlapped = st.finished < len;
                drop(st);
                // SAFETY: `done[committed]` means its exclusive claimant
                // finished `f` and will never touch it again; the caller
                // is the only committer, so the reference is unaliased.
                let item = unsafe { &mut *base.add(committed) };
                let hit =
                    catch_unwind(AssertUnwindSafe(|| commit(item, overlapped)));
                committed += 1;
                st = self.shared.lock();
                if let Err(payload) = hit {
                    st.stash_panic(payload);
                    break;
                }
                continue;
            }
            // Frontier not ready: help with phase work instead of idling.
            if let Some(i) = st.claim(0) {
                drop(st);
                // SAFETY: exclusive claim of `i`; `ctx` lives until the
                // barrier below.
                let hit = catch_unwind(AssertUnwindSafe(|| unsafe {
                    (job.call)(job.data, i)
                }));
                st = self.shared.lock();
                match hit {
                    Ok(()) => {
                        st.done[i] = true;
                        st.finished += 1;
                    }
                    Err(payload) => st.stash_panic(payload),
                }
                continue;
            }
            // Nothing to claim and the frontier item is still running on
            // a worker: park until a completion (or check-in) pulse.
            st = self.shared.wait_done(st);
        }
        drop(st);
        self.barrier();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            // A worker's own panics are caught in its claim loop, so join
            // errors are not expected; swallowing one at shutdown beats
            // panicking in Drop.
            let _ = h.join();
        }
    }
}

/// Body of one spawned worker (`lane` ≥ 1; lane 0 is the caller): park
/// until a new round (or shutdown), claim-and-run items until the round
/// is dry — own affinity range first — check in, repeat. Streaming
/// rounds additionally flag each completed item and pulse the caller so
/// the commit frontier can advance.
fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen: u64 = 0;
    let mut st = shared.lock();
    loop {
        while !st.shutdown && st.round == seen {
            st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.shutdown {
            return;
        }
        seen = st.round;
        if let Some(job) = st.job {
            loop {
                let i = match st.claim(lane) {
                    Some(i) => i,
                    None => break,
                };
                drop(st);
                // SAFETY: exclusive claim of `i`; the caller's barrier
                // keeps the pointee alive until we check in below.
                let hit = catch_unwind(AssertUnwindSafe(|| unsafe {
                    (job.call)(job.data, i)
                }));
                st = shared.lock();
                match hit {
                    Ok(()) => {
                        if st.streaming {
                            st.done[i] = true;
                            st.finished += 1;
                            // Wake the committer: the frontier may now
                            // include this item.
                            shared.done_cv.notify_all();
                        }
                    }
                    Err(payload) => {
                        st.stash_panic(payload);
                        if st.streaming {
                            shared.done_cv.notify_all();
                        }
                    }
                }
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_visits_every_item_exactly_once() {
        let pool = WorkerPool::new(4);
        for len in [0usize, 1, 2, 3, 4, 7, 64, 257] {
            let mut items: Vec<u32> = vec![0; len];
            pool.scatter(&mut items, |x| *x += 1);
            assert!(items.iter().all(|&x| x == 1), "len {len}: {items:?}");
        }
    }

    #[test]
    fn shared_counter_mode_also_visits_every_item_exactly_once() {
        let mut pool = WorkerPool::new(4);
        pool.set_affinity(false);
        assert!(!pool.affinity());
        for len in [0usize, 1, 3, 7, 64, 257] {
            let mut items: Vec<u32> = vec![0; len];
            pool.scatter(&mut items, |x| *x += 1);
            assert!(items.iter().all(|&x| x == 1), "len {len}: {items:?}");
        }
    }

    #[test]
    fn batch_smaller_than_worker_count_still_drains() {
        // 8 lanes, 2 items: six workers wake, find nothing to claim, and
        // must still check the round in so scatter's barrier releases.
        let pool = WorkerPool::new(8);
        for round in 0..50 {
            let mut items = vec![0u64; 2];
            pool.scatter(&mut items, |x| *x = round + 1);
            assert_eq!(items, vec![round + 1; 2]);
        }
    }

    #[test]
    fn rounds_reuse_the_same_workers_with_varying_lengths() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        let mut total = 0;
        for len in [5usize, 1, 0, 12, 3, 40] {
            let mut items: Vec<u8> = vec![0; len];
            pool.scatter(&mut items, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            total += len;
        }
        assert_eq!(hits.load(Ordering::Relaxed), total);
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.workers(), 1);
        let mut items = vec![0u32; 10];
        pool.scatter(&mut items, |x| *x = 9);
        assert!(items.iter().all(|&x| x == 9));
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = WorkerPool::new(6);
        assert_eq!(pool.workers(), 6);
        // Run a round so the workers have demonstrably woken at least once.
        let mut items = vec![0u32; 32];
        pool.scatter(&mut items, |x| *x += 1);
        let probe = Arc::clone(&pool.shared);
        drop(pool);
        // Every spawned worker held one Arc clone; after Drop joined them
        // all, only the probe remains — no thread leaked past shutdown.
        assert_eq!(Arc::strong_count(&probe), 1);
    }

    #[test]
    fn closure_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = (0..64).collect();
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.scatter(&mut items, |x| {
                if *x == 13 {
                    panic!("unlucky shard");
                }
            });
        }));
        assert!(boom.is_err(), "worker panic must surface on the caller");
        // The pool is still serviceable for later batches.
        let mut again = vec![0u32; 16];
        pool.scatter(&mut again, |x| *x = 7);
        assert!(again.iter().all(|&x| x == 7));
    }

    #[test]
    fn streaming_commits_every_item_in_ascending_order() {
        let pool = WorkerPool::new(4);
        for len in [0usize, 1, 2, 3, 7, 64, 257] {
            let mut items: Vec<u32> = vec![0; len];
            let order = std::sync::Mutex::new(Vec::new());
            pool.scatter_streaming(
                &mut items,
                |x| *x += 1,
                |x, _overlapped| {
                    *x += 10;
                    order.lock().unwrap().push(*x);
                },
            );
            assert!(items.iter().all(|&x| x == 11), "len {len}: {items:?}");
            // Commits ran strictly in index order, exactly once each.
            assert_eq!(order.into_inner().unwrap().len(), len);
        }
    }

    #[test]
    fn streaming_commit_sees_phase_work_of_its_item() {
        // Commit index order is observable: stamp each item with its
        // commit sequence number and check it matches its index.
        let pool = WorkerPool::new(3);
        let mut items: Vec<(u64, u64)> = (0..100).map(|i| (i, 0)).collect();
        let mut seq = 0u64;
        pool.scatter_streaming(
            &mut items,
            |it| it.1 = it.0 * 2,
            |it, _| {
                assert_eq!(it.1, it.0 * 2, "commit before f finished");
                it.1 = seq;
                seq += 1;
            },
        );
        for (i, it) in items.iter().enumerate() {
            assert_eq!(it.1, i as u64, "commit order broke at {i}");
        }
    }

    #[test]
    fn streaming_single_lane_interleaves_inline() {
        let pool = WorkerPool::new(1);
        let mut items = vec![0u32; 9];
        let mut commits = 0;
        pool.scatter_streaming(
            &mut items,
            |x| *x = 5,
            |x, overlapped| {
                assert_eq!(*x, 5);
                assert!(!overlapped, "inline path never overlaps");
                commits += 1;
            },
        );
        assert_eq!(commits, 9);
    }

    #[test]
    fn streaming_panic_in_f_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = (0..64).collect();
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.scatter_streaming(
                &mut items,
                |x| {
                    if *x == 13 {
                        panic!("unlucky shard");
                    }
                },
                |_x, _| {},
            );
        }));
        assert!(boom.is_err(), "phase panic must surface on the caller");
        let mut again = vec![0u32; 16];
        pool.scatter_streaming(&mut again, |x| *x = 3, |x, _| *x += 1);
        assert!(again.iter().all(|&x| x == 4));
    }

    #[test]
    fn streaming_panic_in_commit_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = (0..64).collect();
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.scatter_streaming(
                &mut items,
                |_x| {},
                |x, _| {
                    if *x == 20 {
                        panic!("unlucky commit");
                    }
                },
            );
        }));
        assert!(boom.is_err(), "commit panic must surface on the caller");
        let mut again = vec![0u32; 8];
        pool.scatter(&mut again, |x| *x = 2);
        assert!(again.iter().all(|&x| x == 2));
    }
}
