//! Persistent worker pool for the parallel per-tenant phase of batched
//! ticks.
//!
//! PR 8 spawned fresh OS threads through `std::thread::scope` on every
//! coincident-tick batch. That is correct but pays thread creation and
//! teardown (tens of microseconds per worker) on *every* batch, which
//! bounds the speedup exactly where parallelism matters most: many small
//! batches. [`WorkerPool`] amortizes that cost across the whole run —
//! workers are spawned once per [`crate::sim::GridWorld`], parked on a
//! condvar between batches, and handed each batch through a shared
//! claim counter ([`WorkerPool::scatter`]).
//!
//! **Determinism.** The pool moves *where* shard work runs, never *what*
//! it computes: each slice element is claimed by exactly one worker,
//! every element is processed exactly once, and `scatter` does not return
//! until all of them finished. Which worker ran which element is the only
//! thing scheduling affects, and nothing in the shard pipeline depends on
//! it (the `PAR-SHARED` lint rule statically rejects shared-state access
//! in pool-run closures just as it does in `// lint:par-section` fns), so
//! traces stay bit-exact at every worker count.
//!
//! **Lifetimes.** Long-lived workers cannot borrow the per-batch shards
//! directly, so `scatter` erases the item type behind a raw base pointer
//! plus a monomorphized trampoline and acts as its own scope: the caller
//! participates in the claim loop and then blocks until every worker has
//! checked the round in, which is what makes the borrow sound — no worker
//! can touch the batch after `scatter` returns. A panic inside the
//! closure is caught on the worker, aborts the round's remaining claims,
//! and is resumed on the caller thread after the barrier (the pool itself
//! stays usable). Dropping the pool parks no work: it flags shutdown,
//! wakes everyone and joins every worker, so a dropped
//! [`crate::sim::GridWorld`] leaks no threads.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// One published batch: an erased pointer to the caller's stack context,
/// the monomorphized trampoline that reconstitutes it, and the item count
/// workers claim against.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    len: usize,
}

// SAFETY: `data` points at a `Ctx` on the `scatter` caller's stack, and
// `scatter` blocks until every worker has checked the round in before
// returning — the pointee strictly outlives every dereference. Item
// indices are claimed exclusively under the state mutex, so no two
// threads ever touch the same element.
unsafe impl Send for Job {}

/// Shared pool state behind the hand-off mutex.
struct State {
    /// Batch counter; workers run one claim loop per observed increment.
    round: u64,
    /// Next unclaimed item index of the current round.
    next: usize,
    /// Workers that have not yet checked the current round in.
    remaining: usize,
    job: Option<Job>,
    /// First panic payload caught this round; resumed on the caller.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between rounds.
    work_cv: Condvar,
    /// The caller parks here until `remaining` hits zero.
    done_cv: Condvar,
}

impl Shared {
    fn lock(&self) -> MutexGuard<'_, State> {
        // A poisoned mutex means a thread panicked while holding it; the
        // critical sections below are plain counter bookkeeping (closure
        // panics are caught outside the lock), so the state is still
        // coherent — continue rather than double-panic.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A fixed-size pool of long-lived workers created once and reused for
/// every batch. See the module docs for the hand-off protocol.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

/// Typed context `scatter` publishes behind the erased [`Job`] pointer.
struct Ctx<T, F> {
    base: *mut T,
    f: *const F,
}

/// Reconstitute the typed context and run the closure on item `i`. Safety
/// contract is [`Job`]'s: exclusive index claims, caller-outlives-round.
unsafe fn call_one<T, F: Fn(&mut T) + Sync>(data: *const (), i: usize) {
    let ctx = &*(data as *const Ctx<T, F>);
    (*ctx.f)(&mut *ctx.base.add(i));
}

impl WorkerPool {
    /// A pool presenting `workers` total lanes of parallelism. The caller
    /// thread is lane 0 (it claims items alongside the pool in
    /// [`WorkerPool::scatter`]), so `workers - 1` OS threads are spawned;
    /// `new(1)` spawns none and `scatter` degenerates to a plain loop.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                round: 0,
                next: 0,
                remaining: 0,
                job: None,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Total parallel lanes (spawned workers + the participating caller).
    pub fn workers(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f` once on every element of `items`, fanned across the pool.
    /// Blocks until every element is done; panics inside `f` are re-raised
    /// here after the round has fully drained. Each element is visited by
    /// exactly one thread; which thread is the only scheduling freedom, so
    /// order-independent per-element work stays deterministic.
    pub fn scatter<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        if self.handles.is_empty() || items.len() <= 1 {
            // Nothing to fan out: the reference path, caller thread only.
            for it in items.iter_mut() {
                f(it);
            }
            return;
        }
        let len = items.len();
        let ctx = Ctx { base: items.as_mut_ptr(), f: &f };
        let job = Job {
            data: (&ctx as *const Ctx<T, F>).cast(),
            call: call_one::<T, F>,
            len,
        };
        {
            let mut st = self.shared.lock();
            st.round = st.round.wrapping_add(1);
            st.next = 0;
            st.remaining = self.handles.len();
            st.job = Some(job);
            self.shared.work_cv.notify_all();
        }
        // Lane 0: the caller claims items alongside the woken workers.
        loop {
            let i = {
                let mut st = self.shared.lock();
                if st.next >= len {
                    break;
                }
                let i = st.next;
                st.next += 1;
                i
            };
            // SAFETY: index `i` was claimed exclusively above and `ctx`
            // lives until the barrier below.
            let hit = catch_unwind(AssertUnwindSafe(|| unsafe {
                (job.call)(job.data, i)
            }));
            if let Err(payload) = hit {
                let mut st = self.shared.lock();
                st.next = len; // abort the round's remaining claims
                if st.panic.is_none() {
                    st.panic = Some(payload);
                }
            }
        }
        // Barrier: `scatter` must not return (releasing the `items`
        // borrow) while any worker could still be inside an element.
        let mut st = self.shared.lock();
        while st.remaining > 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            // A worker's own panics are caught in its claim loop, so join
            // errors are not expected; swallowing one at shutdown beats
            // panicking in Drop.
            let _ = h.join();
        }
    }
}

/// Body of one spawned worker: park until a new round (or shutdown),
/// claim-and-run items until the round is dry, check in, repeat.
fn worker_loop(shared: &Shared) {
    let mut seen: u64 = 0;
    let mut st = shared.lock();
    loop {
        while !st.shutdown && st.round == seen {
            st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.shutdown {
            return;
        }
        seen = st.round;
        if let Some(job) = st.job {
            loop {
                if st.next >= job.len {
                    break;
                }
                let i = st.next;
                st.next += 1;
                drop(st);
                // SAFETY: exclusive claim of `i`; the caller's barrier
                // keeps the pointee alive until we check in below.
                let hit = catch_unwind(AssertUnwindSafe(|| unsafe {
                    (job.call)(job.data, i)
                }));
                st = shared.lock();
                if let Err(payload) = hit {
                    st.next = job.len;
                    if st.panic.is_none() {
                        st.panic = Some(payload);
                    }
                }
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_visits_every_item_exactly_once() {
        let pool = WorkerPool::new(4);
        for len in [0usize, 1, 2, 3, 4, 7, 64, 257] {
            let mut items: Vec<u32> = vec![0; len];
            pool.scatter(&mut items, |x| *x += 1);
            assert!(items.iter().all(|&x| x == 1), "len {len}: {items:?}");
        }
    }

    #[test]
    fn batch_smaller_than_worker_count_still_drains() {
        // 8 lanes, 2 items: six workers wake, find nothing to claim, and
        // must still check the round in so scatter's barrier releases.
        let pool = WorkerPool::new(8);
        for round in 0..50 {
            let mut items = vec![0u64; 2];
            pool.scatter(&mut items, |x| *x = round + 1);
            assert_eq!(items, vec![round + 1; 2]);
        }
    }

    #[test]
    fn rounds_reuse_the_same_workers_with_varying_lengths() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        let mut total = 0;
        for len in [5usize, 1, 0, 12, 3, 40] {
            let mut items: Vec<u8> = vec![0; len];
            pool.scatter(&mut items, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            total += len;
        }
        assert_eq!(hits.load(Ordering::Relaxed), total);
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.workers(), 1);
        let mut items = vec![0u32; 10];
        pool.scatter(&mut items, |x| *x = 9);
        assert!(items.iter().all(|&x| x == 9));
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = WorkerPool::new(6);
        assert_eq!(pool.workers(), 6);
        // Run a round so the workers have demonstrably woken at least once.
        let mut items = vec![0u32; 32];
        pool.scatter(&mut items, |x| *x += 1);
        let probe = Arc::clone(&pool.shared);
        drop(pool);
        // Every spawned worker held one Arc clone; after Drop joined them
        // all, only the probe remains — no thread leaked past shutdown.
        assert_eq!(Arc::strong_count(&probe), 1);
    }

    #[test]
    fn closure_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = (0..64).collect();
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.scatter(&mut items, |x| {
                if *x == 13 {
                    panic!("unlucky shard");
                }
            });
        }));
        assert!(boom.is_err(), "worker panic must surface on the caller");
        // The pool is still serviceable for later batches.
        let mut again = vec![0u32; 16];
        pool.scatter(&mut again, |x| *x = 7);
        assert!(again.iter().all(|&x| x == 7));
    }
}
