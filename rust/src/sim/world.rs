//! The shared grid world: one testbed + directory + event queue + economy
//! hosting **N concurrent tenant experiments** (paper §3: many users with
//! independent deadlines, budgets and policies competing for
//! distributedly-owned resources).
//!
//! [`GridWorld`] owns everything that belongs to the *grid* — the
//! [`Testbed`], ground-truth dynamics, MDS directory, GRAM job managers,
//! GASS/proxy staging, availability churn, the residual background
//! [`Competition`] process and the single discrete-event queue. Each
//! [`Tenant`] is a complete Nimrod/G broker instance over that world: its
//! own [`Experiment`] engine, [`Ledger`], schedule advisor (policy + rate
//! estimator), work sampler, journal and report, plus its own persistent
//! incremental view table *and* candidate index (prices are per-user,
//! in-flight counts are per-experiment, so neither can be shared). The
//! index re-keys exactly the entries the view refresh rebuilds, so policy
//! allocation walks pre-ranked candidates instead of sorting the table —
//! any new driver must dirty the index alongside the view table (see
//! [`crate::scheduler::index`]).
//!
//! Contention between tenants is *real*, not synthetic: tenant A's
//! in-flight jobs reduce the `free_slots` tenant B sees (one formula —
//! [`crate::grid::competition::visible_slots`] — covers tenant occupancy
//! and background claims together), and owners with a demand-responsive
//! [`crate::economy::PriceModel`] (`demand_slope > 0`) reprice with total
//! machine utilization, so demand from any tenant moves every tenant's
//! quotes.
//!
//! **Incrementality is preserved.** Every state-changing event dirties
//! exactly the view entries it touches, now across *all* tenants' tables:
//! MDS deltas, churn and competition claims mark the affected resource for
//! every tenant; a job transition marks that resource for every tenant too
//! (the owning tenant's in-flight count changed, everyone else's visible
//! slots and demand premium changed). Ticks stay O(changed), and the
//! single-tenant [`super::GridSimulation`] is literally the N = 1 case of
//! this code — bit-exact against the pre-world driver at equal seeds for
//! competition-free configurations (competition-enabled traces differ by
//! design: arrivals now respect real occupancy).
//!
//! That discipline is machine-checked: the **DIRTY-PAIR** rule of
//! `nimrod-lint` (`tools/nimrod-lint`, run by CI and by
//! `rust/tests/lint_clean.rs`) flags any function in this file that marks
//! views dirty without re-keying the [`crate::scheduler::CandidateIndex`]
//! in the same body. Most event handlers here *intentionally* defer the
//! re-key to [`GridWorld::refresh_dirty_views`], which drains the dirty
//! queue once per tick — each such handler carries a `DIRTY-PAIR` allow
//! marker naming that deferral, so an unpaired mark added by a future
//! driver fails the lint instead of silently serving stale rankings.
//!
//! **The market layer is pluggable.** Under the default
//! [`MarketKind::PostedPrice`] every quote is the owner's posted rate times
//! competition/demand premiums — bit-exact with the pre-market code. Under
//! [`MarketKind::GraceAuction`] the world additionally runs one GRACE
//! tender/bid round per tenant at every directory refresh: the tender is
//! derived from the tenant's live DBC state (remaining jobs, deadline
//! slack, budget headroom), per-owner bid servers quote on *real*
//! utilization (the same [`visible_slots`] occupancy and
//! [`crate::economy::PriceModel::demand_slope`] signals the posted path
//! uses), and awards become time-limited [`PriceAgreement`]s per
//! (tenant, resource). Both the scheduler's resource views and the billing
//! path honour a live agreement over the posted quote, and awards/expiries
//! dirty only the winning tenant's views of the touched resources, so the
//! O(changed) tick survives the auction layer.
//!
//! **Advance reservations are world-booked.** With a
//! [`ReservationConfig`] on tenant 0 the world runs the probe → reserve →
//! commit lifecycle of [`crate::economy::reservation`]: near its deadline
//! a tenant shadow-prices several candidate resource sets against its live
//! views, really reserves the two cheapest plans, commits the cheapest and
//! walks away from the rest while cancellation is still free. Held slots
//! are real capacity: they join the shared `total_reserved` occupancy,
//! leave every *other* tenant's [`visible_slots`] (the holder still sees
//! its own holds — that is what it dispatches into), feed the demand-
//! premium utilization signal, and extend the slot-conservation invariant
//! to Σ in-flight + competition claims + reserved ≤ CPUs, asserted every
//! debug tick. Every hold transition follows the standing rule — it
//! dirties the touched resource's view *and* candidate-index entry for
//! every tenant — and is journalled for crash recovery (recovery releases
//! open holds rather than restoring them). With the config absent the
//! subsystem is inert: no RNG is drawn, no f64 changes, and the world
//! replays bit-exactly like the pre-reservation pipeline.
//!
//! **Coincident ticks run as one three-phase batch.** Whenever two or more
//! tenants tick at the same virtual instant (the common case: co-tenants
//! share a tick period and all start at t = 0), the run loop coalesces the
//! consecutive `Tick` events into a batch and [`GridWorld::on_tick_batch`]
//! processes it in three phases: (1) a **sequential snapshot** — expiry
//! sweeps, repricing marks and the (shared-state-mutating) reserve-ahead
//! move run in ascending tenant order, then one per-tenant RNG sub-stream
//! is forked from the world RNG per member, again in tenant order; (2) a
//! **parallel per-tenant phase** — the batch members are scattered across
//! the world's persistent [`WorkerPool`] (long-lived workers created once
//! per world and parked between batches, so small batches stop paying
//! per-batch thread-spawn cost; `set_scoped_spawn` keeps the PR-8
//! `std::thread::scope` baseline selectable for benches). Each shard runs
//! view refresh, candidate-index re-keying (through the struct-of-arrays
//! [`ViewColumns`] mirror) and policy allocation against the frozen
//! [`WorldView`] snapshot and its pre-drawn sub-RNG, then *pre-computes*
//! the frozen-input parts of its pending submits — posted-quote ×
//! competition pricing, agreement lookup, effective speed, spec name, the
//! per-job work draw — into [`PreparedSubmit`]s, producing a
//! [`MergeAction`] delta instead of mutating shared state (the
//! `PAR-SHARED` lint rule rejects shared-state access in
//! `lint:par-section` functions and in closures run through
//! `WorkerPool::scatter`/`scatter_streaming`); (3) a **streaming ordered
//! merge** — only the genuinely order-dependent work, run as an in-order
//! commit queue instead of a hard barrier: tenant *t*'s delta applies
//! (through [`MergeCtx`], the mutable slice of world state commits touch)
//! as soon as shards `0..=t` have all finished phase 2, while
//! higher-numbered shards are still running in the pool. Deltas still
//! apply in ascending tenant order through a ground-truth capacity guard
//! (snapshot decisions can collectively overbook a machine; deferred
//! submits stay Ready and retry next tick, exactly like a refused budget
//! commit), each admitted submit finishes its rate from the *live* demand
//! signal ([`merge_submit_prepared`] — demand premiums and reservation
//! holds move with earlier merge submits, so they cannot be precomputed),
//! and the members' next ticks are rescheduled in the same order.
//!
//! **The streaming-merge invariant:** a commit must never change anything
//! a still-running shard can read. Shards read the occupancy tallies
//! through per-batch snapshot copies (`snap_in_flight`/`snap_reserved`),
//! commits mutate the live arrays; the cross-tenant effects a commit
//! *would* fan out — `mark_view_all` dirtying and GRAM cancel
//! withdrawals — are deferred into commit-ordered buffers
//! (`mark_buf`/`cancel_buf`) and replayed by `drain_merge_buffers` once
//! every shard has dropped its `&mut Tenant`. The capacity guard reads
//! only the live tallies (never the GRAM managers), so deferring the
//! withdrawals is invisible to admission decisions. Streamed commits are
//! therefore byte-identical to the PR-9 barrier
//! ([`GridWorld::set_barrier_merge`] keeps that path selectable for the
//! comparison), and no step depends on worker interleaving, so traces are
//! bit-exact at **every** thread count and merge mode: `threads(1)` runs
//! the identical pipeline on the caller thread and is the reference path
//! (`rust/tests/parallel_equivalence.rs` replays contested, auction,
//! reservation and 256-tenant worlds at 1/2/4/8 threads under both merge
//! modes and compares `to_bits`). Batches of one — any single-tenant
//! world — take the original sequential `on_tick` verbatim, which is what
//! keeps [`super::GridSimulation`] byte-identical to the legacy driver:
//! snapshot semantics and snapshot-vs-cascade differences only exist
//! where two tenants actually share an instant.

use crate::broker::{ScheduleAdvisor, TickCtx};
use crate::config::ExperimentConfig;
use crate::dispatcher::Action;
use crate::economy::grace::{BidServer, BidStrategy, Broker as GraceBroker, Tender};
use crate::economy::market::{GraceConfig, MarketKind, PriceAgreement};
use crate::economy::reservation::{
    CommitLevel, Reservation, ReservationConfig, ReservationStore, ShadowPlan,
    ShadowSchedule,
};
use crate::economy::Ledger;
use crate::engine::journal::Journal;
use crate::engine::{Experiment, JobState};
use crate::grid::competition::{visible_slots, Competition};
use crate::grid::dynamics::{ResourceDyn, LOAD_UPDATE_PERIOD_S};
use crate::grid::gass::Gass;
use crate::grid::mds::{Mds, MDS_REFRESH_PERIOD_S};
use crate::grid::proxy::ClusterProxy;
use crate::grid::testbed::{local_hour, Testbed};
use crate::grid::JobManager;
use crate::metrics::{Report, ResourceUsage, TenantOutcome, WorldReport};
use crate::plan::JobSpec;
use crate::scheduler::dbc::reservation_candidate_sets;
use crate::scheduler::{
    guarded_window_h, CandidateIndex, ResourceView, ViewColumns,
    DEADLINE_SAFETY,
};
use crate::sim::pool::WorkerPool;
use crate::simtime::EventQueue;
use crate::types::{GridDollars, JobId, ResourceId, SimTime, HOUR};
use crate::util::rng::Rng;
use crate::workload::WorkSampler;
use std::collections::BTreeMap;

/// Bits of a GRAM-level job id reserved for the per-tenant job number;
/// the tenant index lives above them. Tenant 0's grid ids equal its engine
/// ids, which is what keeps the N = 1 world bit-identical to the legacy
/// single-tenant driver.
const TENANT_ID_SHIFT: u32 = 24;

/// Encode a tenant-local job id into the world-unique id shared GRAM
/// managers key on.
fn grid_jid(tid: usize, jid: JobId) -> JobId {
    JobId(((tid as u32) << TENANT_ID_SHIFT) | jid.0)
}

/// Decode a world-unique GRAM job id back into (tenant, local job).
fn split_jid(gid: JobId) -> (usize, JobId) {
    (
        (gid.0 >> TENANT_ID_SHIFT) as usize,
        JobId(gid.0 & ((1 << TENANT_ID_SHIFT) - 1)),
    )
}

/// Pseudo job id carrying one reservation's ledger envelope (the
/// worst-case cancellation penalty committed when the hold binds). These
/// ids live only inside per-tenant *ledgers*, where real job ids are
/// tenant-local engine ids below 2^24 (asserted in [`GridWorld::new`]) —
/// so the 0xFF prefix can never collide there, and the manager-namespace
/// grid ids (where tenant 255's jobs do carry an 0xFF prefix) never meet
/// a reservation id.
fn rsv_jid(rid: ResourceId) -> JobId {
    JobId(0xFF00_0000 | rid.0)
}

/// Simulation events. Per-tenant events carry the tenant index; grid-level
/// events (directory refresh, load drift, churn, background competition)
/// affect every tenant's view table.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Scheduler tick for one tenant (discovery → selection → dispatch).
    Tick { tid: u32 },
    /// Directory refresh.
    MdsRefresh,
    /// Background-load AR(1) step on all resources.
    LoadUpdate,
    /// Stage-in finished; hand the job to GRAM.
    StagedIn {
        tid: u32,
        rid: ResourceId,
        jid: JobId,
    },
    /// GRAM started the job (queue delay elapsed).
    BeginExec {
        tid: u32,
        rid: ResourceId,
        jid: JobId,
    },
    /// Execution + stage-out finished.
    Complete {
        tid: u32,
        rid: ResourceId,
        jid: JobId,
    },
    /// Availability churn.
    Fail { rid: ResourceId },
    Recover { rid: ResourceId },
    /// A background competing experiment lands on the grid (paper §3).
    CompetitorArrive,
    /// Background competing experiments holding until `now` leave.
    CompetitorDepart,
}

/// Per-in-flight-job bookkeeping.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    dispatched_at: SimTime,
    exec_started: Option<SimTime>,
    /// G$/CPU-second locked at execution start.
    rate: GridDollars,
    /// Work drawn for this job, reference CPU-hours.
    work_ref_h: f64,
    /// CPU seconds this job will consume on its machine.
    cpu_s: f64,
    /// Rate locked by the reservation slot this dispatch consumed, if any:
    /// execution start bills it even after the hold itself has closed.
    locked_rate: Option<GridDollars>,
}

/// Everything one co-scheduled experiment owns: a full Nimrod/G broker
/// instance (engine, economy, advisor, journal) plus its private
/// incremental view table over the shared grid.
pub struct Tenant {
    cfg: ExperimentConfig,
    exp: Experiment,
    ledger: Ledger,
    advisor: ScheduleAdvisor,
    sampler: WorkSampler,
    journal: Option<Journal>,
    inflight: BTreeMap<JobId, InFlight>,
    report: Report,
    busy_cpus: u32,
    /// Persistent per-resource view table (index = ResourceId). Entries
    /// are rebuilt only when marked dirty by a state-changing event.
    views: Vec<ResourceView>,
    view_dirty: Vec<bool>,
    dirty_queue: Vec<u32>,
    /// Persistent ranked candidate orderings over `views`, re-keyed in
    /// O(log R) for exactly the entries `refresh_dirty_views` rebuilds —
    /// policies allocate off these instead of sorting the table.
    index: CandidateIndex,
    /// Struct-of-arrays projection of the ranking-relevant `views` columns
    /// (rate/slots/speed/measured, dense by resource id). Written in the
    /// same breath as `views[i]` by the refresh, and what the index
    /// re-keys from ([`CandidateIndex::update_cols`]) so the hot path
    /// reads four dense arrays instead of striding view structs.
    cols: ViewColumns,
    /// Static per-resource authorization for `cfg.user`; unauthorized
    /// entries stay zeroed forever and are never marked.
    authorized: Vec<bool>,
    /// Authorized time-of-day-priced resources grouped by site, with the
    /// site's hour phase (start hour + tz offset) — the only quotes that
    /// move on their own, and only when the site's local clock crosses an
    /// integer hour.
    tod_by_site: Vec<(f64, Vec<u32>)>,
    /// Virtual time of this tenant's previous scheduler tick (repricing).
    last_tick_t: SimTime,
    /// Active GRACE price agreements by resource (index = ResourceId).
    /// All-`None` forever in posted-price worlds.
    agreements: Vec<Option<PriceAgreement>>,
    /// Earliest `valid_until` among active agreements (∞ when none), so the
    /// tick-time expiry sweep is O(1) until something is actually due.
    next_agreement_expiry: SimTime,
    /// Auction accounting for the world report.
    agreements_won: u32,
    negotiation_rounds: u64,
    deal_rounds: u64,
    failed_negotiations: u32,
    /// Advance-reservation holds (empty forever when the subsystem is off).
    rsv: ReservationStore,
    /// Recycled action buffer for this tenant's [`TenantShard`]: taken at
    /// shard construction, returned (drained, capacity intact) by the
    /// merge commit — batched ticks stop allocating a delta Vec per
    /// member per batch.
    merge_scratch: Vec<MergeAction>,
    /// Scratch for the bulk re-key path of `refresh_tenant_views`: the
    /// rids popped off `dirty_queue` this refresh, in pop order, handed to
    /// [`CandidateIndex::update_cols_bulk`] in one call.
    refresh_buf: Vec<u32>,
}

impl Tenant {
    /// Invalidate one resource's view entry (no-op for machines this user
    /// cannot schedule on, and for entries already queued for refresh).
    fn mark_view(&mut self, rid: ResourceId) {
        let i = rid.0 as usize;
        if i < self.view_dirty.len() && self.authorized[i] && !self.view_dirty[i]
        {
            self.view_dirty[i] = true;
            self.dirty_queue.push(rid.0);
        }
    }

    /// Mark time-of-day-priced entries whose site's local clock crossed an
    /// integer hour since this tenant's previous tick — the only instants
    /// owner quotes can change on their own (prices are piecewise-constant
    /// per local hour; demand premiums move only with marked occupancy
    /// events). Phase-aware, so fractional start hours and timezone offsets
    /// reprice exactly when the boundary passes, independent of the tick
    /// period or event ordering. O(sites with time-of-day pricing) per tick.
    // lint:allow(DIRTY-PAIR): queues views only — GridWorld::refresh_dirty_views re-keys the index the same tick
    fn mark_repriced(&mut self, now: SimTime) {
        let prev = self.last_tick_t;
        self.last_tick_t = now;
        if self.tod_by_site.is_empty() || now == prev {
            return;
        }
        let sites = std::mem::take(&mut self.tod_by_site);
        for (theta, rids) in &sites {
            if (theta + now / 3600.0).floor() > (theta + prev / 3600.0).floor()
            {
                for &r in rids {
                    self.mark_view(ResourceId(r));
                }
            }
        }
        self.tod_by_site = sites;
    }

    /// Drop agreements whose validity ended at or before `now`, marking the
    /// affected view entries so pricing reverts to posted rates. Runs at
    /// tick start; O(1) until an expiry is actually due, then O(resources)
    /// for that one sweep. Billing paths never consult an expired agreement
    /// regardless ([`PriceAgreement::active`] is checked at use), so a
    /// mid-sweep lapse can at worst leave one tick scheduling on a price
    /// that just expired — the same staleness window posted quotes already
    /// have between directory refreshes.
    // lint:allow(DIRTY-PAIR): queues views only — GridWorld::refresh_dirty_views re-keys the index the same tick
    fn expire_agreements(&mut self, now: SimTime) {
        if now < self.next_agreement_expiry {
            return;
        }
        let mut next = SimTime::INFINITY;
        for i in 0..self.agreements.len() {
            let Some(a) = self.agreements[i] else {
                continue;
            };
            if a.active(now) {
                next = next.min(a.valid_until);
            } else {
                self.agreements[i] = None;
                self.mark_view(ResourceId(i as u32));
            }
        }
        self.next_agreement_expiry = next;
    }
}

/// Read-only snapshot of the shared world state published to the parallel
/// per-tenant phase of a batched tick (phase 2 of the three-phase pipeline
/// — see the module docs). Everything here is borrowed immutably from the
/// world, so any number of workers can consume it concurrently while each
/// owns a disjoint `&mut Tenant`; shared-state *mutation* belongs to the
/// sequential snapshot (phase 1) and merge (phase 3) phases, a discipline
/// the `PAR-SHARED` lint rule checks statically on `lint:par-section`
/// functions.
struct WorldView<'w> {
    now: SimTime,
    tb: &'w Testbed,
    mds: &'w Mds,
    dyns: &'w [ResourceDyn],
    managers: &'w [JobManager],
    competition: Option<&'w Competition>,
    total_in_flight: &'w [u32],
    total_reserved: &'w [u32],
    start_utc_hour: f64,
    full_rebuild: bool,
    full_alloc_sort: bool,
}

/// The frozen-input half of one pending submit, computed in the parallel
/// phase so the merge barrier only finishes the live half. Everything
/// here is constant across the whole merge: posted quotes and competition
/// premiums move only with marked events, agreements and effective speeds
/// are untouched by merge submits, and the per-job work draw is a pure
/// function of (sampler seed, job id). What *cannot* be precomputed — the
/// demand premium (earlier merge submits raise utilization) and the
/// committed-hold rate override (an earlier submit by the same tenant can
/// consume the hold's last slot and close it) — stays in
/// [`merge_submit_prepared`]. (Ledger-line spec names are borrowed from
/// the testbed at commit time, so nothing here is heap-allocated.)
struct PreparedSubmit {
    /// Posted per-user quote × background-competition premium; the live
    /// demand premium multiplies this at merge time, in the same
    /// left-to-right order `effective_rate` always used.
    posted_x_comp: GridDollars,
    /// Live GRACE agreement rate at tick time, if the tenant won one
    /// (merge submits never create or expire agreements).
    agreement_rate: Option<GridDollars>,
    /// Effective speed under current background load, floored like every
    /// cost estimate (`LoadUpdate` is a separate event, never mid-merge).
    speed: f64,
    /// The job's true work draw — pure in (sampler seed, job id).
    work_ref_h: f64,
}

/// One entry of a shard's merge delta: a dispatcher [`Action`] with the
/// frozen-input half of a submit already attached.
enum MergeAction {
    Submit {
        job: JobId,
        rid: ResourceId,
        prep: PreparedSubmit,
    },
    CancelQueued {
        job: JobId,
        rid: ResourceId,
    },
}

/// One batch member's slice of the parallel phase: the tenant it owns
/// exclusively, its pre-drawn RNG sub-stream (forked from the world RNG in
/// ascending tenant order during phase 1, so the world stream advances
/// identically at every thread count), and the delta it produces — the
/// prepared actions the merge barrier will apply in ascending tenant
/// order.
struct TenantShard<'t> {
    tid: usize,
    tenant: &'t mut Tenant,
    rng: Rng,
    actions: Vec<MergeAction>,
    job_work: f64,
}

/// Dirty-queue size at which `refresh_tenant_views` switches from
/// per-entry `update_cols` re-keys to one [`CandidateIndex::update_cols_bulk`]
/// sweep over the collected rids. Below this, chunk setup costs more than
/// it saves; at or above it (MDS refreshes, repricing sweeps, agreement
/// expiries — anything that dirties many views at once), the bulk path's
/// fixed-width column loops win. Keys are bit-identical either way (both
/// paths share the `_parts` helpers), so this is purely a throughput knob.
const BULK_REKEY_MIN: usize = 8;

/// Rebuild every dirty view entry of one tenant from its sources: the
/// (stale) MDS record, GRAM slots net of competition claims and other
/// tenants' occupancy, the demand-adjusted quote, the tenant engine's
/// in-flight count and its advisor's measured service rate. Every rebuilt
/// entry is re-keyed in the tenant's candidate index (O(log R)) — inline
/// for small refreshes, deferred into one chunked
/// [`CandidateIndex::update_cols_bulk`] sweep when ≥ [`BULK_REKEY_MIN`]
/// entries are dirty (the rebuild loop never reads the index, so moving
/// the re-keys after it is state-identical) — keeping the ranked
/// orderings policies allocate from in lockstep with the table. Cost is
/// O(dirty · log R); the pre-incremental pipeline paid O(resources) here
/// every tick. Reads shared state only through the frozen snapshot and
/// writes only tenant-local state, so the parallel phase runs it on
/// disjoint tenants concurrently.
// lint:par-section
fn refresh_tenant_views(wv: &WorldView<'_>, tenant: &mut Tenant) {
    if wv.full_rebuild {
        let n = tenant.views.len();
        for i in 0..n {
            tenant.mark_view(ResourceId(i as u32));
        }
    }
    let bulk = tenant.dirty_queue.len() >= BULK_REKEY_MIN;
    if bulk {
        tenant.refresh_buf.clear();
    }
    let now = wv.now;
    while let Some(r) = tenant.dirty_queue.pop() {
        let i = r as usize;
        tenant.view_dirty[i] = false;
        let rid = ResourceId(r);
        // lint:allow(PANIC-BUDGET): Mds::new builds one record per testbed resource and never removes any
        let rec = wv.mds.record(rid).expect("record for every resource");
        let planning_speed = rec.planning_speed();
        let batch_queue = rec.batch_queue;
        let spec = wv.tb.spec(rid);
        let own = tenant.exp.in_flight_on(rid);
        let foreign = wv.total_in_flight[i].saturating_sub(own);
        // Foreign-only, like in-flight: the holder keeps seeing its own
        // held slots — they are exactly what it dispatches into.
        let foreign_rsv =
            wv.total_reserved[i].saturating_sub(tenant.rsv.held_on(rid));
        let quote = posted_quote(
            wv.tb,
            wv.start_utc_hour,
            now,
            &tenant.cfg.user,
            rid,
        );
        let base_slots = wv.managers[i].slots();
        let (slots, rate) = match wv.competition {
            Some(comp) => (
                comp.free_slots(wv.tb, rid, base_slots, foreign, foreign_rsv),
                quote * comp.demand_premium(wv.tb, rid),
            ),
            None => (
                visible_slots(base_slots, spec.cpus, 0, foreign, foreign_rsv),
                quote,
            ),
        };
        let claimed = wv.competition.map(|c| c.claimed(rid)).unwrap_or(0);
        let util = utilization_of(
            wv.total_in_flight[i],
            claimed,
            wv.total_reserved[i],
            spec.cpus,
        );
        let rate = rate * spec.price.demand_premium(util);
        // A live GRACE agreement overrides the posted/premium quote:
        // DBC schedules against the price the tenant actually won.
        let rate = match tenant.agreements[i] {
            Some(a) if a.active(now) => a.rate,
            _ => rate,
        };
        // A live committed hold locks the rate harder still: dispatches
        // into it bill at the reservation's locked rate.
        let rate = match tenant.rsv.get(rid) {
            Some(r) if r.level == CommitLevel::Committed && r.active(now) => {
                r.rate
            }
            _ => rate,
        };
        tenant.views[i] = ResourceView {
            id: rid,
            slots,
            planning_speed,
            rate,
            in_flight: own,
            measured_jphps: tenant.advisor.measured_jphps(rid),
            batch_queue,
        };
        // Project into the dense columns and re-key from them: the index
        // touch reads 25 contiguous-array bytes instead of striding the
        // view structs. Same keys to the last bit (`update_cols` shares
        // the `_parts` key helpers with `update`; unit-proven in
        // scheduler::index and audited by `consistent_with` below). Large
        // refreshes collect their rids instead and re-key once, below.
        tenant.cols.set(&tenant.views[i]);
        if bulk {
            tenant.refresh_buf.push(r);
        } else {
            tenant.index.update_cols(rid, &tenant.cols);
        }
        tenant.report.view_refreshes += 1;
    }
    if bulk {
        tenant.index.update_cols_bulk(&tenant.refresh_buf, &tenant.cols);
    }
}

/// Pre-compute the frozen-input half of one pending submit (see
/// [`PreparedSubmit`] for the frozen/live split). Reads shared state only
/// through the snapshot and the shard's own tenant, so the parallel phase
/// runs it concurrently per shard; the merge barrier finishes the rate
/// from the live demand signal in [`GridWorld::submit_prepared`].
// lint:par-section
fn prepare_submit(
    wv: &WorldView<'_>,
    tenant: &Tenant,
    jid: JobId,
    rid: ResourceId,
) -> PreparedSubmit {
    let i = rid.0 as usize;
    let quote =
        posted_quote(wv.tb, wv.start_utc_hour, wv.now, &tenant.cfg.user, rid);
    let comp_premium = wv
        .competition
        .map(|c| c.demand_premium(wv.tb, rid))
        .unwrap_or(1.0);
    let agreement_rate = match tenant.agreements[i] {
        Some(a) if a.active(wv.now) => Some(a.rate),
        _ => None,
    };
    let spec = wv.tb.spec(rid);
    PreparedSubmit {
        posted_x_comp: quote * comp_premium,
        agreement_rate,
        speed: wv.dyns[i].effective_speed(spec).max(0.05),
        work_ref_h: tenant.sampler.work_ref_h(jid),
    }
}

/// Phase 2 of the batched tick for one batch member: refresh the tenant's
/// views against the frozen snapshot, audit the index (debug builds), run
/// the sort-every-tick baseline re-rank if configured, and let the policy
/// allocate off the pre-drawn RNG sub-stream. Produces the shard's action
/// delta; nothing shared is touched — the merge barrier applies the delta
/// in ascending tenant order afterwards.
// lint:par-section
fn tick_tenant_shard(wv: &WorldView<'_>, shard: &mut TenantShard<'_>) {
    let tenant = &mut *shard.tenant;
    refresh_tenant_views(wv, tenant);
    // Index-consistency audit (debug builds): the same runtime cross-check
    // of the DIRTY-PAIR discipline the sequential path runs. Small worlds
    // every tick, index-storm-sized worlds sampled.
    #[cfg(debug_assertions)]
    {
        if tenant.views.len() <= 4096 || tenant.report.ticks % 64 == 1 {
            if let Err(e) = tenant.index.consistent_with(&tenant.views) {
                panic!(
                    "tenant {} index audit failed at t={}: {e}",
                    shard.tid, wv.now
                );
            }
        }
    }
    shard.job_work = tenant.advisor.job_work_ref_h();
    // lint:allow(ND-CLOCK): alloc_ns is wall-clock telemetry about the allocator itself; it never feeds sim state
    let alloc_t0 = std::time::Instant::now();
    if wv.full_alloc_sort {
        // Sort-every-tick baseline: throw the incremental rankings away
        // and re-derive them all (bit-identical state, O(R log R) cost).
        tenant.index.rebuild_from(&tenant.views);
    }
    let actions = tenant.advisor.advise(
        TickCtx {
            now: wv.now,
            deadline: tenant.exp.deadline,
            budget_headroom: tenant.ledger.headroom(),
            views: &tenant.views,
            candidates: &tenant.index,
        },
        &tenant.exp,
        &mut shard.rng,
    );
    tenant.report.alloc_ns += alloc_t0.elapsed().as_nanos() as u64;
    // Hoist the frozen-input half of every pending submit out of the
    // merge commit: pricing lookups, agreement checks, speed reads and
    // work draws all run here, in parallel, leaving the commit queue only
    // the ordered capacity-guarded parts. Extends the shard's recycled
    // scratch buffer (taken from the tenant at shard construction, handed
    // back by the commit) so steady-state batches allocate nothing here.
    shard.actions.extend(actions.into_iter().map(|a| match a {
        Action::Submit { job, rid } => MergeAction::Submit {
            job,
            rid,
            prep: prepare_submit(wv, tenant, job, rid),
        },
        Action::CancelQueued { job, rid } => {
            MergeAction::CancelQueued { job, rid }
        }
    }));
}

/// The mutable slice of world state a phase-3 commit touches, split out
/// of [`GridWorld`] so the streaming ordered merge can apply deltas while
/// phase-2 shards still hold `&mut` borrows of the *tenants* vector.
/// Field borrows are disjoint by construction: commits mutate the live
/// occupancy tallies, the billing transports and the event queue; shards
/// own their single `Tenant` and read everything shared through the
/// frozen [`WorldView`] (whose occupancy columns point at per-batch
/// snapshot copies, not these live arrays). The two cross-tenant effects
/// a commit cannot apply while shards run — `mark_view_all` dirtying and
/// GRAM cancel withdrawals — are deferred into `marks`/`gram_cancels` in
/// commit order and replayed by [`GridWorld::drain_merge_buffers`] after
/// the shards drop.
struct MergeCtx<'a> {
    now: SimTime,
    tb: &'a Testbed,
    competition: Option<&'a Competition>,
    total_in_flight: &'a mut Vec<u32>,
    total_reserved: &'a mut Vec<u32>,
    gass: &'a mut Gass,
    proxy: &'a mut ClusterProxy,
    q: &'a mut EventQueue<Ev>,
    marks: &'a mut Vec<ResourceId>,
    gram_cancels: &'a mut Vec<(ResourceId, JobId)>,
}

impl MergeCtx<'_> {
    /// Live demand signal at commit time (mirrors
    /// [`GridWorld::utilization`]) — earlier commits in the same batch
    /// have already moved the tallies, which is exactly why the demand
    /// premium cannot be precomputed in phase 2.
    fn utilization(&self, rid: ResourceId) -> f64 {
        let claimed =
            self.competition.map(|c| c.claimed(rid)).unwrap_or(0);
        utilization_of(
            self.total_in_flight[rid.0 as usize],
            claimed,
            self.total_reserved[rid.0 as usize],
            self.tb.spec(rid).cpus,
        )
    }

    /// Mirrors [`GridWorld::dec_total_in_flight`] for merge commits.
    fn dec_in_flight(&mut self, rid: ResourceId) {
        let c = &mut self.total_in_flight[rid.0 as usize];
        debug_assert!(*c > 0, "world in-flight underflow on {rid}");
        *c = c.saturating_sub(1);
    }
}

/// Merge-phase capacity guard. Batch members decide against the same
/// frozen snapshot, so their combined submits can oversubscribe a machine
/// that looked free to each of them individually. A submit is admitted
/// when ground truth still has an unclaimed CPU — or when the tenant
/// holds a live committed reservation slot there (dispatching consumes
/// the hold, so occupancy is net unchanged). A deferred job stays Ready
/// and is retried at the tenant's next tick, exactly like a refused
/// budget commit. Earlier tenants win contended last slots — the same
/// deterministic ascending-tenant order the sequential cascade always
/// gave them; the commit queue preserves it whether commits stream under
/// phase 2 or drain behind the barrier. Reads only the live tallies,
/// never the GRAM managers — which is what makes deferring cancel
/// withdrawals to the post-batch replay invisible to admission.
fn merge_submit_ok(
    ctx: &MergeCtx<'_>,
    tenant: &Tenant,
    rid: ResourceId,
) -> bool {
    let i = rid.0 as usize;
    if let Some(r) = tenant.rsv.get(rid) {
        if r.level == CommitLevel::Committed
            && r.active(ctx.now)
            && r.slots > 0
        {
            return true;
        }
    }
    let claimed = ctx.competition.map(|c| c.claimed(rid)).unwrap_or(0);
    ctx.total_in_flight[i] + claimed + ctx.total_reserved[i]
        < ctx.tb.spec(rid).cpus
}

/// The live, order-dependent half of a submit — the only submit work left
/// in the phase-3 commit. Finishes the effective rate from ground truth
/// (committed-hold override, then the agreement the shard looked up, then
/// posted × competition × *live* demand premium — earlier commits move
/// utilization and can consume holds, which is exactly why these two
/// reads cannot be hoisted), then commits budget, dispatches, and
/// schedules stage-in. Cross-tenant view marks are deferred into
/// `ctx.marks` (see [`MergeCtx`]).
fn merge_submit_prepared(
    ctx: &mut MergeCtx<'_>,
    tenant: &mut Tenant,
    tid: usize,
    jid: JobId,
    rid: ResourceId,
    job_work: f64,
    prep: PreparedSubmit,
) {
    let now = ctx.now;
    // Budget commit against the expected cost here. Rate precedence
    // matches `effective_rate`: committed hold, then agreement, then
    // posted quote under the live demand premium.
    let rate = match tenant.rsv.get(rid) {
        Some(r) if r.level == CommitLevel::Committed && r.active(now) => {
            r.rate
        }
        _ => match prep.agreement_rate {
            Some(a) => a,
            None => {
                prep.posted_x_comp
                    * ctx
                        .tb
                        .spec(rid)
                        .price
                        .demand_premium(ctx.utilization(rid))
            }
        },
    };
    let PreparedSubmit { speed, work_ref_h, .. } = prep;
    let name = &ctx.tb.spec(rid).name;
    let est_cost = rate * job_work / speed * 3600.0;
    if !tenant.ledger.commit(jid, est_cost) {
        return; // budget headroom exhausted: leave the job Ready
    }
    if tenant.exp.dispatch(jid, rid, now).is_err() {
        tenant.ledger.release(jid, 0.0, name);
        return;
    }
    if let Some(j) = &mut tenant.journal {
        let _ = j.dispatched(jid, rid, now);
    }
    // Dispatching onto a machine the tenant holds a committed
    // reservation on consumes one held slot at its locked rate; the
    // rate rides the in-flight record so execution start still bills
    // it after the hold itself has closed.
    let mut locked_rate = None;
    if let Some(c) = tenant.rsv.consume_slot(rid, now) {
        locked_rate = Some(c.rate);
        ctx.total_reserved[rid.0 as usize] =
            ctx.total_reserved[rid.0 as usize].saturating_sub(1);
        if c.closed {
            // Every slot was used: refund the penalty envelope whole.
            tenant.ledger.release(rsv_jid(rid), 0.0, name);
            if let Some(j) = &mut tenant.journal {
                let _ = j.reservation_closed(rid);
            }
        }
    }
    tenant.inflight.insert(
        jid,
        InFlight {
            dispatched_at: now,
            exec_started: None,
            rate: 0.0,
            work_ref_h,
            cpu_s: 0.0,
            locked_rate,
        },
    );
    ctx.total_in_flight[rid.0 as usize] += 1;
    ctx.marks.push(rid); // occupancy changed for everyone (replayed post-batch)
    // Stage-in through GASS (and the cluster proxy if private).
    let input_bytes = tenant.cfg.workload.input_bytes;
    let t_stage =
        ctx.proxy
            .begin(ctx.gass, ctx.tb, ctx.tb.spec(rid), input_bytes);
    ctx.q.schedule_in(
        t_stage,
        Ev::StagedIn {
            tid: tid as u32,
            rid,
            jid,
        },
    );
}

/// Commit half of a queued-job cancellation. The GRAM withdrawal is
/// deferred into `ctx.gram_cancels`: still-running shards read manager
/// slot counts through the frozen snapshot semantics, and the capacity
/// guard never consults managers, so replaying withdrawals post-batch (in
/// commit order) leaves every admission decision and the end-of-batch
/// manager state byte-identical to the inline call.
fn merge_cancel_queued(
    ctx: &mut MergeCtx<'_>,
    tenant: &mut Tenant,
    tid: usize,
    jid: JobId,
    rid: ResourceId,
) {
    // Withdraw from GRAM if it got there (deferred; see above) —
    // mid-stage-in jobs are caught at their StagedIn event by the state
    // check.
    ctx.gram_cancels.push((rid, grid_jid(tid, jid)));
    let name = &ctx.tb.spec(rid).name;
    tenant.ledger.release(jid, 0.0, name);
    if tenant.exp.release(jid).is_ok() {
        if let Some(j) = &mut tenant.journal {
            let _ = j.released(jid);
        }
        ctx.dec_in_flight(rid);
        ctx.marks.push(rid); // occupancy changed for everyone
    }
    tenant.inflight.remove(&jid);
}

/// Apply one finished shard's delta — the commit-queue body shared by
/// every phase-3 mode (streaming, barrier, sequential `threads(1)`):
/// capacity-guarded submits and cancellations in action order, then the
/// member's next tick rescheduled. Returns the drained action buffer to
/// the tenant as recycled scratch for its next shard.
fn commit_shard(ctx: &mut MergeCtx<'_>, shard: &mut TenantShard<'_>) {
    let tid = shard.tid;
    let job_work = shard.job_work;
    for action in shard.actions.drain(..) {
        match action {
            MergeAction::Submit { job, rid, prep } => {
                if merge_submit_ok(ctx, shard.tenant, rid) {
                    merge_submit_prepared(
                        ctx,
                        shard.tenant,
                        tid,
                        job,
                        rid,
                        job_work,
                        prep,
                    );
                }
            }
            MergeAction::CancelQueued { job, rid } => {
                merge_cancel_queued(ctx, shard.tenant, tid, job, rid)
            }
        }
    }
    shard.tenant.merge_scratch = std::mem::take(&mut shard.actions);
    if !shard.tenant.exp.finished() {
        let period = shard.tenant.cfg.tick_period_s;
        ctx.q.schedule_in(period, Ev::Tick { tid: tid as u32 });
    }
}

/// One tenant's construction inputs for [`GridWorld::new`].
pub struct TenantSetup {
    /// Envelope + identity. `competition` and `start_utc_hour` are
    /// world-level: only tenant 0's are honoured.
    pub cfg: ExperimentConfig,
    pub specs: Vec<JobSpec>,
    pub advisor: ScheduleAdvisor,
}

/// The shared world: grid state + event queue + N tenants. Construct with
/// [`GridWorld::new`] (or through
/// [`crate::broker::ExperimentBuilder::world`]), run with
/// [`GridWorld::run_world`].
pub struct GridWorld {
    pub tb: Testbed,
    dyns: Vec<ResourceDyn>,
    mds: Mds,
    gass: Gass,
    proxy: ClusterProxy,
    managers: Vec<JobManager>,
    tenants: Vec<Tenant>,
    q: EventQueue<Ev>,
    /// World RNG: seeds dynamics/churn and serves every tenant's policy —
    /// one stream, so the N = 1 world draws exactly like the legacy driver.
    rng: Rng,
    /// Background competing-experiment process, if configured.
    competition: Option<Competition>,
    /// Per-resource total in-flight jobs across all tenants (index =
    /// ResourceId), maintained in lockstep with the engines' transitions.
    /// This is what makes foreign-occupancy lookups O(1) inside the
    /// O(changed) view refresh.
    total_in_flight: Vec<u32>,
    /// UTC hour-of-day at world start (tenant 0's; drives all pricing).
    start_utc_hour: f64,
    /// Stop even if jobs remain (budget exhaustion, dead grid).
    hard_stop: SimTime,
    /// Benchmark baseline: rebuild every entry on every tick.
    full_rebuild: bool,
    /// Benchmark baseline: re-rank every tenant's whole candidate index
    /// from its views on every tick (the sort-every-tick allocation
    /// baseline) instead of re-keying only dirtied entries.
    full_alloc_sort: bool,
    /// Mean posted effective rate across up machines (base quote ×
    /// competition premium × demand premium), sampled at each directory
    /// refresh — the cross-tenant price trajectory.
    price_index: Vec<(SimTime, f64)>,
    /// Highest combined premium factor observed at any sample.
    peak_premium: f64,
    /// GRACE auction market, if the world runs one (tenant 0's
    /// `cfg.market`; world-level like competition). `None` = posted-price,
    /// bit-exact with the pre-market pipeline.
    market: Option<GraceConfig>,
    /// Mean awarded rate per auction sweep that produced agreements.
    clearing_prices: Vec<(SimTime, f64)>,
    /// Advance-reservation subsystem, if the world runs one (tenant 0's
    /// `cfg.reservations`; world-level like the market). `None` = inert,
    /// bit-exact with the pre-reservation pipeline.
    reservations: Option<ReservationConfig>,
    /// Per-resource slots held by reservations across all tenants (index =
    /// ResourceId), maintained in lockstep with every hold transition —
    /// the third term of the slot-conservation invariant.
    total_reserved: Vec<u32>,
    /// Worker threads for the parallel per-tenant phase of batched ticks.
    /// 1 (the default) runs the identical three-phase pipeline on the
    /// caller thread — the proven-bit-exact reference path.
    threads: usize,
    /// Persistent worker pool for phase 2, created lazily at the first
    /// batch that can use one (`threads > 1` and ≥ 2 tenants) and reused
    /// for every batch after — dropping the world joins its threads.
    /// Stays `None` forever on sequential worlds and under
    /// `set_scoped_spawn`.
    pool: Option<WorkerPool>,
    /// Benchmark baseline: spawn scoped threads per batch (the PR-8
    /// behaviour) instead of using the persistent pool. Bit-identical
    /// traces; only spawn overhead differs.
    scoped_spawn: bool,
    /// Comparison baseline: drain the whole phase-3 commit queue behind a
    /// hard barrier (the PR-9 behaviour) instead of streaming commits
    /// under phase 2. Bit-identical traces; only overlap differs.
    barrier_merge: bool,
    /// Wall-clock phase telemetry for the batched tick (see the
    /// [`crate::metrics::WorldReport`] fields of the same names): never
    /// read by the simulation, excluded from bit-exact comparisons.
    snapshot_ns: u64,
    parallel_ns: u64,
    merge_ns: u64,
    /// Merge wall-time that ran while phase-2 shards were still in flight
    /// (streaming mode only; always 0 under the barrier).
    merge_overlap_ns: u64,
    /// Batches fanned out through the persistent pool (telemetry).
    pool_rounds: u64,
    /// Per-batch scratch, reused across batches so the batched tick is
    /// allocation-stable at steady state (`scratch_regrows` counts the
    /// exceptions): frozen occupancy copies published to phase-2 shards
    /// (`snap_*`), the live member list / flags / forked sub-RNGs, and
    /// the commit-ordered deferred-effect buffers drained by
    /// `drain_merge_buffers`.
    snap_in_flight: Vec<u32>,
    snap_reserved: Vec<u32>,
    member_buf: Vec<usize>,
    member_flag_buf: Vec<bool>,
    rng_buf: Vec<Rng>,
    mark_buf: Vec<ResourceId>,
    cancel_buf: Vec<(ResourceId, JobId)>,
    /// Times any per-batch scratch buffer grew past its previously
    /// observed (nonzero) capacity — a debug-visible allocation-stability
    /// counter; small after warm-up by construction.
    scratch_regrows: u64,
    /// Previously observed capacities of (member, mark, cancel) scratch.
    scratch_caps: [usize; 3],
}

impl GridWorld {
    /// Build a world over `tb` hosting one tenant per [`TenantSetup`].
    /// Panics on empty tenant lists, more than 256 tenants, or a tenant
    /// with ≥ 2^24 jobs (the GRAM id-space partition; see [`rsv_jid`] for
    /// why the full 2^8 tenant range is collision-free).
    // lint:allow(DIRTY-PAIR): construction seeds the dirty queue; the first refresh_dirty_views builds the index
    pub fn new(tb: Testbed, setups: Vec<TenantSetup>) -> GridWorld {
        assert!(!setups.is_empty(), "a world needs at least one tenant");
        assert!(
            setups.len() <= (1 << (32 - TENANT_ID_SHIFT)),
            "at most {} tenants per world",
            1 << (32 - TENANT_ID_SHIFT)
        );
        let world_seed = setups[0].cfg.seed;
        let start_utc_hour = setups[0].cfg.start_utc_hour;
        let competition_model = setups[0].cfg.competition.clone();
        let market = match setups[0].cfg.market.clone() {
            MarketKind::PostedPrice => None,
            MarketKind::GraceAuction(cfg) => Some(cfg),
        };
        let reservations = setups[0].cfg.reservations.clone();
        let mut rng = Rng::new(world_seed);
        let dyns: Vec<ResourceDyn> = tb
            .resources
            .iter()
            .map(|s| ResourceDyn::new(s, &mut rng))
            .collect();
        let mds = Mds::new(&tb, &dyns);
        let managers: Vec<JobManager> =
            tb.resources.iter().map(JobManager::new).collect();
        let gass = Gass::new(&tb);
        let n = tb.resources.len();

        let mut tenants: Vec<Tenant> = Vec::with_capacity(setups.len());
        let mut hard_stop: SimTime = 0.0;
        for (tid, setup) in setups.into_iter().enumerate() {
            let TenantSetup { cfg, specs, advisor } = setup;
            assert!(
                specs.len() < (1 << TENANT_ID_SHIFT) as usize,
                "tenant {tid} has too many jobs for the GRAM id space"
            );
            let jobs_total = specs.len() as u32;
            let exp = Experiment::new(
                specs,
                cfg.deadline,
                cfg.budget,
                &cfg.user,
                cfg.max_attempts,
            );
            let ledger = Ledger::new(cfg.budget);
            // Tenant 0 draws per-job work exactly like the legacy driver;
            // later tenants perturb the stream by index so co-tenants with
            // equal seeds still draw independent workloads.
            let sampler_seed = cfg.seed
                ^ 0xF00D
                ^ (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let sampler = WorkSampler::new(&cfg.workload, sampler_seed);
            let authorized: Vec<bool> = tb
                .resources
                .iter()
                .map(|r| r.auth.allows(&cfg.user))
                .collect();
            let mut tod_per_site: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
            for r in &tb.resources {
                if authorized[r.id.0 as usize] && r.price.time_of_day {
                    tod_per_site.entry(r.site.0).or_default().push(r.id.0);
                }
            }
            let tod_by_site: Vec<(f64, Vec<u32>)> = tod_per_site
                .into_iter()
                .map(|(sid, rids)| {
                    let theta =
                        start_utc_hour + tb.sites[sid as usize].tz_offset_hours;
                    (theta, rids)
                })
                .collect();
            let views: Vec<ResourceView> = tb
                .resources
                .iter()
                .map(|r| ResourceView {
                    id: r.id,
                    slots: 0,
                    planning_speed: 0.0,
                    rate: 0.0,
                    in_flight: 0,
                    measured_jphps: None,
                    batch_queue: false,
                })
                .collect();
            hard_stop = hard_stop.max(cfg.deadline * 4.0 + 48.0 * HOUR);
            tenants.push(Tenant {
                report: Report {
                    jobs_total,
                    deadline_s: cfg.deadline,
                    ..Default::default()
                },
                cfg,
                exp,
                ledger,
                advisor,
                sampler,
                journal: None,
                inflight: BTreeMap::new(),
                busy_cpus: 0,
                views,
                view_dirty: vec![false; n],
                dirty_queue: Vec::with_capacity(n),
                index: CandidateIndex::new(n),
                cols: ViewColumns::new(n),
                authorized,
                tod_by_site,
                last_tick_t: 0.0,
                agreements: vec![None; n],
                next_agreement_expiry: SimTime::INFINITY,
                agreements_won: 0,
                negotiation_rounds: 0,
                deal_rounds: 0,
                failed_negotiations: 0,
                rsv: ReservationStore::new(n),
                merge_scratch: Vec::new(),
                refresh_buf: Vec::new(),
            });
        }

        let mut q = EventQueue::new();
        for tid in 0..tenants.len() {
            q.schedule_at(0.0, Ev::Tick { tid: tid as u32 });
        }
        q.schedule_at(MDS_REFRESH_PERIOD_S, Ev::MdsRefresh);
        q.schedule_at(LOAD_UPDATE_PERIOD_S, Ev::LoadUpdate);
        let competition = competition_model
            .map(|model| Competition::new(&tb, model, rng.fork(0xC0117E7E)));
        if competition.is_some() {
            q.schedule_at(1.0, Ev::CompetitorArrive);
        }

        let mut world = GridWorld {
            tb,
            dyns,
            mds,
            gass,
            proxy: ClusterProxy::default(),
            managers,
            tenants,
            q,
            rng,
            competition,
            total_in_flight: vec![0; n],
            start_utc_hour,
            hard_stop,
            full_rebuild: false,
            full_alloc_sort: false,
            price_index: Vec::new(),
            peak_premium: 1.0,
            market,
            clearing_prices: Vec::new(),
            reservations,
            total_reserved: vec![0; n],
            threads: 1,
            pool: None,
            scoped_spawn: false,
            barrier_merge: false,
            snapshot_ns: 0,
            parallel_ns: 0,
            merge_ns: 0,
            merge_overlap_ns: 0,
            pool_rounds: 0,
            snap_in_flight: Vec::new(),
            snap_reserved: Vec::new(),
            member_buf: Vec::new(),
            member_flag_buf: Vec::new(),
            rng_buf: Vec::new(),
            mark_buf: Vec::new(),
            cancel_buf: Vec::new(),
            scratch_regrows: 0,
            scratch_caps: [0; 3],
        };
        // Seed availability churn per resource.
        for i in 0..world.tb.resources.len() {
            let spec = world.tb.resources[i].clone();
            let t = world.dyns[i].draw_uptime(&spec);
            world.q.schedule_at(t, Ev::Fail { rid: spec.id });
        }
        // Everything schedulable starts dirty; each tenant's first tick
        // fills its table from the t = 0 directory snapshot.
        for tenant in &mut world.tenants {
            for i in 0..n {
                tenant.mark_view(ResourceId(i as u32));
            }
        }
        world.sample_price_index(0.0);
        world
    }

    // -- accessors -----------------------------------------------------------

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.q.now()
    }

    /// Number of co-scheduled tenants.
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// One tenant's experiment engine.
    pub fn exp(&self, tid: usize) -> &Experiment {
        &self.tenants[tid].exp
    }

    /// One tenant's spend ledger.
    pub fn ledger(&self, tid: usize) -> &Ledger {
        &self.tenants[tid].ledger
    }

    /// One tenant's configuration.
    pub fn tenant_cfg(&self, tid: usize) -> &ExperimentConfig {
        &self.tenants[tid].cfg
    }

    /// Number of tenant `tid`'s recorded GRACE agreements still in force
    /// at `now` (always 0 in posted-price worlds) — time-explicit so
    /// callers between events can ask about a specific instant.
    pub fn active_agreements_at(&self, tid: usize, now: SimTime) -> usize {
        self.tenants[tid]
            .agreements
            .iter()
            .filter(|a| matches!(a, Some(a) if a.active(now)))
            .count()
    }

    /// One tenant's advance-reservation hold table (empty forever when the
    /// subsystem is off).
    pub fn reservations_of(&self, tid: usize) -> &ReservationStore {
        &self.tenants[tid].rsv
    }

    /// Attach a persistence journal to one tenant (restart support).
    pub fn attach_journal(&mut self, tid: usize, journal: Journal) {
        self.tenants[tid].journal = Some(journal);
    }

    /// Replace one tenant's experiment (restart-from-journal path) and
    /// re-derive the world occupancy tables from every tenant's counters.
    /// The restarted tenant's reservation holds were already released at
    /// recovery (the journal surfaces them as
    /// [`crate::engine::journal::RecoveredReservation`]s), so its hold
    /// table restarts empty and the shared reserved occupancy is re-summed
    /// from the tenants that kept running.
    pub fn replace_experiment(&mut self, tid: usize, exp: Experiment) {
        self.tenants[tid].report.jobs_total = exp.jobs.len() as u32;
        self.tenants[tid].exp = exp;
        let n = self.tb.resources.len();
        self.tenants[tid].rsv = ReservationStore::new(n);
        self.total_in_flight = vec![0; n];
        self.total_reserved = vec![0; n];
        for t in &self.tenants {
            for (i, &c) in t.exp.in_flight_counts().iter().enumerate() {
                if i < n {
                    self.total_in_flight[i] += c;
                }
            }
            for i in 0..n {
                self.total_reserved[i] += t.rsv.held_on(ResourceId(i as u32));
            }
        }
    }

    /// Benchmark support: rebuild each tenant's whole view table on every
    /// one of its ticks (the pre-incremental behaviour) instead of only
    /// dirty entries. The resulting trace is bit-identical — entries just
    /// get recomputed to the same values many more times.
    pub fn set_full_view_rebuild(&mut self, on: bool) {
        self.full_rebuild = on;
    }

    /// Benchmark support: re-derive each tenant's entire candidate index
    /// from its view table on every one of its ticks — the sort-every-tick
    /// allocation baseline the incremental index replaced. The resulting
    /// trace is bit-identical (a full re-rank converges to exactly the
    /// state incremental re-keying maintains); only the per-tick cost
    /// differs (O(R log R) versus O(dirty · log R)). Mirrors
    /// [`set_full_view_rebuild`](Self::set_full_view_rebuild), and the two
    /// compose.
    pub fn set_full_allocation_sort(&mut self, on: bool) {
        self.full_alloc_sort = on;
    }

    /// Worker threads for the parallel per-tenant phase of coincident-tick
    /// batches (clamped to ≥ 1). Traces are bit-exact at every thread
    /// count — the batch pipeline is phase-ordered and merge order is
    /// ascending tenant id regardless of worker interleaving — so this is
    /// purely a throughput knob. Prefer
    /// [`crate::broker::ExperimentBuilder::threads`], which validates and
    /// clamps against the tenant count.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
        // Any existing pool was sized for the old count: shut it down
        // (joining its workers) and let the next batch build a right-sized
        // replacement lazily.
        self.pool = None;
    }

    /// Configured worker-thread count for batched ticks.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Benchmark support: run phase 2 on per-batch `std::thread::scope`
    /// spawns (the PR-8 behaviour) instead of the persistent worker pool.
    /// Traces are bit-identical — shard work does not depend on which
    /// thread runs it — so this exists purely for the pooled-vs-scoped
    /// spawn-overhead comparison in `benches/grid_scaling.rs`. Mirrors
    /// [`set_full_view_rebuild`](Self::set_full_view_rebuild).
    pub fn set_scoped_spawn(&mut self, on: bool) {
        self.scoped_spawn = on;
        if on {
            self.pool = None;
        }
    }

    /// Comparison baseline: drain the phase-3 commit queue behind a hard
    /// barrier — every shard finishes before the first delta applies (the
    /// PR-9 behaviour) — instead of the default streaming ordered merge
    /// that commits tenant *t* as soon as shards `0..=t` are done. Traces
    /// are bit-identical: both modes apply the same deltas in the same
    /// ascending tenant order against the same deferred-effect buffers,
    /// only the wall-clock overlap with phase 2 differs. Exists for the
    /// barrier-vs-streaming comparison in `benches/grid_scaling.rs` and
    /// `rust/tests/parallel_equivalence.rs`. Mirrors
    /// [`set_full_view_rebuild`](Self::set_full_view_rebuild).
    pub fn set_barrier_merge(&mut self, on: bool) {
        self.barrier_merge = on;
    }

    /// Times any per-batch scratch buffer grew past its previously
    /// observed capacity (see `scratch_regrows` on the struct) — the
    /// allocation-stability telemetry for the batched hot path.
    pub fn scratch_regrows(&self) -> u64 {
        self.scratch_regrows
    }

    /// Lanes of parallelism batched ticks actually use: the configured
    /// thread count clamped to the tenant population (a batch never has
    /// more members than tenants, so extra workers would only idle).
    pub fn effective_workers(&self) -> usize {
        self.threads.min(self.tenants.len()).max(1)
    }

    /// Number of batches fanned out through the persistent worker pool so
    /// far (0 on sequential or scoped-spawn worlds) — telemetry.
    pub fn pool_rounds(&self) -> u64 {
        self.pool_rounds
    }

    /// All tenants finished ⇒ the world run is over.
    pub fn finished(&self) -> bool {
        self.tenants.iter().all(|t| t.exp.finished())
    }

    /// Per-resource invariant, extended by the reservation subsystem:
    /// tenants' in-flight jobs plus background competition claims plus
    /// reservation-held slots never oversubscribe a machine's CPUs.
    /// Policies cap allocations at the contention-adjusted `free_slots`,
    /// competitor arrivals respect tenant occupancy (holds included), and
    /// reservations book against ground-truth free capacity
    /// ([`Self::bookable_slots`]), so this holds at every tick by
    /// construction; tests (and debug builds) verify it.
    pub fn slot_conservation_ok(&self) -> bool {
        self.tb.resources.iter().all(|spec| {
            let i = spec.id.0 as usize;
            let claimed = self
                .competition
                .as_ref()
                .map(|c| c.claimed(spec.id))
                .unwrap_or(0);
            self.total_in_flight[i] + claimed + self.total_reserved[i]
                <= spec.cpus
        })
    }

    // -- economy helpers -----------------------------------------------------

    /// Fraction of `rid`'s CPUs occupied by tenants' in-flight jobs,
    /// background competition claims and reservation-held slots — the
    /// demand signal owners reprice on (held capacity is demand too).
    fn utilization(&self, rid: ResourceId) -> f64 {
        let claimed = self
            .competition
            .as_ref()
            .map(|c| c.claimed(rid))
            .unwrap_or(0);
        utilization_of(
            self.total_in_flight[rid.0 as usize],
            claimed,
            self.total_reserved[rid.0 as usize],
            self.tb.spec(rid).cpus,
        )
    }

    /// Effective rate tenant `tid` is billed on `rid` right now: the rate
    /// locked by a live *committed* reservation hold if the tenant has
    /// one, else a live GRACE agreement if the tenant won one (scheduling
    /// and billing must agree on won prices), else the owner's posted
    /// per-user quote at the owner's local hour, times the
    /// background-competition premium, times the owner's demand-responsive
    /// premium on total utilization.
    fn effective_rate(&self, tid: usize, rid: ResourceId) -> GridDollars {
        if let Some(r) = self.tenants[tid].rsv.get(rid) {
            if r.level == CommitLevel::Committed && r.active(self.q.now()) {
                return r.rate;
            }
        }
        if let Some(a) = self.tenants[tid].agreements[rid.0 as usize] {
            if a.active(self.q.now()) {
                return a.rate;
            }
        }
        let quote = posted_quote(
            &self.tb,
            self.start_utc_hour,
            self.q.now(),
            &self.tenants[tid].cfg.user,
            rid,
        );
        let comp_premium = self
            .competition
            .as_ref()
            .map(|c| c.demand_premium(&self.tb, rid))
            .unwrap_or(1.0);
        let demand_premium =
            self.tb.spec(rid).price.demand_premium(self.utilization(rid));
        quote * comp_premium * demand_premium
    }

    /// Record the world price trajectory: mean effective posted rate over
    /// up machines and the peak combined premium. Piggybacks on directory
    /// refreshes, which already walk every resource.
    fn sample_price_index(&mut self, now: SimTime) {
        let mut sum = 0.0;
        let mut up = 0u32;
        let mut peak = self.peak_premium;
        for (i, spec) in self.tb.resources.iter().enumerate() {
            if !self.dyns[i].up {
                continue;
            }
            let lh = local_hour(
                self.start_utc_hour + now / 3600.0,
                self.tb.site(spec.site).tz_offset_hours,
            );
            let comp_premium = self
                .competition
                .as_ref()
                .map(|c| c.demand_premium(&self.tb, spec.id))
                .unwrap_or(1.0);
            let claimed = self
                .competition
                .as_ref()
                .map(|c| c.claimed(spec.id))
                .unwrap_or(0);
            let util = utilization_of(
                self.total_in_flight[i],
                claimed,
                self.total_reserved[i],
                spec.cpus,
            );
            let demand_premium = spec.price.demand_premium(util);
            // Posted rate for an undiscounted user.
            sum += spec.price.rate_at(lh, "") * comp_premium * demand_premium;
            peak = peak.max(comp_premium * demand_premium);
            up += 1;
        }
        if up > 0 {
            self.price_index.push((now, sum / up as f64));
        }
        self.peak_premium = peak;
    }

    // -- GRACE market --------------------------------------------------------

    /// Owner-side bid servers for one tenant's tender: every authorized,
    /// up machine with free capacity quotes through a [`BidServer`].
    /// Capacity is the real contention-adjusted slot count — the same
    /// [`visible_slots`] occupancy formula (and the same foreign-only
    /// subtraction) the scheduler's view refresh uses, because the tender
    /// asks for capacity for *all* remaining jobs including the tenant's
    /// own in-flight ones, which already hold their slots. Pricing runs
    /// the owner's demand slope over *total* real utilization, so auction
    /// offers move on the very signals posted quotes do. Owners quote from
    /// ground truth (their own machine), not the stale directory.
    fn bid_servers(
        &self,
        tid: usize,
        now: SimTime,
        idle_discount: f64,
    ) -> Vec<BidServer> {
        let tenant = &self.tenants[tid];
        let mut servers = Vec::new();
        for spec in &self.tb.resources {
            let i = spec.id.0 as usize;
            if !tenant.authorized[i] || !self.dyns[i].up {
                continue;
            }
            let claimed = self
                .competition
                .as_ref()
                .map(|c| c.claimed(spec.id))
                .unwrap_or(0);
            let own = tenant.exp.in_flight_on(spec.id);
            let foreign = self.total_in_flight[i].saturating_sub(own);
            let foreign_rsv = self.total_reserved[i]
                .saturating_sub(tenant.rsv.held_on(spec.id));
            let free = visible_slots(
                self.managers[i].slots(),
                spec.cpus,
                claimed,
                foreign,
                foreign_rsv,
            );
            if free == 0 {
                continue;
            }
            let util = utilization_of(
                self.total_in_flight[i],
                claimed,
                self.total_reserved[i],
                spec.cpus,
            );
            let posted = posted_quote(
                &self.tb,
                self.start_utc_hour,
                now,
                &tenant.cfg.user,
                spec.id,
            );
            servers.push(BidServer {
                resource: spec.id,
                speed: self.dyns[i].effective_speed(spec).max(0.05),
                free_slots: free,
                posted_rate: posted,
                utilization: util,
                strategy: BidStrategy::Demand {
                    slope: spec.price.demand_slope,
                    idle_discount,
                },
            });
        }
        servers
    }

    /// GRACE market: one tender/bid negotiation per tenant at this
    /// directory refresh (no-op in posted-price worlds). The tender is
    /// derived from the tenant's live DBC state — remaining jobs, the
    /// safety-discounted deadline window, and a budget-headroom cap on how
    /// far the reservation rate may concede. Awards become time-limited
    /// [`PriceAgreement`]s, dirtying only the winning tenant's views of the
    /// awarded resources; failures are counted with the final rejected
    /// tender's evidence. Deterministic: no RNG is drawn, so posted-price
    /// traces are untouched and auction traces replay bit-exactly.
    // lint:allow(DIRTY-PAIR): award marks are re-keyed by the refresh_dirty_views pass of the same directory tick
    fn run_auction(&mut self, now: SimTime) {
        let Some(cfg) = self.market.clone() else {
            return;
        };
        let broker = GraceBroker {
            max_rounds: cfg.max_rounds,
            escalation: cfg.escalation,
        };
        let mut awarded_rates: Vec<GridDollars> = Vec::new();
        for tid in 0..self.tenants.len() {
            if self.tenants[tid].exp.finished() {
                continue;
            }
            let remaining = self.tenants[tid].exp.remaining();
            // finished() above is exactly remaining() == 0, so every tender
            // that reaches the market has work in it (the zero-job tender
            // path is still covered at the grace unit-test level).
            debug_assert!(remaining > 0, "unfinished tenant with no jobs");
            let servers = self.bid_servers(tid, now, cfg.idle_discount);
            if servers.is_empty() {
                // A dead/saturated grid cannot even open a market.
                self.tenants[tid].failed_negotiations += 1;
                continue;
            }
            let job_work = self.tenants[tid].advisor.job_work_ref_h();
            let window_h = guarded_window_h(
                now,
                self.tenants[tid].exp.deadline,
                DEADLINE_SAFETY,
            );
            // Budget headroom caps concession. Headroom already nets out
            // the committed estimates of in-flight jobs, so only the jobs
            // still waiting to dispatch draw on it: with U un-dispatched
            // jobs of w reference hours each, the best case is every job
            // running on the fastest bidding machine (CPU-seconds
            // w/speed·3600 each), so a rate above
            // headroom·speed_best / (U·w·3600) could not be paid even
            // then and escalation stops there. (All-in-flight tenants keep
            // a loose one-job cap — agreements still reprice their jobs at
            // execution start.)
            let in_flight: u32 =
                self.tenants[tid].exp.in_flight_counts().iter().sum();
            let undispatched = remaining.saturating_sub(in_flight).max(1);
            let best_speed = servers
                .iter()
                .map(|s| s.speed)
                .fold(0.0f64, f64::max)
                .max(0.05);
            let budget_cap = self.tenants[tid].ledger.headroom().map(|h| {
                h * best_speed
                    / (undispatched as f64 * job_work * 3600.0).max(1e-9)
            });
            let mean_posted = servers.iter().map(|s| s.posted_rate).sum::<f64>()
                / servers.len() as f64;
            let mut opening = mean_posted * cfg.opening_rate_factor;
            if let Some(cap) = budget_cap {
                opening = opening.min(cap);
            }
            let tender = Tender {
                user: self.tenants[tid].cfg.user.clone(),
                jobs: remaining,
                job_work_ref_h: job_work,
                time_to_deadline_s: window_h * 3600.0,
                max_rate: opening,
                hard_rate_cap: budget_cap,
            };
            let outcome = broker.negotiate(tender, &servers);
            let tenant = &mut self.tenants[tid];
            tenant.negotiation_rounds += outcome.rounds as u64;
            if !outcome.is_deal() {
                tenant.failed_negotiations += 1;
                continue;
            }
            let mut awarded_any = false;
            for bid in &outcome.selected {
                let i = bid.resource.0 as usize;
                // A renewal must never worsen a price the tenant still
                // holds: an active cheaper (or equal) agreement stands
                // until it lapses on its own — otherwise rising utilization
                // would let each sweep re-bill above a still-binding rate,
                // and every renewal would inflate agreements_won.
                if let Some(existing) = tenant.agreements[i] {
                    if existing.active(now) && existing.rate <= bid.rate {
                        continue;
                    }
                }
                tenant.agreements[i] = Some(PriceAgreement {
                    rate: bid.rate,
                    valid_until: now + cfg.agreement_ttl_s,
                });
                tenant.next_agreement_expiry = tenant
                    .next_agreement_expiry
                    .min(now + cfg.agreement_ttl_s);
                tenant.agreements_won += 1;
                awarded_any = true;
                awarded_rates.push(bid.rate);
                // Only the winner's view of the awarded machine changed —
                // other tenants still see posted rates there.
                tenant.mark_view(bid.resource);
            }
            // Deals that only reaffirm still-standing (cheaper) agreements
            // land nothing new and must not inflate rounds-per-agreement.
            if awarded_any {
                tenant.deal_rounds += outcome.rounds as u64;
            }
        }
        if !awarded_rates.is_empty() {
            let mean =
                awarded_rates.iter().sum::<f64>() / awarded_rates.len() as f64;
            self.clearing_prices.push((now, mean));
        }
    }

    // -- advance reservations ------------------------------------------------

    /// Tick-time expiry sweep: every tick event lapses *all* tenants' due
    /// GRACE agreements and reservation holds in one pass — tenant order,
    /// then (inside each tenant) agreements before holds, each in
    /// ascending resource-index order. A deadline shared by an agreement
    /// and a hold, or by two tenants, therefore always lapses in the same
    /// deterministic (tenant, resource) order, in the incremental and the
    /// full-rebuild paths alike: the sweep only retires state and marks
    /// views, and marks are idempotent, so which tenant's tick happens to
    /// run the sweep cannot change the trace.
    fn expire_due(&mut self, now: SimTime) {
        for tid in 0..self.tenants.len() {
            self.tenants[tid].expire_agreements(now);
            self.expire_reservations(tid, now);
        }
    }

    /// Lapse tenant `tid`'s due holds: expired *committed* holds bill the
    /// cancellation penalty on their unused slots; uncommitted holds lapse
    /// free (the commit timeout simply ran out).
    fn expire_reservations(&mut self, tid: usize, now: SimTime) {
        let Some(cfg) = &self.reservations else {
            return;
        };
        let penalty_frac = cfg.cancel_penalty;
        for (rid, r) in self.tenants[tid].rsv.expire_due(now) {
            let penalty = penalty_frac * r.cost_per_slot * r.slots as f64;
            self.close_hold(tid, rid, &r, penalty);
        }
    }

    /// Close out one hold that already left the store (cancelled or
    /// expired): unbook the shared reserved occupancy, settle the ledger
    /// envelope billing `penalty` G$ (committed holds only — uncommitted
    /// holds never opened one), journal the close and dirty the touched
    /// resource for every tenant.
    // lint:allow(DIRTY-PAIR): hold-close marks are re-keyed by refresh_dirty_views at the next tick boundary
    fn close_hold(
        &mut self,
        tid: usize,
        rid: ResourceId,
        r: &Reservation,
        penalty: GridDollars,
    ) {
        let i = rid.0 as usize;
        debug_assert!(self.total_reserved[i] >= r.slots);
        self.total_reserved[i] = self.total_reserved[i].saturating_sub(r.slots);
        let tenant = &mut self.tenants[tid];
        if r.level == CommitLevel::Committed {
            let name = self.tb.spec(rid).name.clone();
            tenant.ledger.release(rsv_jid(rid), penalty, &name);
            tenant.rsv.penalty_spend += penalty;
        }
        if let Some(j) = &mut tenant.journal {
            let _ = j.reservation_closed(rid);
        }
        self.mark_view_all(rid);
    }

    /// Walk away from an uncommitted hold — free, by construction: only
    /// `Reserved`-level holds reach this path.
    fn free_cancel(&mut self, tid: usize, rid: ResourceId, now: SimTime) {
        let Some(r) = self.tenants[tid].rsv.cancel(rid, now) else {
            return;
        };
        debug_assert_eq!(r.level, CommitLevel::Reserved);
        self.close_hold(tid, rid, &r, 0.0);
    }

    /// Ground-truth slots still free to reserve on `rid` right now. Views
    /// can be stale and never subtract the tenant's own occupancy, so real
    /// bookings clamp here — this is what keeps the extended invariant a
    /// construction property rather than a hope.
    fn bookable_slots(&self, rid: ResourceId) -> u32 {
        let i = rid.0 as usize;
        if !self.dyns[i].up {
            return 0;
        }
        let claimed = self
            .competition
            .as_ref()
            .map(|c| c.claimed(rid))
            .unwrap_or(0);
        self.tb
            .spec(rid)
            .cpus
            .saturating_sub(claimed)
            .saturating_sub(self.total_in_flight[i])
            .saturating_sub(self.total_reserved[i])
    }

    /// Really take one shadow plan's holds (commit-timeout level), clamped
    /// at true bookable capacity. Returns the resources actually held.
    // lint:allow(DIRTY-PAIR): booking marks are re-keyed by the caller's post-reserve refresh_dirty_views pass
    fn book_plan(
        &mut self,
        tid: usize,
        plan: &ShadowPlan,
        now: SimTime,
        expires: SimTime,
    ) -> Vec<ResourceId> {
        let mut held = Vec::new();
        for &(rid, slots, rate, per_slot) in &plan.holds {
            let slots = slots.min(self.bookable_slots(rid));
            if slots == 0 {
                continue;
            }
            if !self.tenants[tid]
                .rsv
                .reserve(rid, slots, rate, per_slot, now, expires)
            {
                continue; // overlaps a hold the winner already took
            }
            self.total_reserved[rid.0 as usize] += slots;
            if let Some(j) = &mut self.tenants[tid].journal {
                let _ = j.reserved(rid, slots, rate, expires);
            }
            self.mark_view_all(rid);
            held.push(rid);
        }
        held
    }

    /// The reserve-ahead DBC move: once `now` passes `trigger_frac` of the
    /// deadline and the tenant still has undispatched jobs (and no live
    /// holds from a previous cycle), probe `probe_sets` candidate resource
    /// sets — greedy prefixes of the tenant's ranked candidate orderings —
    /// against a [`ShadowSchedule`], really reserve the two cheapest
    /// feasible plans, commit the cheapest and free-cancel the runner-up.
    /// Committing opens a ledger envelope for the worst-case cancellation
    /// penalty; a refused envelope (budget headroom gone) degrades that
    /// member to a free cancellation. Deterministic: no RNG, ties broken
    /// by `total_cmp` + stable sort.
    // lint:allow(DIRTY-PAIR): on_tick runs a second refresh_dirty_views right after this move to re-key held views
    fn reserve_ahead(&mut self, tid: usize) {
        let Some(cfg) = self.reservations.clone() else {
            return;
        };
        let now = self.q.now();
        let tenant = &self.tenants[tid];
        let deadline = tenant.exp.deadline;
        if now < cfg.trigger_frac * deadline || tenant.rsv.active_holds() > 0 {
            return;
        }
        let remaining = tenant.exp.remaining();
        let in_flight: u32 = tenant.exp.in_flight_counts().iter().sum();
        let undispatched = remaining.saturating_sub(in_flight);
        if undispatched == 0 {
            return;
        }
        let want = undispatched.min(cfg.max_slots);
        let job_work = tenant.advisor.job_work_ref_h();
        let window_h = guarded_window_h(now, deadline, DEADLINE_SAFETY);
        let sets = reservation_candidate_sets(
            &tenant.views,
            &tenant.index,
            want,
            cfg.probe_sets as usize,
        );
        if sets.len() < 2 {
            return; // "commit the cheapest" needs a real comparison
        }
        // Shadow-price every candidate set; nothing live moves here.
        let mut shadow = ShadowSchedule::new(&tenant.views);
        let mut plans: Vec<ShadowPlan> = sets
            .iter()
            .map(|set| shadow.plan(set, job_work, window_h))
            .collect();
        let probes: u64 = plans.iter().map(|p| p.probes as u64).sum();
        plans.retain(|p| p.slots > 0);
        plans.sort_by(|a, b| a.cost_per_slot().total_cmp(&b.cost_per_slot()));
        let mut ranked = plans.into_iter();
        let winner = ranked.next();
        let runner_up = ranked.next();
        self.tenants[tid].rsv.probes += probes;
        let Some(winner) = winner else {
            return; // every probed set was infeasible
        };
        let reserve_until = now + cfg.commit_timeout_s;
        let winner_holds = self.book_plan(tid, &winner, now, reserve_until);
        let runner_holds = match &runner_up {
            Some(p) => self.book_plan(tid, p, now, reserve_until),
            None => Vec::new(),
        };
        // Commit the winner member by member while the runner-up is still
        // held — exactly the probe → reserve → commit ladder, with the
        // comparison made while walking away is still free.
        let commit_until = now + cfg.hold_s;
        for rid in winner_holds {
            let Some(r) = self.tenants[tid].rsv.get(rid).copied() else {
                continue;
            };
            let envelope = cfg.cancel_penalty * r.cost_per_slot * r.slots as f64;
            if !self.tenants[tid].ledger.commit(rsv_jid(rid), envelope) {
                self.free_cancel(tid, rid, now);
                continue;
            }
            let committed =
                self.tenants[tid].rsv.commit(rid, now, commit_until);
            debug_assert!(committed, "fresh hold must accept a commit");
            if let Some(j) = &mut self.tenants[tid].journal {
                let _ = j.reservation_committed(rid, commit_until);
            }
            self.mark_view_all(rid); // the locked rate now rules the view
        }
        for rid in runner_holds {
            self.free_cancel(tid, rid, now);
        }
    }

    // -- run loop ------------------------------------------------------------

    /// Run to completion (or hard stop); consume the world, return the
    /// per-tenant + cross-tenant report.
    pub fn run_world(mut self) -> WorldReport {
        while !self.finished() {
            if self.q.now() > self.hard_stop {
                break;
            }
            let Some((_, ev)) = self.q.pop() else {
                break; // queue drained with jobs unfinished (dead grid)
            };
            self.handle(ev);
        }
        self.finalize_world()
    }

    /// Run until `t` (for incremental inspection in tests/examples).
    pub fn run_until(&mut self, t: SimTime) {
        while !self.finished() {
            match self.q.next_time() {
                Some(nt) if nt <= t => {
                    // next_time() returning Some guarantees a queued event,
                    // but a racing drain is cheap to tolerate outright.
                    let Some((_, ev)) = self.q.pop() else {
                        break;
                    };
                    self.handle(ev);
                }
                _ => break,
            }
        }
    }

    /// Finalize every tenant's report after the event loop.
    pub fn finalize_world(mut self) -> WorldReport {
        let events = self.q.processed();
        let mut outcomes = Vec::with_capacity(self.tenants.len());
        for t in &mut self.tenants {
            t.report.makespan_s = t.exp.makespan();
            t.report.jobs_completed = t.exp.completed();
            t.report.jobs_failed = t.exp.failed();
            t.report.deadline_met = t.report.jobs_completed
                + t.report.jobs_failed
                == t.report.jobs_total
                && t.report.makespan_s <= t.exp.deadline
                && t.report.jobs_failed == 0;
            t.report.total_cost = t.ledger.settled();
            t.report.resources_used = t
                .report
                .per_resource
                .values()
                .filter(|u| u.jobs_completed > 0)
                .count() as u32;
            t.report.events = events;
        }
        for t in self.tenants {
            outcomes.push(TenantOutcome {
                user: t.cfg.user,
                policy: t.cfg.policy,
                agreements_won: t.agreements_won,
                negotiation_rounds: t.negotiation_rounds,
                deal_rounds: t.deal_rounds,
                failed_negotiations: t.failed_negotiations,
                reservation_probes: t.rsv.probes,
                reservations_committed: t.rsv.commits,
                reservations_cancelled: t.rsv.cancels + t.rsv.expiries,
                held_slot_seconds: t.rsv.held_slot_seconds,
                penalty_spend: t.rsv.penalty_spend,
                report: t.report,
            });
        }
        WorldReport {
            tenants: outcomes,
            events,
            price_index: self.price_index,
            peak_premium: self.peak_premium,
            clearing_prices: self.clearing_prices,
            snapshot_ns: self.snapshot_ns,
            parallel_ns: self.parallel_ns,
            merge_ns: self.merge_ns,
            merge_overlap_ns: self.merge_overlap_ns,
            pool_workers: self.pool.as_ref().map(|p| p.workers()).unwrap_or(0)
                as u32,
            pool_rounds: self.pool_rounds,
        }
    }

    // -- event handlers ------------------------------------------------------

    // lint:allow(DIRTY-PAIR): event marks are queued; each tenant's next on_tick refresh_dirty_views re-keys them
    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Tick { tid } => {
                // Coalesce every consecutive Tick sharing this timestamp
                // into one batch: coincident ticks take the three-phase
                // snapshot pipeline (see module docs), a lone tick takes
                // the original sequential path verbatim. Collection stops
                // at the first non-Tick event so FIFO order against
                // same-instant MdsRefresh/job events is preserved.
                let now = self.q.now();
                let mut batch = vec![tid as usize];
                loop {
                    let next = match self.q.peek() {
                        Some((t, &Ev::Tick { tid }))
                            if t.to_bits() == now.to_bits() =>
                        {
                            tid as usize
                        }
                        _ => break,
                    };
                    self.q.pop();
                    batch.push(next);
                }
                if batch.len() == 1 {
                    self.on_tick(batch[0]);
                } else {
                    // Each tenant has exactly one live tick chain, so the
                    // batch is duplicate-free; merge order is ascending
                    // tenant id by construction.
                    batch.sort_unstable();
                    self.on_tick_batch(&batch);
                }
            }
            Ev::MdsRefresh => {
                // Only records whose up/load actually moved invalidate
                // their view entries (in every tenant's table).
                let now = self.q.now();
                let changed =
                    self.mds.refresh(&self.tb, &self.dyns, now);
                for rid in changed {
                    self.mark_view_all(rid);
                }
                // GRACE worlds auction at directory-refresh boundaries:
                // the freshest owner state is exactly what bid servers
                // quote on.
                self.run_auction(now);
                self.sample_price_index(now);
                self.q.schedule_in(MDS_REFRESH_PERIOD_S, Ev::MdsRefresh);
            }
            Ev::LoadUpdate => {
                // Ground truth moves; schedulers keep seeing the stale
                // directory until the next MdsRefresh (no view marking).
                for i in 0..self.dyns.len() {
                    let spec = &self.tb.resources[i];
                    self.dyns[i].step_load(spec);
                }
                self.q.schedule_in(LOAD_UPDATE_PERIOD_S, Ev::LoadUpdate);
            }
            Ev::StagedIn { tid, rid, jid } => {
                self.on_staged_in(tid as usize, rid, jid)
            }
            Ev::BeginExec { tid, rid, jid } => {
                self.on_begin_exec(tid as usize, rid, jid)
            }
            Ev::Complete { tid, rid, jid } => {
                self.on_complete(tid as usize, rid, jid)
            }
            Ev::Fail { rid } => self.on_fail(rid),
            Ev::Recover { rid } => self.on_recover(rid),
            Ev::CompetitorArrive => {
                let now = self.q.now();
                let claimed: Vec<ResourceId> = match &mut self.competition {
                    Some(comp) => {
                        // Arrivals respect reservation holds too: a held
                        // slot is occupied capacity. Only build the
                        // combined vector when the subsystem is on, so
                        // reservation-free worlds take the exact original
                        // path.
                        let combined: Vec<u32>;
                        let occupied = if self.reservations.is_some() {
                            combined = self
                                .total_in_flight
                                .iter()
                                .zip(&self.total_reserved)
                                .map(|(f, r)| f + r)
                                .collect();
                            &combined
                        } else {
                            &self.total_in_flight
                        };
                        let (departs, claimed) =
                            comp.arrive(&self.tb, now, occupied);
                        self.q.schedule_at(departs, Ev::CompetitorDepart);
                        let next = comp.draw_interarrival();
                        self.q.schedule_in(next, Ev::CompetitorArrive);
                        claimed
                    }
                    None => Vec::new(),
                };
                // Premium and free slots changed on the claimed machines.
                for rid in claimed {
                    self.mark_view_all(rid);
                }
            }
            Ev::CompetitorDepart => {
                let now = self.q.now();
                let released = match &mut self.competition {
                    Some(comp) => comp.depart_until(now),
                    None => Vec::new(),
                };
                for rid in released {
                    self.mark_view_all(rid);
                }
            }
        }
    }

    /// Invalidate one resource's view entry in every tenant's table: the
    /// occupancy, visible slots and demand premium of a machine are shared
    /// state, so any change there is scheduler-visible to all tenants.
    // lint:allow(DIRTY-PAIR): the queue fan-out itself — every queued entry is re-keyed by refresh_dirty_views
    fn mark_view_all(&mut self, rid: ResourceId) {
        for t in &mut self.tenants {
            t.mark_view(rid);
        }
    }

    /// Rebuild every dirty view entry of one tenant (and re-key its
    /// candidate index) — the sequential entry point over
    /// [`refresh_tenant_views`], which holds the actual refresh logic in
    /// snapshot form so the parallel phase can run it on disjoint tenants.
    fn refresh_dirty_views(&mut self, tid: usize) {
        let wv = WorldView {
            now: self.q.now(),
            tb: &self.tb,
            mds: &self.mds,
            dyns: &self.dyns,
            managers: &self.managers,
            competition: self.competition.as_ref(),
            total_in_flight: &self.total_in_flight,
            total_reserved: &self.total_reserved,
            start_utc_hour: self.start_utc_hour,
            full_rebuild: self.full_rebuild,
            full_alloc_sort: self.full_alloc_sort,
        };
        refresh_tenant_views(&wv, &mut self.tenants[tid]);
    }

    fn on_tick(&mut self, tid: usize) {
        if self.tenants[tid].exp.finished() {
            return; // other tenants may still be running
        }
        self.tenants[tid].report.ticks += 1;
        let now = self.q.now();
        // 1. discovery + view maintenance: rebuild only the entries whose
        // inputs changed since this tenant's last tick (MDS deltas, churn,
        // any tenant's job transitions, competition claims, local-hour
        // repricing, GRACE agreement expiries). Down and unauthorized
        // machines sit in the table with zero speed/slots; every policy
        // filters them out.
        self.tenants[tid].mark_repriced(now);
        self.expire_due(now);
        self.refresh_dirty_views(tid);
        debug_assert!(
            self.slot_conservation_ok(),
            "slot conservation violated at t={now}"
        );
        // 1a. index-consistency audit (debug builds): every live view is
        // ranked exactly once per ordering with keys matching recomputed
        // values — the runtime cross-check of the static DIRTY-PAIR lint
        // rule. Small worlds are audited every tick; index-storm-sized
        // worlds are sampled so debug runs stay usable.
        #[cfg(debug_assertions)]
        {
            let tenant = &self.tenants[tid];
            if tenant.views.len() <= 4096 || tenant.report.ticks % 64 == 1 {
                if let Err(e) = tenant.index.consistent_with(&tenant.views) {
                    panic!("tenant {tid} index audit failed at t={now}: {e}");
                }
            }
        }
        // 1b. the reserve-ahead move (inert without a reservation config):
        // near the deadline, shadow-price several candidate resource sets,
        // commit the cheapest feasible one and cancel the rest while
        // cancellation is still free. Bookings dirty views, so refresh
        // again before allocation — still O(changed).
        if self.reservations.is_some() {
            self.reserve_ahead(tid);
            self.refresh_dirty_views(tid);
            debug_assert!(
                self.slot_conservation_ok(),
                "slot conservation violated after reserve-ahead at t={now}"
            );
        }
        // 2+3. selection + assignment: the shared advisor pipeline. The
        // alloc_ns clock starts before the baseline re-rank so the
        // sort-every-tick cost it models lands in the allocation-phase
        // metric it exists to compare against.
        let job_work = self.tenants[tid].advisor.job_work_ref_h();
        // lint:allow(ND-CLOCK): alloc_ns is wall-clock telemetry about the allocator itself; it never feeds sim state
        let alloc_t0 = std::time::Instant::now();
        if self.full_alloc_sort {
            // Sort-every-tick baseline: throw the incremental rankings
            // away and re-derive them all (bit-identical state, O(R log R)
            // cost — see set_full_allocation_sort).
            let tenant = &mut self.tenants[tid];
            tenant.index.rebuild_from(&tenant.views);
        }
        let actions = {
            let tenant = &mut self.tenants[tid];
            tenant.advisor.advise(
                TickCtx {
                    now,
                    deadline: tenant.exp.deadline,
                    budget_headroom: tenant.ledger.headroom(),
                    views: &tenant.views,
                    candidates: &tenant.index,
                },
                &tenant.exp,
                &mut self.rng,
            )
        };
        self.tenants[tid].report.alloc_ns +=
            alloc_t0.elapsed().as_nanos() as u64;
        for action in actions {
            match action {
                Action::Submit { job, rid } => {
                    self.submit(tid, job, rid, job_work)
                }
                Action::CancelQueued { job, rid } => {
                    self.cancel_queued(tid, job, rid)
                }
            }
        }
        if !self.tenants[tid].exp.finished() {
            let period = self.tenants[tid].cfg.tick_period_s;
            self.q.schedule_in(period, Ev::Tick { tid: tid as u32 });
        }
    }

    /// The three-phase batched tick for ≥ 2 tenants sharing one virtual
    /// instant (see module docs). `batch` is ascending and duplicate-free.
    ///
    /// Phase 1 (sequential snapshot): expiry sweeps, repricing marks and —
    /// with reservations on — the shared-state-mutating reserve-ahead
    /// cascade run in ascending tenant order, then one RNG sub-stream per
    /// member is forked from the world RNG in the same order, so the world
    /// stream advances identically at every thread count. Phase 2
    /// (parallel): disjoint tenant slices run view refresh + allocation
    /// against the frozen [`WorldView`]. Phase 3 (merge barrier): deltas
    /// apply in ascending tenant order behind a ground-truth capacity
    /// guard, and next ticks reschedule in the same order. Nothing depends
    /// on worker interleaving, so traces are bit-exact regardless of
    /// `threads`.
    fn on_tick_batch(&mut self, batch: &[usize]) {
        let now = self.q.now();
        let mut members = std::mem::take(&mut self.member_buf);
        members.clear();
        members.extend(
            batch
                .iter()
                .copied()
                .filter(|&tid| !self.tenants[tid].exp.finished()),
        );
        if members.is_empty() {
            self.member_buf = members;
            return; // nothing to do, nothing to reschedule
        }
        // -- phase 1: sequential snapshot ---------------------------------
        // lint:allow(ND-CLOCK): phase nanos are wall-clock telemetry about the tick pipeline; they never feed sim state
        let snap_t0 = std::time::Instant::now();
        self.expire_due(now);
        for &tid in &members {
            self.tenants[tid].report.ticks += 1;
            self.tenants[tid].mark_repriced(now);
        }
        // The reserve-ahead move books real capacity (shared occupancy,
        // ledger envelopes, cross-tenant view marks), so it stays in the
        // sequential phase, cascading in ascending tenant order; the
        // parallel refresh afterwards picks up every mark it left.
        if self.reservations.is_some() {
            for &tid in &members {
                self.refresh_dirty_views(tid);
                self.reserve_ahead(tid);
            }
            debug_assert!(
                self.slot_conservation_ok(),
                "slot conservation violated after batched reserve-ahead at t={now}"
            );
        }
        let mut rngs = std::mem::take(&mut self.rng_buf);
        rngs.clear();
        rngs.extend(members.iter().map(|&tid| self.rng.fork(tid as u64)));
        self.snapshot_ns += snap_t0.elapsed().as_nanos() as u64;
        // -- phases 2 + 3: parallel shards + streaming ordered merge ------
        // lint:allow(ND-CLOCK): phase nanos are wall-clock telemetry about the tick pipeline; they never feed sim state
        let pipe_t0 = std::time::Instant::now();
        // First batch that can actually fan out builds the persistent
        // pool, sized once to the effective lane count; every later batch
        // reuses it (workers park on a condvar in between).
        if self.pool.is_none()
            && !self.scoped_spawn
            && self.threads > 1
            && self.tenants.len() > 1
        {
            self.pool = Some(WorkerPool::new(self.effective_workers()));
        }
        let mut member_flag = std::mem::take(&mut self.member_flag_buf);
        member_flag.clear();
        member_flag.resize(self.tenants.len(), false);
        for &tid in &members {
            member_flag[tid] = true;
        }
        // Freeze the occupancy tallies into reusable snapshot buffers:
        // streamed commits mutate the live arrays while phase-2 shards
        // are still reading, so shards read these per-batch copies — the
        // same phase-1 freeze barrier-mode shards always saw implicitly.
        self.snap_in_flight.clear();
        self.snap_in_flight.extend_from_slice(&self.total_in_flight);
        self.snap_reserved.clear();
        self.snap_reserved.extend_from_slice(&self.total_reserved);
        let streaming = !self.barrier_merge && !self.scoped_spawn;
        let (mut merge_acc, mut overlap_acc) = (0u64, 0u64);
        {
            let tb = &self.tb;
            let competition = self.competition.as_ref();
            let wv = WorldView {
                now,
                tb,
                mds: &self.mds,
                dyns: &self.dyns,
                managers: &self.managers,
                competition,
                total_in_flight: &self.snap_in_flight,
                total_reserved: &self.snap_reserved,
                start_utc_hour: self.start_utc_hour,
                full_rebuild: self.full_rebuild,
                full_alloc_sort: self.full_alloc_sort,
            };
            let mut ctx = MergeCtx {
                now,
                tb,
                competition,
                total_in_flight: &mut self.total_in_flight,
                total_reserved: &mut self.total_reserved,
                gass: &mut self.gass,
                proxy: &mut self.proxy,
                q: &mut self.q,
                marks: &mut self.mark_buf,
                gram_cancels: &mut self.cancel_buf,
            };
            // iter_mut ascends tenant ids and `members` is ascending, so
            // the zip pairs each member with the sub-RNG forked for it
            // above. Action buffers are the tenants' recycled scratch.
            let mut shards: Vec<TenantShard<'_>> = self
                .tenants
                .iter_mut()
                .enumerate()
                .filter(|(tid, _)| member_flag[*tid])
                .zip(rngs.drain(..))
                .map(|((tid, tenant), rng)| TenantShard {
                    tid,
                    actions: std::mem::take(&mut tenant.merge_scratch),
                    tenant,
                    rng,
                    job_work: 0.0,
                })
                .collect();
            let workers = self.threads.min(shards.len()).max(1);
            // The commit-queue callback every phase-3 mode funnels
            // through: applies one shard's delta via `commit_shard` and
            // splits the wall time into merged-vs-overlapped telemetry.
            let mut commit = |shard: &mut TenantShard<'_>, overlapped: bool| {
                // lint:allow(ND-CLOCK): phase nanos are wall-clock telemetry about the tick pipeline; they never feed sim state
                let t0 = std::time::Instant::now();
                commit_shard(&mut ctx, shard);
                let dt = t0.elapsed().as_nanos() as u64;
                merge_acc += dt;
                if overlapped {
                    overlap_acc += dt;
                }
            };
            match (workers, &self.pool) {
                (1, _) => {
                    // The reference path: same pipeline, caller thread.
                    // Streaming interleaves each shard's commit behind its
                    // phase-2 work — legal because commits only touch live
                    // state later shards never read (see MergeCtx) — while
                    // barrier mode drains the queue after all shards.
                    if streaming {
                        for shard in &mut shards {
                            tick_tenant_shard(&wv, shard);
                            commit(shard, false);
                        }
                    } else {
                        for shard in &mut shards {
                            tick_tenant_shard(&wv, shard);
                        }
                        for shard in &mut shards {
                            commit(shard, false);
                        }
                    }
                }
                (_, Some(pool)) if !self.scoped_spawn => {
                    // Persistent pool: workers claim shards (own affinity
                    // range first), so a batch smaller than the lane count
                    // just leaves the surplus workers parked. Streaming
                    // commits tenant t as soon as shards 0..=t are done,
                    // while higher shards still run.
                    if streaming {
                        pool.scatter_streaming(
                            &mut shards,
                            |shard| tick_tenant_shard(&wv, shard),
                            &mut commit,
                        );
                    } else {
                        pool.scatter(&mut shards, |shard| {
                            tick_tenant_shard(&wv, shard)
                        });
                        for shard in &mut shards {
                            commit(shard, false);
                        }
                    }
                    self.pool_rounds += 1;
                }
                _ => {
                    // Scoped-spawn baseline (set_scoped_spawn): fresh
                    // threads per batch over contiguous shard chunks — the
                    // PR-8 path the bench compares pool overhead against.
                    // Always barrier-merged: the commit queue needs the
                    // pool's completion flags to stream safely.
                    let chunk = shards.len().div_ceil(workers);
                    let wv = &wv;
                    std::thread::scope(|scope| {
                        for slice in shards.chunks_mut(chunk) {
                            scope.spawn(move || {
                                for shard in slice {
                                    tick_tenant_shard(wv, shard);
                                }
                            });
                        }
                    });
                    for shard in &mut shards {
                        commit(shard, false);
                    }
                }
            }
        }
        let pipe = pipe_t0.elapsed().as_nanos() as u64;
        // Deferred cross-tenant effects (GRAM withdrawals, view-dirtying
        // fan-out) replay once every shard has dropped its tenant borrow;
        // commit order is preserved, so the dirty queues fill exactly as
        // the old inline calls filled them.
        // lint:allow(ND-CLOCK): phase nanos are wall-clock telemetry about the tick pipeline; they never feed sim state
        let tail_t0 = std::time::Instant::now();
        self.drain_merge_buffers();
        debug_assert!(
            self.slot_conservation_ok(),
            "slot conservation violated after batch merge at t={now}"
        );
        let tail = tail_t0.elapsed().as_nanos() as u64;
        self.parallel_ns += pipe.saturating_sub(merge_acc);
        self.merge_ns += merge_acc + tail;
        self.merge_overlap_ns += overlap_acc;
        // Return the per-batch scratch and count any regrowth (the
        // allocation-stability telemetry `scratch_regrows()` reports).
        self.member_buf = members;
        self.rng_buf = rngs;
        self.member_flag_buf = member_flag;
        let caps = [
            self.member_buf.capacity(),
            self.mark_buf.capacity(),
            self.cancel_buf.capacity(),
        ];
        for (prev, cap) in self.scratch_caps.iter_mut().zip(caps) {
            if *prev != 0 && cap > *prev {
                self.scratch_regrows += 1;
            }
            *prev = cap;
        }
    }

    /// Sequential-path submit: pre-compute the frozen half here (at the
    /// same instant, so it is byte-identical to the old inline
    /// computation) and finish through the shared live half. The batched
    /// path computes the same [`PreparedSubmit`] in parallel during phase
    /// 2 instead.
    fn submit(&mut self, tid: usize, jid: JobId, rid: ResourceId, job_work: f64) {
        let wv = WorldView {
            now: self.q.now(),
            tb: &self.tb,
            mds: &self.mds,
            dyns: &self.dyns,
            managers: &self.managers,
            competition: self.competition.as_ref(),
            total_in_flight: &self.total_in_flight,
            total_reserved: &self.total_reserved,
            start_utc_hour: self.start_utc_hour,
            full_rebuild: self.full_rebuild,
            full_alloc_sort: self.full_alloc_sort,
        };
        let prep = prepare_submit(&wv, &self.tenants[tid], jid, rid);
        self.submit_prepared(tid, jid, rid, job_work, prep);
    }

    /// Sequential entry point over [`merge_submit_prepared`], which holds
    /// the actual commit logic in [`MergeCtx`] form so the streaming merge
    /// can run it while later shards are still in flight. Drains the
    /// deferred-effect buffers before returning, so the inline caller
    /// observes exactly the old eager-mark behaviour.
    fn submit_prepared(
        &mut self,
        tid: usize,
        jid: JobId,
        rid: ResourceId,
        job_work: f64,
        prep: PreparedSubmit,
    ) {
        let now = self.q.now();
        let mut ctx = MergeCtx {
            now,
            tb: &self.tb,
            competition: self.competition.as_ref(),
            total_in_flight: &mut self.total_in_flight,
            total_reserved: &mut self.total_reserved,
            gass: &mut self.gass,
            proxy: &mut self.proxy,
            q: &mut self.q,
            marks: &mut self.mark_buf,
            gram_cancels: &mut self.cancel_buf,
        };
        merge_submit_prepared(
            &mut ctx,
            &mut self.tenants[tid],
            tid,
            jid,
            rid,
            job_work,
            prep,
        );
        self.drain_merge_buffers();
    }

    /// Sequential entry point over [`merge_cancel_queued`] — same
    /// wrapper-plus-drain shape as [`Self::submit_prepared`].
    fn cancel_queued(&mut self, tid: usize, jid: JobId, rid: ResourceId) {
        let now = self.q.now();
        let mut ctx = MergeCtx {
            now,
            tb: &self.tb,
            competition: self.competition.as_ref(),
            total_in_flight: &mut self.total_in_flight,
            total_reserved: &mut self.total_reserved,
            gass: &mut self.gass,
            proxy: &mut self.proxy,
            q: &mut self.q,
            marks: &mut self.mark_buf,
            gram_cancels: &mut self.cancel_buf,
        };
        merge_cancel_queued(&mut ctx, &mut self.tenants[tid], tid, jid, rid);
        self.drain_merge_buffers();
    }

    /// Replay the deferred cross-tenant effects of merge commits: GRAM
    /// withdrawals first (each precedes the mark its cancellation
    /// queued, matching the old inline order), then the view-dirtying
    /// fan-out. Runs after the phase-2 shards of a streaming batch have
    /// dropped their `&mut Tenant` borrows, or immediately after a
    /// sequential commit (the wrappers above) — both replay in commit
    /// order, so the dirty queues fill identically to the old inline
    /// calls.
    // lint:allow(DIRTY-PAIR): replays deferred merge marks — every queued entry is re-keyed by refresh_dirty_views at the owners' next ticks
    fn drain_merge_buffers(&mut self) {
        for k in 0..self.cancel_buf.len() {
            let (rid, gid) = self.cancel_buf[k];
            self.managers[rid.0 as usize].cancel(gid);
        }
        self.cancel_buf.clear();
        let mut k = 0;
        while k < self.mark_buf.len() {
            let rid = self.mark_buf[k];
            self.mark_view_all(rid); // occupancy changed for everyone
            k += 1;
        }
        self.mark_buf.clear();
    }

    fn on_staged_in(&mut self, tid: usize, rid: ResourceId, jid: JobId) {
        let spec = self.tb.spec(rid).clone();
        self.proxy.end(&mut self.gass, &spec);
        // The job may have been cancelled or the resource may have died
        // while staging.
        if self.tenants[tid].exp.job(jid).state.resource() != Some(rid) {
            return;
        }
        if !self.dyns[rid.0 as usize].up {
            self.fail_in_flight(tid, jid, rid);
            return;
        }
        self.managers[rid.0 as usize].submit(grid_jid(tid, jid));
        self.try_start(rid);
    }

    /// Pump GRAM: start whatever the queue admits, routing each started
    /// job back to its owning tenant.
    fn try_start(&mut self, rid: ResourceId) {
        let now = self.q.now();
        let started = self.managers[rid.0 as usize].start_eligible(now);
        for (gid, delay) in started {
            let (tid, jid) = split_jid(gid);
            self.q.schedule_in(
                delay,
                Ev::BeginExec {
                    tid: tid as u32,
                    rid,
                    jid,
                },
            );
        }
    }

    // lint:allow(DIRTY-PAIR): withdrawal marks are queued; refresh_dirty_views re-keys them at the next tick
    fn on_begin_exec(&mut self, tid: usize, rid: ResourceId, jid: JobId) {
        let now = self.q.now();
        if self.tenants[tid].exp.job(jid).state.resource() != Some(rid) {
            return; // cancelled while waiting on the queue cycle
        }
        if !self.dyns[rid.0 as usize].up {
            return; // Fail handler already requeued it
        }
        let spec = self.tb.spec(rid);
        let speed = self.dyns[rid.0 as usize].effective_speed(spec).max(0.01);
        // A dispatch that consumed a reservation slot keeps that locked
        // rate to the end, whatever happened to the hold since.
        let rate = match self.tenants[tid].inflight[&jid].locked_rate {
            Some(locked) => locked,
            None => self.effective_rate(tid, rid),
        };
        let name = spec.name.clone();
        let t_out = self
            .tb
            .site(spec.site)
            .link
            .transfer_seconds(self.tenants[tid].cfg.workload.output_bytes);
        let tenant = &mut self.tenants[tid];
        // CPU time on this machine: drawn work scaled by effective speed at
        // start (load drift during the run is absorbed into the draw).
        let work_ref_h = tenant.inflight[&jid].work_ref_h;
        let cpu_s = work_ref_h * 3600.0 / speed;
        // Replace the dispatch-time *estimate* with the now-known actual
        // cost. If the budget headroom no longer carries it, withdraw the
        // job (still Dispatched — a clean release, not a burned attempt)
        // instead of running over budget: this is what makes "spend never
        // exceeds budget" a hard invariant in virtual mode.
        tenant.ledger.release(jid, 0.0, &name);
        if !tenant.ledger.commit(jid, cpu_s * rate) {
            self.managers[rid.0 as usize].cancel(grid_jid(tid, jid));
            let tenant = &mut self.tenants[tid];
            let _ = tenant.exp.release(jid);
            if let Some(j) = &mut tenant.journal {
                let _ = j.released(jid);
            }
            tenant.inflight.remove(&jid);
            self.dec_total_in_flight(rid);
            self.mark_view_all(rid); // occupancy changed for everyone
            return;
        }
        if tenant.exp.start(jid, now).is_err() {
            return;
        }
        if let Some(j) = &mut tenant.journal {
            let _ = j.started(jid, now);
        }
        // lint:allow(PANIC-BUDGET): the dispatch path inserted this record and only this fn's cancel arm removes it
        let inf = tenant.inflight.get_mut(&jid).expect("inflight record");
        inf.exec_started = Some(now);
        inf.rate = rate;
        inf.cpu_s = cpu_s;
        let exec_wall = inf.cpu_s;
        tenant.busy_cpus += 1;
        tenant.report.busy_cpus.record(now, tenant.busy_cpus);
        // Stage-out folded into the completion event.
        self.q.schedule_in(
            exec_wall + t_out,
            Ev::Complete {
                tid: tid as u32,
                rid,
                jid,
            },
        );
    }

    // lint:allow(DIRTY-PAIR): completion marks are queued; refresh_dirty_views re-keys them at the next tick
    fn on_complete(&mut self, tid: usize, rid: ResourceId, jid: JobId) {
        let now = self.q.now();
        if !matches!(self.tenants[tid].exp.job(jid).state, JobState::Running { rid: r, .. } if r == rid)
        {
            return; // failed/cancelled meanwhile
        }
        let name = self.tb.spec(rid).name.clone();
        self.managers[rid.0 as usize].complete(grid_jid(tid, jid));
        let tenant = &mut self.tenants[tid];
        // lint:allow(PANIC-BUDGET): the Running-state guard above proves the dispatch record still exists
        let inf = tenant.inflight.remove(&jid).expect("inflight record");
        tenant.busy_cpus -= 1;
        tenant.report.busy_cpus.record(now, tenant.busy_cpus);
        let cost = inf.cpu_s * inf.rate;
        tenant.ledger.settle(jid, cost, &name);
        tenant
            .exp
            .complete(jid, now, inf.cpu_s, cost)
            // lint:allow(PANIC-BUDGET): the Running-state guard above makes this transition legal by construction
            .expect("legal complete");
        if let Some(j) = &mut tenant.journal {
            let _ = j.completed(jid, now, inf.cpu_s, cost);
        }
        tenant
            .advisor
            .observe_complete(rid, now - inf.dispatched_at, inf.work_ref_h);
        let usage = tenant
            .report
            .per_resource
            .entry(name)
            .or_insert_with(ResourceUsage::default);
        usage.jobs_completed += 1;
        usage.cpu_seconds += inf.cpu_s;
        usage.cost += cost;
        self.dec_total_in_flight(rid);
        self.mark_view_all(rid); // occupancy + measured service rate changed
        self.try_start(rid);
    }

    /// Shared failure path for one in-flight job of tenant `tid` on `rid`.
    // lint:allow(DIRTY-PAIR): failure marks are queued; refresh_dirty_views re-keys them at the next tick
    fn fail_in_flight(&mut self, tid: usize, jid: JobId, rid: ResourceId) {
        let now = self.q.now();
        let name = self.tb.spec(rid).name.clone();
        let tenant = &mut self.tenants[tid];
        if let Some(inf) = tenant.inflight.remove(&jid) {
            // Owners bill for cycles consumed before the crash, capped at
            // the job's full CPU demand (a crash during stage-out must not
            // bill the wire time as CPU time — that could push settled
            // spend past the committed envelope).
            let partial = match inf.exec_started {
                Some(t0) => (now - t0).max(0.0).min(inf.cpu_s) * inf.rate,
                None => 0.0,
            };
            if inf.exec_started.is_some() {
                tenant.busy_cpus = tenant.busy_cpus.saturating_sub(1);
                tenant.report.busy_cpus.record(now, tenant.busy_cpus);
            }
            tenant.ledger.release(jid, partial, &name);
            let usage = tenant
                .report
                .per_resource
                .entry(name)
                .or_insert_with(ResourceUsage::default);
            usage.jobs_failed += 1;
            usage.cost += partial;
        }
        tenant.advisor.observe_failure(rid);
        if tenant.exp.fail_attempt(jid).is_ok() {
            if let Some(j) = &mut tenant.journal {
                let _ = j.failed_attempt(jid);
            }
            self.dec_total_in_flight(rid);
        }
        self.mark_view_all(rid); // occupancy + failure history changed
    }

    fn on_fail(&mut self, rid: ResourceId) {
        let i = rid.0 as usize;
        if !self.dyns[i].up {
            return;
        }
        self.dyns[i].up = false;
        let victims = self.managers[i].fail_all();
        for (gid, _started) in victims {
            let (tid, jid) = split_jid(gid);
            self.fail_in_flight(tid, jid, rid);
        }
        // The owner broke the commitment, not the tenant: holds on a dead
        // machine are released penalty-free (committed envelopes refunded).
        if self.reservations.is_some() {
            for tid in 0..self.tenants.len() {
                if let Some(r) = self.tenants[tid].rsv.cancel(rid, self.q.now())
                {
                    self.close_hold(tid, rid, &r, 0.0);
                }
            }
        }
        let spec = self.tb.resources[i].clone();
        let downtime = self.dyns[i].draw_downtime(&spec);
        self.q.schedule_in(downtime, Ev::Recover { rid });
    }

    fn on_recover(&mut self, rid: ResourceId) {
        let i = rid.0 as usize;
        self.dyns[i].up = true;
        let spec = self.tb.resources[i].clone();
        let uptime = self.dyns[i].draw_uptime(&spec);
        self.q.schedule_in(uptime, Ev::Fail { rid });
    }

    fn dec_total_in_flight(&mut self, rid: ResourceId) {
        let c = &mut self.total_in_flight[rid.0 as usize];
        debug_assert!(*c > 0, "world in-flight underflow on {rid}");
        *c = c.saturating_sub(1);
    }
}

/// The one demand-signal formula: fraction of a machine's CPUs occupied by
/// tenants' in-flight jobs, background competition claims and
/// reservation-held slots (0 when the subsystem is off), clamped to
/// [0, 1]. Shared by billing ([`GridWorld::utilization`]), the scheduler's
/// view refresh and the price-index sampler, so tenants are always
/// scheduled on the same rate they are billed at.
fn utilization_of(in_flight: u32, claimed: u32, reserved: u32, cpus: u32) -> f64 {
    if cpus == 0 {
        return 0.0;
    }
    ((in_flight + claimed + reserved) as f64 / cpus as f64).min(1.0)
}

/// Posted G$/CPU-second on `rid` for `user` right now (owner price at the
/// owner's local hour, before competition/demand premiums).
fn posted_quote(
    tb: &Testbed,
    start_utc_hour: f64,
    now: SimTime,
    user: &str,
    rid: ResourceId,
) -> GridDollars {
    let spec = tb.spec(rid);
    let lh = local_hour(
        start_utc_hour + now / 3600.0,
        tb.site(spec.site).tz_offset_hours,
    );
    spec.price.rate_at(lh, user)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Broker;

    fn three_tenant_world(seed: u64) -> GridWorld {
        Broker::experiment()
            .plan(
                "parameter i integer range from 1 to 40\n\
                 task main\nexecute icc $i\nendtask",
            )
            .deadline_h(18.0)
            .policy("cost")
            .user("rajkumar")
            .seed(seed)
            .testbed_scale(0.5)
            .tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 40\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(10.0)
                    .policy("time")
                    .user("davida"),
            )
            .tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 40\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(14.0)
                    .policy("deadline-only")
                    .user("stranger"),
            )
            .world()
            .unwrap()
    }

    #[test]
    fn grid_jid_roundtrip() {
        for tid in [0usize, 1, 7, 200] {
            for j in [0u32, 1, 165, (1 << TENANT_ID_SHIFT) - 1] {
                let g = grid_jid(tid, JobId(j));
                assert_eq!(split_jid(g), (tid, JobId(j)));
            }
        }
        // Tenant 0 ids are identical to engine ids (N = 1 bit-exactness).
        assert_eq!(grid_jid(0, JobId(42)), JobId(42));
    }

    #[test]
    fn multi_tenant_world_completes_all_tenants() {
        let wr = three_tenant_world(11).run_world();
        assert_eq!(wr.tenants.len(), 3);
        for t in &wr.tenants {
            assert_eq!(
                t.report.jobs_completed + t.report.jobs_failed,
                t.report.jobs_total,
                "{} ({}): {}",
                t.user,
                t.policy,
                t.report.summary()
            );
            assert!(t.report.jobs_completed >= 35, "{}", t.report.summary());
        }
        assert!(wr.events > 100);
    }

    #[test]
    fn tenants_diverge_by_policy() {
        // Same workload, different policies: the time optimizer must finish
        // no later than the cost optimizer, and realized costs must differ
        // — tenants are real competitors, not clones.
        let wr = three_tenant_world(5).run_world();
        let cost = &wr.tenants[0].report;
        let time = &wr.tenants[1].report;
        assert!(
            time.makespan_s <= cost.makespan_s,
            "time-opt {:.2}h vs cost-opt {:.2}h",
            time.makespan_s / HOUR,
            cost.makespan_s / HOUR
        );
        assert!(
            (cost.total_cost - time.total_cost).abs() > 1e-9,
            "policies should realize different costs"
        );
    }

    #[test]
    fn world_is_deterministic() {
        let a = three_tenant_world(9).run_world();
        let b = three_tenant_world(9).run_world();
        assert_eq!(a.events, b.events);
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                x.report.makespan_s.to_bits(),
                y.report.makespan_s.to_bits()
            );
            assert_eq!(
                x.report.total_cost.to_bits(),
                y.report.total_cost.to_bits()
            );
            assert_eq!(x.report.busy_cpus.points(), y.report.busy_cpus.points());
        }
    }

    #[test]
    fn multi_tenant_incremental_views_match_full_rebuild_bit_exactly() {
        // The per-tenant dirty-tracking tables are a pure optimization even
        // under cross-tenant dirtying: forcing full rebuilds every tick
        // must replay the exact same world trace while touching far more
        // entries.
        let a = three_tenant_world(7).run_world();
        let mut forced = three_tenant_world(7);
        forced.set_full_view_rebuild(true);
        let b = forced.run_world();
        assert_eq!(a.events, b.events);
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(x.report.ticks, y.report.ticks);
            assert_eq!(
                x.report.makespan_s.to_bits(),
                y.report.makespan_s.to_bits()
            );
            assert_eq!(
                x.report.total_cost.to_bits(),
                y.report.total_cost.to_bits()
            );
            assert_eq!(x.report.busy_cpus.points(), y.report.busy_cpus.points());
            assert!(
                x.report.view_refreshes < y.report.view_refreshes,
                "incremental should touch fewer entries: {} vs {}",
                x.report.view_refreshes,
                y.report.view_refreshes
            );
        }
    }

    #[test]
    fn incremental_index_matches_full_allocation_sort_bit_exactly() {
        // The candidate index is a pure optimization over per-tick sorting:
        // forcing a full re-rank of every tenant's index on every tick must
        // replay the exact same world trace.
        let a = three_tenant_world(7).run_world();
        let mut forced = three_tenant_world(7);
        forced.set_full_allocation_sort(true);
        let b = forced.run_world();
        assert_eq!(a.events, b.events);
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(x.report.ticks, y.report.ticks);
            assert_eq!(
                x.report.makespan_s.to_bits(),
                y.report.makespan_s.to_bits()
            );
            assert_eq!(
                x.report.total_cost.to_bits(),
                y.report.total_cost.to_bits()
            );
            assert_eq!(x.report.busy_cpus.points(), y.report.busy_cpus.points());
        }
    }

    #[test]
    fn slot_conservation_holds_throughout_a_contended_run() {
        let mut world = three_tenant_world(3);
        let mut t = 0.0;
        while !world.finished() && t < 30.0 * HOUR {
            t += 0.5 * HOUR;
            world.run_until(t);
            assert!(
                world.slot_conservation_ok(),
                "slot conservation violated at t={t}"
            );
        }
        assert!(world.finished(), "tenants should finish inside 30h");
    }

    fn grace_world(seed: u64, market: GraceConfig) -> GridWorld {
        Broker::experiment()
            .plan(
                "parameter i integer range from 1 to 40\n\
                 task main\nexecute icc $i\nendtask",
            )
            .deadline_h(18.0)
            .policy("cost")
            .user("rajkumar")
            .budget(2.0e6)
            .seed(seed)
            .testbed_scale(0.5)
            .demand_pricing(0.5)
            .grace_market(market)
            .tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 40\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(10.0)
                    .policy("time")
                    .user("davida"),
            )
            .tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 40\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(14.0)
                    .policy("deadline-only")
                    .user("stranger"),
            )
            .world()
            .unwrap()
    }

    #[test]
    fn posted_price_worlds_carry_no_market_data() {
        let wr = three_tenant_world(11).run_world();
        assert!(!wr.has_market_data());
        assert!(wr.clearing_prices.is_empty());
        for t in &wr.tenants {
            assert_eq!(t.agreements_won, 0);
            assert_eq!(t.negotiation_rounds, 0);
            assert_eq!(t.failed_negotiations, 0);
        }
    }

    #[test]
    fn grace_world_completes_with_agreements() {
        let wr = grace_world(13, GraceConfig::default()).run_world();
        assert_eq!(wr.tenants.len(), 3);
        for t in &wr.tenants {
            assert_eq!(
                t.report.jobs_completed + t.report.jobs_failed,
                t.report.jobs_total,
                "{} ({}): {}",
                t.user,
                t.policy,
                t.report.summary()
            );
        }
        assert!(wr.has_market_data());
        assert!(
            wr.agreements_won() > 0,
            "auctions must strike agreements: {}",
            wr.summary()
        );
        assert!(
            !wr.clearing_prices.is_empty(),
            "clearing-price trajectory must be sampled"
        );
        // One negotiation round can award many agreements, so the figure
        // can sit below 1 — it just has to be a real positive ratio.
        assert!(
            wr.rounds_per_agreement() > 0.0,
            "agreements imply tender rounds: {}",
            wr.rounds_per_agreement()
        );
        let share_sum: f64 = wr.award_share().iter().sum();
        assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to 1");
    }

    #[test]
    fn grace_world_is_deterministic() {
        let a = grace_world(9, GraceConfig::default()).run_world();
        let b = grace_world(9, GraceConfig::default()).run_world();
        assert_eq!(a.events, b.events);
        assert_eq!(a.agreements_won(), b.agreements_won());
        assert_eq!(a.clearing_prices.len(), b.clearing_prices.len());
        for ((ta, pa), (tb, pb)) in
            a.clearing_prices.iter().zip(&b.clearing_prices)
        {
            assert_eq!(ta.to_bits(), tb.to_bits());
            assert_eq!(pa.to_bits(), pb.to_bits());
        }
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                x.report.total_cost.to_bits(),
                y.report.total_cost.to_bits()
            );
            assert_eq!(
                x.report.makespan_s.to_bits(),
                y.report.makespan_s.to_bits()
            );
            assert_eq!(x.agreements_won, y.agreements_won);
        }
    }

    #[test]
    fn grace_incremental_views_match_full_rebuild_bit_exactly() {
        // Award/expiry dirtying must be exact, including agreements that
        // lapse *between* directory refreshes (TTL below the refresh
        // period): a missed or late mark would diverge from the
        // rebuild-every-tick baseline.
        let short_ttl = GraceConfig {
            agreement_ttl_s: 90.0, // < MDS_REFRESH_PERIOD_S: lapses mid-sweep
            ..GraceConfig::default()
        };
        for cfg in [GraceConfig::default(), short_ttl] {
            let a = grace_world(7, cfg.clone()).run_world();
            let mut forced = grace_world(7, cfg);
            forced.set_full_view_rebuild(true);
            let b = forced.run_world();
            assert_eq!(a.events, b.events);
            assert_eq!(a.agreements_won(), b.agreements_won());
            for (x, y) in a.tenants.iter().zip(&b.tenants) {
                assert_eq!(
                    x.report.makespan_s.to_bits(),
                    y.report.makespan_s.to_bits()
                );
                assert_eq!(
                    x.report.total_cost.to_bits(),
                    y.report.total_cost.to_bits()
                );
                assert!(
                    x.report.view_refreshes < y.report.view_refreshes,
                    "incremental should touch fewer entries: {} vs {}",
                    x.report.view_refreshes,
                    y.report.view_refreshes
                );
            }
        }
    }

    #[test]
    fn grace_agreements_expire_mid_sweep() {
        // TTL below the refresh period: every award lapses before the next
        // auction can renew it. The first auction runs at the first MDS
        // refresh (t = 120 s); its agreements must be live just after and
        // dead before the next refresh.
        let mut world = grace_world(
            5,
            GraceConfig {
                agreement_ttl_s: 60.0,
                ..GraceConfig::default()
            },
        );
        world.run_until(121.0);
        let live: usize = (0..world.tenant_count())
            .map(|tid| world.active_agreements_at(tid, 121.0))
            .sum();
        assert!(live > 0, "first auction should strike agreements");
        let lapsed: usize = (0..world.tenant_count())
            .map(|tid| world.active_agreements_at(tid, 200.0))
            .sum();
        assert_eq!(lapsed, 0, "TTL 60 s awards from t=120 lapse by t=200");
        // And the run still finishes with every invariant intact.
        let mut t = 200.0;
        while !world.finished() && t < 40.0 * HOUR {
            t += 0.5 * HOUR;
            world.run_until(t);
            assert!(world.slot_conservation_ok(), "slots violated at {t}");
            let ledger = world.ledger(0);
            if let Some(budget) = ledger.budget() {
                assert!(
                    ledger.exposure() <= budget + 1e-6,
                    "exposure {} over budget {budget}",
                    ledger.exposure()
                );
            }
        }
        assert!(world.finished(), "grace world should finish inside 40h");
    }

    #[test]
    fn grace_invariants_hold_every_tick() {
        // Slot conservation and settled+committed ≤ budget, sampled densely
        // across a whole auction-market run (the ISSUE-4 acceptance gate).
        let mut world = grace_world(3, GraceConfig::default());
        let mut t = 0.0;
        while !world.finished() && t < 40.0 * HOUR {
            t += 0.25 * HOUR;
            world.run_until(t);
            assert!(
                world.slot_conservation_ok(),
                "slot conservation violated at t={t}"
            );
            for tid in 0..world.tenant_count() {
                let ledger = world.ledger(tid);
                if let Some(budget) = ledger.budget() {
                    assert!(
                        ledger.exposure() <= budget + 1e-6,
                        "tenant {tid} exposure {} over budget {budget} at t={t}",
                        ledger.exposure()
                    );
                }
            }
        }
        assert!(world.finished(), "grace world should finish inside 40h");
    }

    #[test]
    fn grace_agreements_change_realized_prices() {
        // Same seed, same grid, market on vs off: an auction world must
        // realize a different total spend than the posted-price world —
        // won prices, not posted rates, are what DBC schedules and settles
        // against.
        let build = |grace: bool| {
            let mut b = Broker::experiment()
                .plan(
                    "parameter i integer range from 1 to 40\n\
                     task main\nexecute icc $i\nendtask",
                )
                .deadline_h(18.0)
                .policy("cost")
                .seed(21)
                .testbed_scale(0.5)
                .demand_pricing(0.5)
                .tenant(
                    Broker::experiment()
                        .plan(
                            "parameter i integer range from 1 to 40\n\
                             task main\nexecute icc $i\nendtask",
                        )
                        .deadline_h(10.0)
                        .policy("time")
                        .user("davida"),
                );
            if grace {
                b = b.grace_market(GraceConfig::default());
            }
            b.run_world().unwrap()
        };
        let auction = build(true);
        let flat = build(false);
        assert!(auction.agreements_won() > 0);
        assert_eq!(flat.agreements_won(), 0);
        let total = |wr: &WorldReport| -> f64 {
            wr.tenants.iter().map(|t| t.report.total_cost).sum()
        };
        assert!(
            (total(&auction) - total(&flat)).abs() > 1e-6,
            "agreement pricing must move realized spend: {} vs {}",
            total(&auction),
            total(&flat)
        );
    }

    /// A contested, demand-priced world with the reservation subsystem on.
    /// The low trigger fraction arms reserve-ahead while plenty of work is
    /// still undispatched, so every seed exercises the full
    /// probe → reserve → commit ladder.
    fn reservation_world(seed: u64, cfg: ReservationConfig) -> GridWorld {
        Broker::experiment()
            .plan(
                "parameter i integer range from 1 to 40\n\
                 task main\nexecute icc $i\nendtask",
            )
            .deadline_h(18.0)
            .policy("cost")
            .user("rajkumar")
            .budget(2.0e6)
            .seed(seed)
            .testbed_scale(0.5)
            .demand_pricing(0.5)
            // Background claims make the extended invariant three-termed
            // for real: arrivals must respect in-flight AND held slots.
            .competition(crate::grid::competition::CompetitionModel {
                mean_interarrival_s: 3600.0,
                mean_duration_s: 2.0 * 3600.0,
                mean_cpus: 30.0,
            })
            .reservations(cfg)
            .tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 40\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(10.0)
                    .policy("time")
                    .user("davida")
                    .budget(2.0e6),
            )
            .tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 40\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(14.0)
                    .policy("deadline-only")
                    .user("stranger")
                    .budget(2.0e6),
            )
            .world()
            .unwrap()
    }

    fn eager() -> ReservationConfig {
        ReservationConfig {
            trigger_frac: 0.05,
            ..ReservationConfig::default()
        }
    }

    #[test]
    fn posted_worlds_carry_no_reservation_data() {
        let wr = three_tenant_world(11).run_world();
        assert!(!wr.has_reservation_data());
        for t in &wr.tenants {
            assert_eq!(t.reservation_probes, 0);
            assert_eq!(t.reservations_committed, 0);
            assert_eq!(t.reservations_cancelled, 0);
            assert_eq!(t.held_slot_seconds.to_bits(), 0.0f64.to_bits());
            assert_eq!(t.penalty_spend.to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn reservation_world_completes_and_commits() {
        let wr = reservation_world(13, eager()).run_world();
        assert_eq!(wr.tenants.len(), 3);
        for t in &wr.tenants {
            assert_eq!(
                t.report.jobs_completed + t.report.jobs_failed,
                t.report.jobs_total,
                "{} ({}): {}",
                t.user,
                t.policy,
                t.report.summary()
            );
        }
        assert!(wr.has_reservation_data());
        // The reserve-ahead move probed ≥ 2 candidate sets and committed
        // the cheapest — the lifecycle ran end to end.
        let probes: u64 = wr.tenants.iter().map(|t| t.reservation_probes).sum();
        assert!(probes >= 2, "reserve-ahead must probe ≥ 2 sets: {probes}");
        assert!(
            wr.reservations_committed() > 0,
            "someone must commit a hold: {}",
            wr.summary()
        );
        let held: f64 = wr.tenants.iter().map(|t| t.held_slot_seconds).sum();
        assert!(held > 0.0, "committed holds accrue held slot-seconds");
    }

    #[test]
    fn reservation_world_is_deterministic() {
        let a = reservation_world(9, eager()).run_world();
        let b = reservation_world(9, eager()).run_world();
        assert_eq!(a.events, b.events);
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                x.report.makespan_s.to_bits(),
                y.report.makespan_s.to_bits()
            );
            assert_eq!(
                x.report.total_cost.to_bits(),
                y.report.total_cost.to_bits()
            );
            assert_eq!(x.report.busy_cpus.points(), y.report.busy_cpus.points());
            assert_eq!(x.reservation_probes, y.reservation_probes);
            assert_eq!(x.reservations_committed, y.reservations_committed);
            assert_eq!(x.reservations_cancelled, y.reservations_cancelled);
            assert_eq!(
                x.held_slot_seconds.to_bits(),
                y.held_slot_seconds.to_bits()
            );
            assert_eq!(x.penalty_spend.to_bits(), y.penalty_spend.to_bits());
        }
    }

    #[test]
    fn reservation_incremental_views_match_full_rebuild_bit_exactly() {
        // Hold transitions dirty views and index entries like every other
        // occupancy event; a missed or late mark would diverge from the
        // rebuild-every-tick baseline. The short commit timeout forces
        // mid-run expiries (commit timeouts *and* hold expiries) into the
        // trace being compared.
        let quick_lapse = ReservationConfig {
            trigger_frac: 0.05,
            hold_s: 1800.0,
            ..ReservationConfig::default()
        };
        for cfg in [eager(), quick_lapse] {
            let a = reservation_world(7, cfg.clone()).run_world();
            let mut forced = reservation_world(7, cfg);
            forced.set_full_view_rebuild(true);
            forced.set_full_allocation_sort(true);
            let b = forced.run_world();
            assert_eq!(a.events, b.events);
            for (x, y) in a.tenants.iter().zip(&b.tenants) {
                assert_eq!(
                    x.report.makespan_s.to_bits(),
                    y.report.makespan_s.to_bits()
                );
                assert_eq!(
                    x.report.total_cost.to_bits(),
                    y.report.total_cost.to_bits()
                );
                assert_eq!(x.reservations_committed, y.reservations_committed);
                assert_eq!(x.penalty_spend.to_bits(), y.penalty_spend.to_bits());
                assert!(
                    x.report.view_refreshes < y.report.view_refreshes,
                    "incremental should touch fewer entries: {} vs {}",
                    x.report.view_refreshes,
                    y.report.view_refreshes
                );
            }
        }
    }

    #[test]
    fn reservation_invariants_hold_every_tick() {
        // The extended invariant (in-flight + claims + reserved ≤ CPUs) and
        // settled+committed ≤ budget, sampled densely across a reservation
        // run with churn and competition in play.
        let mut world = reservation_world(3, eager());
        let mut t = 0.0;
        while !world.finished() && t < 40.0 * HOUR {
            t += 0.25 * HOUR;
            world.run_until(t);
            assert!(
                world.slot_conservation_ok(),
                "slot conservation violated at t={t}"
            );
            for tid in 0..world.tenant_count() {
                let ledger = world.ledger(tid);
                if let Some(budget) = ledger.budget() {
                    assert!(
                        ledger.exposure() <= budget + 1e-6,
                        "tenant {tid} exposure {} over budget {budget} at t={t}",
                        ledger.exposure()
                    );
                }
            }
        }
        assert!(world.finished(), "reservation world should finish inside 40h");
    }

    #[test]
    fn demand_pricing_moves_the_price_index() {
        let base = |slope: f64| {
            Broker::experiment()
                .plan(
                    "parameter i integer range from 1 to 60\n\
                     task main\nexecute icc $i\nendtask",
                )
                .deadline_h(8.0)
                .policy("time")
                .seed(21)
                .testbed_scale(0.5)
                .demand_pricing(slope)
                .tenant(
                    Broker::experiment()
                        .plan(
                            "parameter i integer range from 1 to 60\n\
                             task main\nexecute icc $i\nendtask",
                        )
                        .deadline_h(8.0)
                        .policy("time")
                        .user("davida"),
                )
                .world()
                .unwrap()
                .run_world()
        };
        let flat = base(0.0);
        let priced = base(0.9);
        assert!(flat.peak_premium <= 1.0 + 1e-9, "no premium without slope");
        assert!(
            priced.peak_premium > 1.0,
            "busy machines must reprice: peak {}",
            priced.peak_premium
        );
        let total = |wr: &WorldReport| -> f64 {
            wr.tenants.iter().map(|t| t.report.total_cost).sum()
        };
        assert!(
            total(&priced) > total(&flat),
            "demand pricing must raise realized spend: {} vs {}",
            total(&priced),
            total(&flat)
        );
    }

    /// Bit-exact world-trace comparison for the spawn-strategy tests
    /// below (wall-clock telemetry excluded, like `tests/common`).
    fn assert_same_trace(a: &WorldReport, b: &WorldReport, tag: &str) {
        assert_eq!(a.events, b.events, "{tag}: event counts diverged");
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(x.report.ticks, y.report.ticks, "{tag}: ticks");
            assert_eq!(
                x.report.makespan_s.to_bits(),
                y.report.makespan_s.to_bits(),
                "{tag}: makespan"
            );
            assert_eq!(
                x.report.total_cost.to_bits(),
                y.report.total_cost.to_bits(),
                "{tag}: spend"
            );
            assert_eq!(
                x.report.busy_cpus.points(),
                y.report.busy_cpus.points(),
                "{tag}: busy-cpu timeline"
            );
        }
    }

    #[test]
    fn pooled_and_scoped_spawn_replay_the_sequential_trace() {
        // Three spawn strategies, one trace: the sequential reference, the
        // persistent worker pool, and the scoped per-batch spawn baseline
        // must be pure scheduling choices with zero trace influence.
        let sequential = three_tenant_world(17).run_world();
        let mut pooled_world = three_tenant_world(17);
        pooled_world.set_threads(3);
        let pooled = pooled_world.run_world();
        assert_same_trace(&sequential, &pooled, "pooled");
        let mut scoped_world = three_tenant_world(17);
        scoped_world.set_threads(3);
        scoped_world.set_scoped_spawn(true);
        let scoped = scoped_world.run_world();
        assert_same_trace(&sequential, &scoped, "scoped");
        // And the telemetry tells the three apart: only the pooled run
        // built a pool and fanned batches through it.
        assert_eq!(sequential.pool_workers, 0);
        assert_eq!(sequential.pool_rounds, 0);
        assert_eq!(pooled.pool_workers, 3, "pool sized to the lane count");
        assert!(pooled.pool_rounds > 0, "no batch went through the pool");
        assert_eq!(scoped.pool_workers, 0, "scoped baseline must stay pool-free");
        assert_eq!(scoped.pool_rounds, 0);
    }

    #[test]
    fn pool_handles_batch_membership_changing_between_rounds() {
        // Staggered tick periods (600/600/1800 s) make batch membership
        // breathe: most batches hold two members, every third holds all
        // three, and as tenants finish the batches shrink further —
        // singletons take the legacy path entirely. The pool keeps its
        // original lane count throughout and must drain every width
        // bit-exactly.
        let build = || {
            Broker::experiment()
                .plan(
                    "parameter i integer range from 1 to 40\n\
                     task main\nexecute icc $i\nendtask",
                )
                .deadline_h(18.0)
                .policy("cost")
                .user("rajkumar")
                .seed(29)
                .testbed_scale(0.5)
                .tick_period_s(600.0)
                .tenant(
                    Broker::experiment()
                        .plan(
                            "parameter i integer range from 1 to 40\n\
                             task main\nexecute icc $i\nendtask",
                        )
                        .deadline_h(10.0)
                        .policy("time")
                        .user("davida")
                        .tick_period_s(600.0),
                )
                .tenant(
                    Broker::experiment()
                        .plan(
                            "parameter i integer range from 1 to 8\n\
                             task main\nexecute icc $i\nendtask",
                        )
                        .deadline_h(14.0)
                        .policy("deadline-only")
                        .user("stranger")
                        .tick_period_s(1800.0),
                )
                .world()
                .unwrap()
        };
        let sequential = build().run_world();
        let mut pooled_world = build();
        pooled_world.set_threads(3);
        let pooled = pooled_world.run_world();
        assert_same_trace(&sequential, &pooled, "breathing-batches");
        assert!(pooled.pool_rounds > 0, "no batch went through the pool");
        for t in &pooled.tenants {
            assert_eq!(
                t.report.jobs_completed + t.report.jobs_failed,
                t.report.jobs_total,
                "{}: {}",
                t.user,
                t.report.summary()
            );
        }
    }

    #[test]
    fn set_threads_discards_a_stale_pool() {
        // Mid-run thread-count changes rebuild the pool at the new width
        // on the next fan-out batch; the trace must not notice.
        let sequential = three_tenant_world(23).run_world();
        let mut world = three_tenant_world(23);
        world.set_threads(2);
        world.run_until(2.0 * HOUR);
        let early_rounds = world.pool_rounds();
        assert!(early_rounds > 0, "pool should have run by 2h");
        world.set_threads(3); // drops the 2-lane pool
        let resized = world.run_world();
        assert_same_trace(&sequential, &resized, "resized-mid-run");
        assert_eq!(resized.pool_workers, 3, "report reflects the new width");
        assert!(resized.pool_rounds > early_rounds, "new pool kept running");
    }

    #[test]
    fn streaming_and_barrier_merge_replay_the_sequential_trace() {
        // The streaming ordered merge is a pure latency optimization: at
        // every lane count, commits applied mid-flight (streaming) and
        // commits drained after the barrier must replay the exact same
        // world trace as the sequential reference.
        let sequential = three_tenant_world(37).run_world();
        for lanes in [2usize, 3] {
            let mut streaming_world = three_tenant_world(37);
            streaming_world.set_threads(lanes);
            let streaming = streaming_world.run_world();
            assert_same_trace(
                &sequential,
                &streaming,
                &format!("streaming@{lanes}"),
            );
            let mut barrier_world = three_tenant_world(37);
            barrier_world.set_threads(lanes);
            barrier_world.set_barrier_merge(true);
            let barrier = barrier_world.run_world();
            assert_same_trace(&sequential, &barrier, &format!("barrier@{lanes}"));
            // Overlap telemetry separates the modes: a barrier drain can
            // never overlap the lanes, and the sequential world has no
            // lanes to overlap with at all.
            assert_eq!(barrier.merge_overlap_ns, 0, "barrier cannot overlap");
        }
        assert_eq!(sequential.merge_overlap_ns, 0);
    }

    #[test]
    fn streaming_merge_matches_barrier_on_grace_auctions() {
        // Grace auctions route agreement state through the tick path; the
        // commit queue must defer its GRAM cancels and view marks exactly
        // like the barrier drain did.
        let market = GraceConfig::default();
        let sequential = grace_world(13, market.clone()).run_world();
        let mut streaming_world = grace_world(13, market.clone());
        streaming_world.set_threads(2);
        let streaming = streaming_world.run_world();
        assert_same_trace(&sequential, &streaming, "grace-streaming");
        let mut barrier_world = grace_world(13, market);
        barrier_world.set_threads(2);
        barrier_world.set_barrier_merge(true);
        let barrier = barrier_world.run_world();
        assert_same_trace(&sequential, &barrier, "grace-barrier");
    }

    #[test]
    fn streaming_merge_matches_barrier_on_reservations() {
        // Reserve-ahead worlds exercise the committed-hold fast path in
        // the merge capacity guard.
        let cfg = ReservationConfig::default();
        let sequential = reservation_world(19, cfg.clone()).run_world();
        let mut streaming_world = reservation_world(19, cfg.clone());
        streaming_world.set_threads(2);
        let streaming = streaming_world.run_world();
        assert_same_trace(&sequential, &streaming, "resv-streaming");
        let mut barrier_world = reservation_world(19, cfg);
        barrier_world.set_threads(2);
        barrier_world.set_barrier_merge(true);
        let barrier = barrier_world.run_world();
        assert_same_trace(&sequential, &barrier, "resv-barrier");
    }

    #[test]
    fn batch_scratch_buffers_stop_regrowing_after_warmup() {
        // Phase-2/3 scratch (member lists, forked RNGs, deferred mark and
        // cancel queues, per-tenant action buffers) is reused across
        // batches; after first-batch warmup the capacities must plateau.
        // The counter only ticks when an already-warm buffer regrows, so a
        // full run should see at most a handful of regrowth events.
        let mut world = three_tenant_world(41);
        world.set_threads(3);
        world.run_until(SimTime::MAX);
        assert!(world.pool_rounds() > 0, "pool should have fanned out");
        assert!(
            world.scratch_regrows() <= 16,
            "batch scratch kept regrowing: {} regrowth events",
            world.scratch_regrows()
        );
    }

    #[test]
    fn dropping_a_world_mid_run_shuts_the_pool_down() {
        // The pool joins its workers on Drop (unit-proven in sim::pool);
        // at world level this is the no-hang smoke: a half-run parallel
        // world must drop cleanly, not leak or deadlock on parked workers.
        let mut world = three_tenant_world(31);
        world.set_threads(3);
        world.run_until(2.0 * HOUR);
        assert!(world.pool_rounds() > 0, "pool should have run by 2h");
        drop(world);
    }
}
