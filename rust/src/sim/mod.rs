//! The discrete-event experiment driver: Nimrod/G running over the
//! simulated GUSTO testbed in virtual time.
//!
//! Wires every component the paper's Figure 2 shows: the parametric engine
//! ([`crate::engine`]) holds job state; each scheduler tick discovers
//! resources through MDS, quotes prices from the economy, and hands the
//! assembled views to the shared [`crate::broker::ScheduleAdvisor`] (which
//! runs the configured policy and reconciles via the dispatcher); GRAM job
//! managers enforce queue semantics; GASS + the cluster proxy time the
//! staging; background load and availability churn perturb everything.
//!
//! Construct through [`crate::broker::ExperimentBuilder`]
//! (`Broker::experiment()…simulate()`); the [`GridSimulation::new`] /
//! [`GridSimulation::gusto_ionization`] constructors remain for direct use.
//!
//! Per-job event chain:
//!
//! ```text
//! Submit ─stage-in──▶ StagedIn ─queue──▶ BeginExec ─exec+stage-out──▶ Complete
//!    (GASS/proxy)       (GRAM)              (engine Running)           (settle)
//! ```
//!
//! **Incremental view table.** The scheduler tick does not rebuild every
//! [`ResourceView`] from an MDS sweep: the simulation keeps one persistent
//! view per resource and the events that actually change scheduler-visible
//! state dirty exactly the entries they touch — an MDS refresh dirties only
//! records whose up/load changed (outages and recoveries become visible
//! there, preserving the paper's stale-directory semantics), job
//! dispatch/start/completion/failure touches the one resource it ran on,
//! competitor arrivals/departures touch the claimed machines, and owners
//! with time-of-day pricing are re-marked only when their local clock
//! crosses an hour boundary. Each tick then
//! refreshes the dirty entries (O(changed), not O(resources)) before
//! handing the table to the shared advisor, which is what lets a quiet
//! 10k-machine grid tick in near-constant time (see
//! `benches/grid_scaling.rs`).
//!
//! A 20-hour trial replays in a few milliseconds; identical seeds produce
//! identical traces (see `rust/tests/`).

pub mod live;

use crate::broker::{ScheduleAdvisor, TickCtx};
use crate::config::ExperimentConfig;
use crate::dispatcher::Action;
use crate::economy::Ledger;
use crate::engine::journal::Journal;
use crate::engine::{Experiment, JobState};
use crate::grid::competition::Competition;
use crate::grid::dynamics::{ResourceDyn, LOAD_UPDATE_PERIOD_S};
use crate::grid::gass::Gass;
use crate::grid::mds::{Mds, MDS_REFRESH_PERIOD_S};
use crate::grid::proxy::ClusterProxy;
use crate::grid::testbed::{local_hour, Testbed};
use crate::grid::JobManager;
use crate::metrics::{Report, ResourceUsage};
use crate::plan::JobSpec;
use crate::scheduler::ResourceView;
use crate::simtime::EventQueue;
use crate::types::{GridDollars, JobId, ResourceId, SimTime, HOUR};
use crate::util::rng::Rng;
use crate::workload::WorkSampler;
use std::collections::BTreeMap;

/// Simulation events.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Scheduler tick (discovery → selection → dispatch).
    Tick,
    /// Directory refresh.
    MdsRefresh,
    /// Background-load AR(1) step on all resources.
    LoadUpdate,
    /// Stage-in finished; hand the job to GRAM.
    StagedIn { rid: ResourceId, jid: JobId },
    /// GRAM started the job (queue delay elapsed).
    BeginExec { rid: ResourceId, jid: JobId },
    /// Execution + stage-out finished.
    Complete { rid: ResourceId, jid: JobId },
    /// Availability churn.
    Fail { rid: ResourceId },
    Recover { rid: ResourceId },
    /// A competing experiment lands on the grid (paper §3).
    CompetitorArrive,
    /// Competing experiments holding until `now` leave.
    CompetitorDepart,
}

/// Per-in-flight-job bookkeeping.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    dispatched_at: SimTime,
    exec_started: Option<SimTime>,
    /// G$/CPU-second locked at execution start.
    rate: GridDollars,
    /// Work drawn for this job, reference CPU-hours.
    work_ref_h: f64,
    /// CPU seconds this job will consume on its machine.
    cpu_s: f64,
}

/// The simulation. Construct with [`GridSimulation::new`], call
/// [`GridSimulation::run`] for the final [`Report`].
pub struct GridSimulation {
    pub tb: Testbed,
    cfg: ExperimentConfig,
    dyns: Vec<ResourceDyn>,
    mds: Mds,
    gass: Gass,
    proxy: ClusterProxy,
    managers: Vec<JobManager>,
    pub exp: Experiment,
    pub ledger: Ledger,
    advisor: ScheduleAdvisor,
    sampler: WorkSampler,
    q: EventQueue<Ev>,
    rng: Rng,
    busy_cpus: u32,
    inflight: BTreeMap<JobId, InFlight>,
    report: Report,
    journal: Option<Journal>,
    /// Background competing-experiment process, if configured.
    competition: Option<Competition>,
    /// Stop even if jobs remain (budget exhaustion, dead grid).
    hard_stop: SimTime,
    /// Persistent per-resource view table (index = ResourceId). Entries
    /// are rebuilt only when marked dirty by a state-changing event.
    views: Vec<ResourceView>,
    view_dirty: Vec<bool>,
    dirty_queue: Vec<u32>,
    /// Static per-resource authorization for `cfg.user`; unauthorized
    /// entries stay zeroed forever and are never marked.
    authorized: Vec<bool>,
    /// Authorized time-of-day-priced resources grouped by site, with the
    /// site's hour phase (start hour + tz offset) — the only quotes that
    /// move on their own, and only when the site's local clock crosses an
    /// integer hour.
    tod_by_site: Vec<(f64, Vec<u32>)>,
    /// Virtual time of the previous scheduler tick (repricing check).
    last_tick_t: SimTime,
    /// Benchmark baseline: rebuild every entry on every tick.
    full_rebuild: bool,
}

impl GridSimulation {
    /// Build a simulation over `tb` running `specs` under `cfg`, resolving
    /// `cfg.policy` (a `name` or `name?key=value` spec) against the
    /// built-in policy registry. Panics on an unresolvable policy; use
    /// [`crate::broker::ExperimentBuilder`] for fallible construction.
    pub fn new(tb: Testbed, specs: Vec<JobSpec>, cfg: ExperimentConfig) -> Self {
        let advisor =
            ScheduleAdvisor::resolve(&cfg.policy, cfg.workload.job_work_ref_h)
                .unwrap_or_else(|e| panic!("{e:#}"));
        GridSimulation::with_advisor(tb, specs, cfg, advisor)
    }

    /// Build a simulation with an explicitly-constructed schedule advisor
    /// (the [`crate::broker::ExperimentBuilder`] path, which supports
    /// custom policy registries).
    pub fn with_advisor(
        tb: Testbed,
        specs: Vec<JobSpec>,
        cfg: ExperimentConfig,
        advisor: ScheduleAdvisor,
    ) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let dyns: Vec<ResourceDyn> = tb
            .resources
            .iter()
            .map(|s| ResourceDyn::new(s, &mut rng))
            .collect();
        let mds = Mds::new(&tb, &dyns);
        let managers = tb.resources.iter().map(JobManager::new).collect();
        let gass = Gass::new(&tb);
        let jobs_total = specs.len() as u32;
        let exp = Experiment::new(
            specs,
            cfg.deadline,
            cfg.budget,
            &cfg.user,
            cfg.max_attempts,
        );
        let ledger = Ledger::new(cfg.budget);
        let sampler = WorkSampler::new(&cfg.workload, cfg.seed ^ 0xF00D);
        let mut q = EventQueue::new();
        q.schedule_at(0.0, Ev::Tick);
        q.schedule_at(MDS_REFRESH_PERIOD_S, Ev::MdsRefresh);
        q.schedule_at(LOAD_UPDATE_PERIOD_S, Ev::LoadUpdate);
        let competition = cfg.competition.clone().map(|model| {
            Competition::new(&tb, model, rng.fork(0xC0117E7E))
        });
        if competition.is_some() {
            q.schedule_at(1.0, Ev::CompetitorArrive);
        }
        let hard_stop = cfg.deadline * 4.0 + 48.0 * HOUR;
        // Persistent view table: who this user may schedule on (static),
        // which owners reprice by local hour, and one zeroed view per
        // resource that the first tick fills in.
        let authorized: Vec<bool> = tb
            .resources
            .iter()
            .map(|r| r.auth.allows(&cfg.user))
            .collect();
        let mut tod_per_site: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for r in &tb.resources {
            if authorized[r.id.0 as usize] && r.price.time_of_day {
                tod_per_site.entry(r.site.0).or_default().push(r.id.0);
            }
        }
        let tod_by_site: Vec<(f64, Vec<u32>)> = tod_per_site
            .into_iter()
            .map(|(sid, rids)| {
                let theta = cfg.start_utc_hour
                    + tb.sites[sid as usize].tz_offset_hours;
                (theta, rids)
            })
            .collect();
        let views: Vec<ResourceView> = tb
            .resources
            .iter()
            .map(|r| ResourceView {
                id: r.id,
                slots: 0,
                planning_speed: 0.0,
                rate: 0.0,
                in_flight: 0,
                measured_jphps: None,
                batch_queue: false,
            })
            .collect();
        let n = tb.resources.len();
        let mut sim = GridSimulation {
            report: Report {
                jobs_total,
                deadline_s: cfg.deadline,
                ..Default::default()
            },
            tb,
            cfg,
            dyns,
            mds,
            gass,
            proxy: ClusterProxy::default(),
            managers,
            exp,
            ledger,
            advisor,
            sampler,
            q,
            rng,
            busy_cpus: 0,
            inflight: BTreeMap::new(),
            journal: None,
            competition,
            hard_stop,
            views,
            view_dirty: vec![false; n],
            dirty_queue: Vec::with_capacity(n),
            authorized,
            tod_by_site,
            last_tick_t: 0.0,
            full_rebuild: false,
        };
        // Seed availability churn per resource.
        for i in 0..sim.tb.resources.len() {
            let spec = sim.tb.resources[i].clone();
            let t = sim.dyns[i].draw_uptime(&spec);
            sim.q.schedule_at(t, Ev::Fail { rid: spec.id });
        }
        // Everything schedulable starts dirty; the first tick fills the
        // table from the t = 0 directory snapshot.
        for i in 0..sim.tb.resources.len() {
            sim.mark_view(ResourceId(i as u32));
        }
        sim
    }

    /// Convenience: paper-scale Figure-3 experiment over the GUSTO testbed.
    pub fn gusto_ionization(cfg: ExperimentConfig) -> Self {
        let tb = Testbed::gusto(cfg.seed ^ 0x6057, 1.0);
        let specs = crate::workload::ionization_jobs(cfg.seed);
        GridSimulation::new(tb, specs, cfg)
    }

    /// Attach a persistence journal (restart support).
    pub fn with_journal(mut self, journal: Journal) -> Self {
        self.journal = Some(journal);
        self
    }

    /// Replace the experiment (restart-from-journal path).
    pub fn with_experiment(mut self, exp: Experiment) -> Self {
        self.report.jobs_total = exp.jobs.len() as u32;
        self.exp = exp;
        self
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.q.now()
    }

    /// Posted G$/CPU-second on `rid` for the experiment user right now
    /// (owner price at the owner's local hour, before demand premium).
    fn quote(&self, rid: ResourceId) -> GridDollars {
        let spec = self.tb.spec(rid);
        let lh = local_hour(
            self.cfg.start_utc_hour + self.q.now() / 3600.0,
            self.tb.site(spec.site).tz_offset_hours,
        );
        spec.price.rate_at(lh, &self.cfg.user)
    }

    /// Effective rate including any competition demand premium — what jobs
    /// are actually billed at.
    fn effective_rate(&self, rid: ResourceId) -> GridDollars {
        let premium = self
            .competition
            .as_ref()
            .map(|c| c.demand_premium(&self.tb, rid))
            .unwrap_or(1.0);
        self.quote(rid) * premium
    }

    /// Run to completion (or hard stop); consume the sim, return the report.
    pub fn run(mut self) -> Report {
        while !self.exp.finished() {
            if self.q.now() > self.hard_stop {
                break;
            }
            let Some((_, ev)) = self.q.pop() else {
                break; // queue drained with jobs unfinished (dead grid)
            };
            self.handle(ev);
        }
        self.finalize()
    }

    /// Run until `t` (for incremental inspection in tests/examples).
    pub fn run_until(&mut self, t: SimTime) {
        while !self.exp.finished() {
            match self.q.next_time() {
                Some(nt) if nt <= t => {
                    let (_, ev) = self.q.pop().unwrap();
                    self.handle(ev);
                }
                _ => break,
            }
        }
    }

    /// Finalize the report after the event loop.
    pub fn finalize(mut self) -> Report {
        self.report.makespan_s = self.exp.makespan();
        self.report.jobs_completed = self.exp.completed();
        self.report.jobs_failed = self.exp.failed();
        self.report.deadline_met = self.report.jobs_completed
            + self.report.jobs_failed
            == self.report.jobs_total
            && self.report.makespan_s <= self.exp.deadline
            && self.report.jobs_failed == 0;
        self.report.total_cost = self.ledger.settled();
        self.report.resources_used = self
            .report
            .per_resource
            .values()
            .filter(|u| u.jobs_completed > 0)
            .count() as u32;
        self.report.events = self.q.processed();
        self.report
    }

    // -- event handlers ------------------------------------------------------

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Tick => self.on_tick(),
            Ev::MdsRefresh => {
                // Only records whose up/load actually moved invalidate
                // their view entry.
                let changed =
                    self.mds.refresh(&self.tb, &self.dyns, self.q.now());
                for rid in changed {
                    self.mark_view(rid);
                }
                self.q
                    .schedule_in(MDS_REFRESH_PERIOD_S, Ev::MdsRefresh);
            }
            Ev::LoadUpdate => {
                // Ground truth moves; the scheduler keeps seeing the stale
                // directory until the next MdsRefresh (no view marking).
                for i in 0..self.dyns.len() {
                    let spec = &self.tb.resources[i];
                    self.dyns[i].step_load(spec);
                }
                self.q.schedule_in(LOAD_UPDATE_PERIOD_S, Ev::LoadUpdate);
            }
            Ev::StagedIn { rid, jid } => self.on_staged_in(rid, jid),
            Ev::BeginExec { rid, jid } => self.on_begin_exec(rid, jid),
            Ev::Complete { rid, jid } => self.on_complete(rid, jid),
            Ev::Fail { rid } => self.on_fail(rid),
            Ev::Recover { rid } => self.on_recover(rid),
            Ev::CompetitorArrive => {
                let now = self.q.now();
                let claimed: Vec<ResourceId> = match &mut self.competition {
                    Some(comp) => {
                        let (departs, claimed) = comp.arrive(&self.tb, now);
                        self.q.schedule_at(departs, Ev::CompetitorDepart);
                        let next = comp.draw_interarrival();
                        self.q.schedule_in(next, Ev::CompetitorArrive);
                        claimed
                    }
                    None => Vec::new(),
                };
                // Premium and free slots changed on the claimed machines.
                for rid in claimed {
                    self.mark_view(rid);
                }
            }
            Ev::CompetitorDepart => {
                let now = self.q.now();
                let released = match &mut self.competition {
                    Some(comp) => comp.depart_until(now),
                    None => Vec::new(),
                };
                for rid in released {
                    self.mark_view(rid);
                }
            }
        }
    }

    /// Mark time-of-day-priced entries whose site's local clock crossed an
    /// integer hour since the previous tick — the only instants owner
    /// quotes can change (prices are piecewise-constant per local hour).
    /// Phase-aware, so fractional start hours and timezone offsets reprice
    /// exactly when the boundary passes, independent of the tick period or
    /// event ordering. O(sites with time-of-day pricing) per tick.
    fn mark_repriced(&mut self, now: SimTime) {
        let prev = self.last_tick_t;
        self.last_tick_t = now;
        if self.tod_by_site.is_empty() || now == prev {
            return;
        }
        let sites = std::mem::take(&mut self.tod_by_site);
        for (theta, rids) in &sites {
            if (theta + now / 3600.0).floor()
                > (theta + prev / 3600.0).floor()
            {
                for &r in rids {
                    self.mark_view(ResourceId(r));
                }
            }
        }
        self.tod_by_site = sites;
    }

    /// Invalidate one resource's view entry (no-op for machines this user
    /// cannot schedule on, and for entries already queued for refresh).
    fn mark_view(&mut self, rid: ResourceId) {
        let i = rid.0 as usize;
        if i < self.view_dirty.len() && self.authorized[i] && !self.view_dirty[i]
        {
            self.view_dirty[i] = true;
            self.dirty_queue.push(rid.0);
        }
    }

    /// Rebuild every dirty view entry from its sources: the (stale) MDS
    /// record, GRAM slots, competition-adjusted quote, engine in-flight
    /// count and the advisor's measured service rate. Cost is O(dirty);
    /// the pre-incremental pipeline paid O(resources) here every tick.
    fn refresh_dirty_views(&mut self) {
        if self.full_rebuild {
            for i in 0..self.views.len() {
                self.mark_view(ResourceId(i as u32));
            }
        }
        while let Some(r) = self.dirty_queue.pop() {
            let i = r as usize;
            self.view_dirty[i] = false;
            let rid = ResourceId(r);
            let rec = self.mds.record(rid).expect("record for every resource");
            let planning_speed = rec.planning_speed();
            let batch_queue = rec.batch_queue;
            let base_slots = self.managers[i].slots();
            let (slots, rate) = match &self.competition {
                Some(comp) => (
                    comp.free_slots(&self.tb, rid, base_slots),
                    self.quote(rid) * comp.demand_premium(&self.tb, rid),
                ),
                None => (base_slots, self.quote(rid)),
            };
            self.views[i] = ResourceView {
                id: rid,
                slots,
                planning_speed,
                rate,
                in_flight: self.exp.in_flight_on(rid),
                measured_jphps: self.advisor.measured_jphps(rid),
                batch_queue,
            };
            self.report.view_refreshes += 1;
        }
    }

    /// Benchmark support: rebuild the whole view table on every tick (the
    /// pre-incremental behaviour) instead of only dirty entries. The
    /// resulting trace is bit-identical — entries just get recomputed to
    /// the same values many more times. (Quotes are piecewise-constant per
    /// local hour and [`Self::mark_repriced`] dirties them exactly when a
    /// boundary passes, so the equivalence holds for any start hour,
    /// timezone offset or tick period.)
    pub fn set_full_view_rebuild(&mut self, on: bool) {
        self.full_rebuild = on;
    }

    fn on_tick(&mut self) {
        self.report.ticks += 1;
        let now = self.q.now();
        // 1. discovery + view maintenance: rebuild only the entries whose
        // inputs changed since the last tick (MDS deltas, churn, job
        // transitions, competition claims, local-hour repricing). Down and
        // unauthorized machines sit in the table with zero speed/slots;
        // every policy filters them out, exactly as discovery used to.
        self.mark_repriced(now);
        self.refresh_dirty_views();
        // 2+3. selection + assignment: the shared advisor pipeline.
        let job_work = self.advisor.job_work_ref_h();
        let actions = self.advisor.advise(
            TickCtx {
                now,
                deadline: self.exp.deadline,
                budget_headroom: self.ledger.headroom(),
                views: &self.views,
            },
            &self.exp,
            &mut self.rng,
        );
        for action in actions {
            match action {
                Action::Submit { job, rid } => self.submit(job, rid, job_work),
                Action::CancelQueued { job, rid } => self.cancel_queued(job, rid),
            }
        }
        if !self.exp.finished() {
            self.q.schedule_in(self.cfg.tick_period_s, Ev::Tick);
        }
    }

    fn submit(&mut self, jid: JobId, rid: ResourceId, job_work: f64) {
        let now = self.q.now();
        // Budget commit against the expected cost here.
        let spec = self.tb.spec(rid);
        let d = &self.dyns[rid.0 as usize];
        let speed = d.effective_speed(spec).max(0.05);
        let est_cost = self.effective_rate(rid) * job_work / speed * 3600.0;
        if !self.ledger.commit(jid, est_cost) {
            return; // budget headroom exhausted: leave the job Ready
        }
        if self.exp.dispatch(jid, rid, now).is_err() {
            self.ledger.release(jid, 0.0, &spec.name);
            return;
        }
        self.mark_view(rid); // in-flight count changed
        if let Some(j) = &mut self.journal {
            let _ = j.dispatched(jid, rid, now);
        }
        self.inflight.insert(
            jid,
            InFlight {
                dispatched_at: now,
                exec_started: None,
                rate: 0.0,
                work_ref_h: self.sampler.work_ref_h(jid),
                cpu_s: 0.0,
            },
        );
        // Stage-in through GASS (and the cluster proxy if private).
        let spec = self.tb.spec(rid).clone();
        let t_stage = self.proxy.begin(
            &mut self.gass,
            &self.tb,
            &spec,
            self.cfg.workload.input_bytes,
        );
        self.q.schedule_in(t_stage, Ev::StagedIn { rid, jid });
    }

    fn cancel_queued(&mut self, jid: JobId, rid: ResourceId) {
        // Withdraw from GRAM if it got there; mid-stage-in jobs are caught
        // at their StagedIn event by the state check.
        self.managers[rid.0 as usize].cancel(jid);
        let name = self.tb.spec(rid).name.clone();
        self.ledger.release(jid, 0.0, &name);
        if self.exp.release(jid).is_ok() {
            self.mark_view(rid); // in-flight count changed
            if let Some(j) = &mut self.journal {
                let _ = j.released(jid);
            }
        }
        self.inflight.remove(&jid);
    }

    fn on_staged_in(&mut self, rid: ResourceId, jid: JobId) {
        let spec = self.tb.spec(rid).clone();
        self.proxy.end(&mut self.gass, &spec);
        // The job may have been cancelled or the resource may have died
        // while staging.
        if self.exp.job(jid).state.resource() != Some(rid) {
            return;
        }
        if !self.dyns[rid.0 as usize].up {
            self.fail_in_flight(jid, rid);
            return;
        }
        self.managers[rid.0 as usize].submit(jid);
        self.try_start(rid);
    }

    /// Pump GRAM: start whatever the queue admits.
    fn try_start(&mut self, rid: ResourceId) {
        let now = self.q.now();
        let started = self.managers[rid.0 as usize].start_eligible(now);
        for (jid, delay) in started {
            self.q.schedule_in(delay, Ev::BeginExec { rid, jid });
        }
    }

    fn on_begin_exec(&mut self, rid: ResourceId, jid: JobId) {
        let now = self.q.now();
        if self.exp.job(jid).state.resource() != Some(rid) {
            return; // cancelled while waiting on the queue cycle
        }
        if !self.dyns[rid.0 as usize].up {
            return; // Fail handler already requeued it
        }
        let spec = self.tb.spec(rid);
        let speed = self.dyns[rid.0 as usize].effective_speed(spec).max(0.01);
        let rate = self.effective_rate(rid);
        let name = spec.name.clone();
        // CPU time on this machine: drawn work scaled by effective speed at
        // start (load drift during the run is absorbed into the draw).
        let work_ref_h = self.inflight[&jid].work_ref_h;
        let cpu_s = work_ref_h * 3600.0 / speed;
        // Replace the dispatch-time *estimate* with the now-known actual
        // cost. If the budget headroom no longer carries it, withdraw the
        // job (still Dispatched — a clean release, not a burned attempt)
        // instead of running over budget: this is what makes "spend never
        // exceeds budget" a hard invariant in virtual mode.
        self.ledger.release(jid, 0.0, &name);
        if !self.ledger.commit(jid, cpu_s * rate) {
            self.managers[rid.0 as usize].cancel(jid);
            let _ = self.exp.release(jid);
            self.mark_view(rid); // in-flight count changed
            if let Some(j) = &mut self.journal {
                let _ = j.released(jid);
            }
            self.inflight.remove(&jid);
            return;
        }
        if self.exp.start(jid, now).is_err() {
            return;
        }
        if let Some(j) = &mut self.journal {
            let _ = j.started(jid, now);
        }
        let inf = self.inflight.get_mut(&jid).expect("inflight record");
        inf.exec_started = Some(now);
        inf.rate = rate;
        inf.cpu_s = cpu_s;
        let exec_wall = inf.cpu_s;
        self.busy_cpus += 1;
        self.report.busy_cpus.record(now, self.busy_cpus);
        // Stage-out folded into the completion event.
        let t_out = self
            .tb
            .site(spec.site)
            .link
            .transfer_seconds(self.cfg.workload.output_bytes);
        self.q
            .schedule_in(exec_wall + t_out, Ev::Complete { rid, jid });
    }

    fn on_complete(&mut self, rid: ResourceId, jid: JobId) {
        let now = self.q.now();
        if !matches!(self.exp.job(jid).state, JobState::Running { rid: r, .. } if r == rid)
        {
            return; // failed/cancelled meanwhile
        }
        let inf = self.inflight.remove(&jid).expect("inflight record");
        self.managers[rid.0 as usize].complete(jid);
        self.busy_cpus -= 1;
        self.report.busy_cpus.record(now, self.busy_cpus);
        let cost = inf.cpu_s * inf.rate;
        let name = self.tb.spec(rid).name.clone();
        self.ledger.settle(jid, cost, &name);
        self.exp
            .complete(jid, now, inf.cpu_s, cost)
            .expect("legal complete");
        if let Some(j) = &mut self.journal {
            let _ = j.completed(jid, now, inf.cpu_s, cost);
        }
        self.advisor
            .observe_complete(rid, now - inf.dispatched_at, inf.work_ref_h);
        self.mark_view(rid); // in-flight count + measured service rate changed
        let usage = self.report.per_resource.entry(name).or_insert_with(
            ResourceUsage::default,
        );
        usage.jobs_completed += 1;
        usage.cpu_seconds += inf.cpu_s;
        usage.cost += cost;
        self.try_start(rid);
    }

    /// Shared failure path for one in-flight job on `rid`.
    fn fail_in_flight(&mut self, jid: JobId, rid: ResourceId) {
        let now = self.q.now();
        let name = self.tb.spec(rid).name.clone();
        if let Some(inf) = self.inflight.remove(&jid) {
            // Owners bill for cycles consumed before the crash.
            let partial = match inf.exec_started {
                Some(t0) => (now - t0).max(0.0) * inf.rate,
                None => 0.0,
            };
            if inf.exec_started.is_some() {
                self.busy_cpus = self.busy_cpus.saturating_sub(1);
                self.report.busy_cpus.record(now, self.busy_cpus);
            }
            self.ledger.release(jid, partial, &name);
            let usage = self
                .report
                .per_resource
                .entry(name)
                .or_insert_with(ResourceUsage::default);
            usage.jobs_failed += 1;
            usage.cost += partial;
        }
        self.advisor.observe_failure(rid);
        if self.exp.fail_attempt(jid).is_ok() {
            if let Some(j) = &mut self.journal {
                let _ = j.failed_attempt(jid);
            }
        }
        self.mark_view(rid); // in-flight count + failure history changed
    }

    fn on_fail(&mut self, rid: ResourceId) {
        let i = rid.0 as usize;
        if !self.dyns[i].up {
            return;
        }
        self.dyns[i].up = false;
        let victims = self.managers[i].fail_all();
        for (jid, _started) in victims {
            self.fail_in_flight(jid, rid);
        }
        let spec = self.tb.resources[i].clone();
        let downtime = self.dyns[i].draw_downtime(&spec);
        self.q.schedule_in(downtime, Ev::Recover { rid });
    }

    fn on_recover(&mut self, rid: ResourceId) {
        let i = rid.0 as usize;
        self.dyns[i].up = true;
        let spec = self.tb.resources[i].clone();
        let uptime = self.dyns[i].draw_uptime(&spec);
        self.q.schedule_in(uptime, Ev::Fail { rid });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::HOUR;

    fn small_cfg(policy: &str, deadline_h: f64) -> ExperimentConfig {
        ExperimentConfig {
            policy: policy.to_string(),
            deadline: deadline_h * HOUR,
            seed: 42,
            ..Default::default()
        }
    }

    fn small_sim(policy: &str, deadline_h: f64, jobs: usize) -> GridSimulation {
        let cfg = small_cfg(policy, deadline_h);
        let tb = Testbed::gusto(7, 0.5);
        let src = format!(
            "parameter voltage float range from 100 to 1000 step {}\nparameter pressure float random from 0.5 to 2 count 1\nparameter energy float select anyof 10\ntask main\nexecute icc -v $voltage -p $pressure -e $energy\nendtask",
            900.0 / (jobs.max(2) - 1) as f64
        );
        let plan = crate::plan::Plan::parse(&src).unwrap();
        let specs = crate::plan::expand(&plan, cfg.seed).unwrap();
        GridSimulation::new(tb, specs, cfg)
    }

    #[test]
    fn small_experiment_completes() {
        let report = small_sim("cost", 30.0, 10).run();
        assert_eq!(report.jobs_completed + report.jobs_failed, 10);
        assert!(report.jobs_completed >= 8, "{}", report.summary());
        assert!(report.total_cost > 0.0);
        assert!(report.busy_cpus.peak() >= 1);
        assert!(report.events > 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = small_sim("cost", 20.0, 12).run();
        let b = small_sim("cost", 20.0, 12).run();
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert!((a.total_cost - b.total_cost).abs() < 1e-9);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn all_policies_run_to_completion() {
        for policy in crate::scheduler::ALL_POLICIES {
            let report = small_sim(policy, 40.0, 8).run();
            assert!(
                report.jobs_completed >= 6,
                "{policy}: {}",
                report.summary()
            );
        }
    }

    #[test]
    fn paper_scale_cost_run() {
        let report =
            GridSimulation::gusto_ionization(small_cfg("cost", 20.0)).run();
        assert_eq!(report.jobs_total, 165);
        assert!(
            report.jobs_completed >= 160,
            "expected nearly all jobs done: {}",
            report.summary()
        );
        assert!(report.makespan_s <= 20.0 * HOUR * 1.05, "{}", report.summary());
        assert!(report.resources_used >= 5);
    }

    #[test]
    fn tighter_deadline_uses_more_processors() {
        let loose =
            GridSimulation::gusto_ionization(small_cfg("cost", 20.0)).run();
        let tight =
            GridSimulation::gusto_ionization(small_cfg("cost", 10.0)).run();
        let avg_loose = loose.busy_cpus.average(loose.makespan_s.max(1.0));
        let avg_tight = tight.busy_cpus.average(tight.makespan_s.max(1.0));
        assert!(
            avg_tight > avg_loose,
            "tight {avg_tight:.1} cpus vs loose {avg_loose:.1}"
        );
    }

    #[test]
    fn incremental_views_match_full_rebuild_bit_exactly() {
        // The dirty-tracking view table is a pure optimization: forcing a
        // full rebuild every tick must replay the exact same trace, while
        // touching far more entries.
        let a = small_sim("cost", 20.0, 12).run();
        let mut forced = small_sim("cost", 20.0, 12);
        forced.set_full_view_rebuild(true);
        let b = forced.run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
        assert_eq!(a.busy_cpus.points(), b.busy_cpus.points());
        assert!(
            a.view_refreshes < b.view_refreshes,
            "incremental maintenance should touch fewer entries: {} vs {}",
            a.view_refreshes,
            b.view_refreshes
        );
    }

    #[test]
    fn incremental_views_match_full_rebuild_under_competition() {
        // Same bit-exactness with premiums/claims churning the table.
        let mk = || {
            let mut cfg = small_cfg("cost", 25.0);
            cfg.competition =
                Some(crate::grid::competition::CompetitionModel {
                    mean_interarrival_s: 1200.0,
                    mean_duration_s: 2.0 * HOUR,
                    mean_cpus: 20.0,
                });
            let tb = Testbed::gusto(7, 0.5);
            let specs = crate::workload::ionization_jobs(cfg.seed);
            GridSimulation::new(tb, specs, cfg)
        };
        let a = mk().run();
        let mut forced = mk();
        forced.set_full_view_rebuild(true);
        let b = forced.run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    }

    #[test]
    fn incremental_views_match_full_rebuild_with_fractional_start_hour() {
        // Peak-price boundaries fall off the whole-hour sim-time grid when
        // the start hour is fractional; phase-aware repricing must still
        // invalidate quotes exactly when a site's local clock crosses an
        // hour (regression: a fixed hourly reprice grid missed these).
        let mk = || {
            let mut cfg = small_cfg("cost", 20.0);
            cfg.start_utc_hour = 21.5;
            let tb = Testbed::gusto(7, 0.5);
            let specs = crate::workload::ionization_jobs(cfg.seed);
            GridSimulation::new(tb, specs, cfg)
        };
        let a = mk().run();
        let mut forced = mk();
        forced.set_full_view_rebuild(true);
        let b = forced.run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    }

    #[test]
    fn budget_is_never_exceeded() {
        let mut cfg = small_cfg("cost", 15.0);
        cfg.budget = Some(2000.0);
        let tb = Testbed::gusto(7, 0.5);
        let specs = crate::workload::ionization_jobs(cfg.seed);
        let sim = GridSimulation::new(tb, specs, cfg);
        let report = sim.run();
        assert!(
            report.total_cost <= 2000.0 + 1e-6,
            "spent {} over budget",
            report.total_cost
        );
    }
}
