//! The discrete-event experiment drivers: Nimrod/G running over the
//! simulated GUSTO testbed in virtual time.
//!
//! The simulation core lives in [`world`]: a shared [`GridWorld`] (testbed
//! + MDS + event queue + pricing + residual background competition) hosts
//! **N concurrent tenants**, each a full experiment with its own engine,
//! ledger, rate estimator, policy, deadline and journal. Contention is
//! real — tenant A's in-flight jobs shrink the `free_slots` tenant B sees,
//! and demand-priced owners reprice with total machine utilization.
//!
//! [`GridSimulation`] is the single-tenant surface the rest of the crate
//! (and the paper's Figure-3 experiments) use: a thin wrapper over a
//! one-tenant world, bit-identical to the pre-world driver at equal seeds
//! for competition-free configurations. (With background competition
//! enabled, traces intentionally differ from the pre-world driver:
//! competitor arrivals now respect the experiment's own occupancy instead
//! of oversubscribing machines — see
//! [`crate::grid::competition::Competition::arrive`].)
//! Construct through [`crate::broker::ExperimentBuilder`]
//! (`Broker::experiment()…simulate()`); multi-tenant worlds come from
//! `Broker::experiment()…tenant(..)…world()`.
//!
//! Per-job event chain:
//!
//! ```text
//! Submit ─stage-in──▶ StagedIn ─queue──▶ BeginExec ─exec+stage-out──▶ Complete
//!    (GASS/proxy)       (GRAM)              (engine Running)           (settle)
//! ```
//!
//! **Incremental view table.** The scheduler tick does not rebuild every
//! [`crate::scheduler::ResourceView`] from an MDS sweep: each tenant keeps
//! one persistent view per resource and the events that actually change
//! scheduler-visible state dirty exactly the entries they touch — an MDS
//! refresh dirties only records whose up/load changed (outages and
//! recoveries become visible there, preserving the paper's stale-directory
//! semantics), any tenant's job dispatch/start/completion/failure touches
//! the one resource it ran on (in every tenant's table: occupancy and
//! demand premiums are shared state), competitor arrivals/departures touch
//! the claimed machines, and owners with time-of-day pricing are re-marked
//! only when their local clock crosses an hour boundary. Each tick then
//! refreshes the dirty entries (O(changed), not O(resources)) before
//! handing the table to the shared advisor, which is what lets a quiet
//! 10k-machine grid tick in near-constant time (see
//! `benches/grid_scaling.rs`).
//!
//! A 20-hour trial replays in a few milliseconds; identical seeds produce
//! identical traces (see `rust/tests/`).

pub mod live;
pub mod pool;
pub mod world;

pub use world::{GridWorld, TenantSetup};

use crate::broker::ScheduleAdvisor;
use crate::config::ExperimentConfig;
use crate::economy::Ledger;
use crate::engine::journal::Journal;
use crate::engine::Experiment;
use crate::grid::testbed::Testbed;
use crate::metrics::Report;
use crate::plan::JobSpec;
use crate::types::SimTime;

/// The single-tenant simulation: the N = 1 case of [`GridWorld`].
/// Construct with [`GridSimulation::new`], call [`GridSimulation::run`] for
/// the final [`Report`].
pub struct GridSimulation {
    world: GridWorld,
}

impl GridSimulation {
    /// Build a simulation over `tb` running `specs` under `cfg`, resolving
    /// `cfg.policy` (a `name` or `name?key=value` spec) against the
    /// built-in policy registry. Panics on an unresolvable policy; use
    /// [`crate::broker::ExperimentBuilder`] for fallible construction.
    pub fn new(tb: Testbed, specs: Vec<JobSpec>, cfg: ExperimentConfig) -> Self {
        let advisor =
            ScheduleAdvisor::resolve(&cfg.policy, cfg.workload.job_work_ref_h)
                .unwrap_or_else(|e| panic!("{e:#}"));
        GridSimulation::with_advisor(tb, specs, cfg, advisor)
    }

    /// Build a simulation with an explicitly-constructed schedule advisor
    /// (the [`crate::broker::ExperimentBuilder`] path, which supports
    /// custom policy registries).
    pub fn with_advisor(
        tb: Testbed,
        specs: Vec<JobSpec>,
        cfg: ExperimentConfig,
        advisor: ScheduleAdvisor,
    ) -> Self {
        GridSimulation {
            world: GridWorld::new(tb, vec![TenantSetup { cfg, specs, advisor }]),
        }
    }

    /// Convenience: paper-scale Figure-3 experiment over the GUSTO testbed.
    pub fn gusto_ionization(cfg: ExperimentConfig) -> Self {
        let tb = Testbed::gusto(cfg.seed ^ 0x6057, 1.0);
        let specs = crate::workload::ionization_jobs(cfg.seed);
        GridSimulation::new(tb, specs, cfg)
    }

    /// Attach a persistence journal (restart support).
    pub fn with_journal(mut self, journal: Journal) -> Self {
        self.world.attach_journal(0, journal);
        self
    }

    /// Replace the experiment (restart-from-journal path).
    pub fn with_experiment(mut self, exp: Experiment) -> Self {
        self.world.replace_experiment(0, exp);
        self
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.world.now()
    }

    /// The experiment engine (job table + envelope).
    pub fn exp(&self) -> &Experiment {
        self.world.exp(0)
    }

    /// The spend ledger.
    pub fn ledger(&self) -> &Ledger {
        self.world.ledger(0)
    }

    /// The testbed this simulation runs over.
    pub fn tb(&self) -> &Testbed {
        &self.world.tb
    }

    /// The underlying one-tenant world (shared-grid introspection).
    pub fn world(&self) -> &GridWorld {
        &self.world
    }

    /// Run to completion (or hard stop); consume the sim, return the report.
    pub fn run(self) -> Report {
        self.world.run_world().into_single()
    }

    /// Run until `t` (for incremental inspection in tests/examples).
    pub fn run_until(&mut self, t: SimTime) {
        self.world.run_until(t);
    }

    /// Finalize the report after the event loop.
    pub fn finalize(self) -> Report {
        self.world.finalize_world().into_single()
    }

    /// Benchmark support: rebuild the whole view table on every tick (the
    /// pre-incremental behaviour) instead of only dirty entries. The
    /// resulting trace is bit-identical — entries just get recomputed to
    /// the same values many more times. (Quotes are piecewise-constant per
    /// local hour and repricing marks them exactly when a boundary passes,
    /// so the equivalence holds for any start hour, timezone offset or
    /// tick period.)
    pub fn set_full_view_rebuild(&mut self, on: bool) {
        self.world.set_full_view_rebuild(on);
    }

    /// Benchmark support: re-rank the whole candidate index from the view
    /// table on every tick (the sort-every-tick allocation baseline)
    /// instead of re-keying only dirtied entries. Bit-identical traces,
    /// O(R log R) per tick — see
    /// [`GridWorld::set_full_allocation_sort`].
    pub fn set_full_allocation_sort(&mut self, on: bool) {
        self.world.set_full_allocation_sort(on);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::HOUR;

    fn small_cfg(policy: &str, deadline_h: f64) -> ExperimentConfig {
        ExperimentConfig {
            policy: policy.to_string(),
            deadline: deadline_h * HOUR,
            seed: 42,
            ..Default::default()
        }
    }

    fn small_sim(policy: &str, deadline_h: f64, jobs: usize) -> GridSimulation {
        let cfg = small_cfg(policy, deadline_h);
        let tb = Testbed::gusto(7, 0.5);
        let src = format!(
            "parameter voltage float range from 100 to 1000 step {}\nparameter pressure float random from 0.5 to 2 count 1\nparameter energy float select anyof 10\ntask main\nexecute icc -v $voltage -p $pressure -e $energy\nendtask",
            900.0 / (jobs.max(2) - 1) as f64
        );
        let plan = crate::plan::Plan::parse(&src).unwrap();
        let specs = crate::plan::expand(&plan, cfg.seed).unwrap();
        GridSimulation::new(tb, specs, cfg)
    }

    #[test]
    fn small_experiment_completes() {
        let report = small_sim("cost", 30.0, 10).run();
        assert_eq!(report.jobs_completed + report.jobs_failed, 10);
        assert!(report.jobs_completed >= 8, "{}", report.summary());
        assert!(report.total_cost > 0.0);
        assert!(report.busy_cpus.peak() >= 1);
        assert!(report.events > 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = small_sim("cost", 20.0, 12).run();
        let b = small_sim("cost", 20.0, 12).run();
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert!((a.total_cost - b.total_cost).abs() < 1e-9);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn all_policies_run_to_completion() {
        for policy in crate::scheduler::ALL_POLICIES {
            let report = small_sim(policy, 40.0, 8).run();
            assert!(
                report.jobs_completed >= 6,
                "{policy}: {}",
                report.summary()
            );
        }
    }

    #[test]
    fn paper_scale_cost_run() {
        let report =
            GridSimulation::gusto_ionization(small_cfg("cost", 20.0)).run();
        assert_eq!(report.jobs_total, 165);
        assert!(
            report.jobs_completed >= 160,
            "expected nearly all jobs done: {}",
            report.summary()
        );
        assert!(report.makespan_s <= 20.0 * HOUR * 1.05, "{}", report.summary());
        assert!(report.resources_used >= 5);
    }

    #[test]
    fn tighter_deadline_uses_more_processors() {
        let loose =
            GridSimulation::gusto_ionization(small_cfg("cost", 20.0)).run();
        let tight =
            GridSimulation::gusto_ionization(small_cfg("cost", 10.0)).run();
        let avg_loose = loose.busy_cpus.average(loose.makespan_s.max(1.0));
        let avg_tight = tight.busy_cpus.average(tight.makespan_s.max(1.0));
        assert!(
            avg_tight > avg_loose,
            "tight {avg_tight:.1} cpus vs loose {avg_loose:.1}"
        );
    }

    #[test]
    fn incremental_views_match_full_rebuild_bit_exactly() {
        // The dirty-tracking view table is a pure optimization: forcing a
        // full rebuild every tick must replay the exact same trace, while
        // touching far more entries.
        let a = small_sim("cost", 20.0, 12).run();
        let mut forced = small_sim("cost", 20.0, 12);
        forced.set_full_view_rebuild(true);
        let b = forced.run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
        assert_eq!(a.busy_cpus.points(), b.busy_cpus.points());
        assert!(
            a.view_refreshes < b.view_refreshes,
            "incremental maintenance should touch fewer entries: {} vs {}",
            a.view_refreshes,
            b.view_refreshes
        );
    }

    #[test]
    fn incremental_views_match_full_rebuild_under_competition() {
        // Same bit-exactness with premiums/claims churning the table.
        let mk = || {
            let mut cfg = small_cfg("cost", 25.0);
            cfg.competition =
                Some(crate::grid::competition::CompetitionModel {
                    mean_interarrival_s: 1200.0,
                    mean_duration_s: 2.0 * HOUR,
                    mean_cpus: 20.0,
                });
            let tb = Testbed::gusto(7, 0.5);
            let specs = crate::workload::ionization_jobs(cfg.seed);
            GridSimulation::new(tb, specs, cfg)
        };
        let a = mk().run();
        let mut forced = mk();
        forced.set_full_view_rebuild(true);
        let b = forced.run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    }

    #[test]
    fn incremental_views_match_full_rebuild_with_fractional_start_hour() {
        // Peak-price boundaries fall off the whole-hour sim-time grid when
        // the start hour is fractional; phase-aware repricing must still
        // invalidate quotes exactly when a site's local clock crosses an
        // hour (regression: a fixed hourly reprice grid missed these).
        let mk = || {
            let mut cfg = small_cfg("cost", 20.0);
            cfg.start_utc_hour = 21.5;
            let tb = Testbed::gusto(7, 0.5);
            let specs = crate::workload::ionization_jobs(cfg.seed);
            GridSimulation::new(tb, specs, cfg)
        };
        let a = mk().run();
        let mut forced = mk();
        forced.set_full_view_rebuild(true);
        let b = forced.run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    }

    #[test]
    fn budget_is_never_exceeded() {
        let mut cfg = small_cfg("cost", 15.0);
        cfg.budget = Some(2000.0);
        let tb = Testbed::gusto(7, 0.5);
        let specs = crate::workload::ionization_jobs(cfg.seed);
        let sim = GridSimulation::new(tb, specs, cfg);
        let report = sim.run();
        assert!(
            report.total_cost <= 2000.0 + 1e-6,
            "spent {} over budget",
            report.total_cost
        );
    }

    #[test]
    fn single_tenant_wrapper_is_the_n1_world() {
        // The legacy GridSimulation surface and a directly-built one-tenant
        // GridWorld must replay the identical trace: the wrapper is the
        // N = 1 case of the world, not a parallel implementation.
        let mk_setup = || {
            let cfg = small_cfg("cost", 20.0);
            let advisor = ScheduleAdvisor::resolve(
                &cfg.policy,
                cfg.workload.job_work_ref_h,
            )
            .unwrap();
            let tb = Testbed::gusto(cfg.seed ^ 0x6057, 1.0);
            let specs = crate::workload::ionization_jobs(cfg.seed);
            (tb, specs, cfg, advisor)
        };
        let (tb, specs, cfg, advisor) = mk_setup();
        let via_wrapper =
            GridSimulation::gusto_ionization(small_cfg("cost", 20.0)).run();
        let via_world = GridWorld::new(
            tb,
            vec![TenantSetup { cfg, specs, advisor }],
        )
        .run_world();
        assert_eq!(via_world.tenants.len(), 1);
        let w = &via_world.tenants[0].report;
        assert_eq!(via_wrapper.events, w.events);
        assert_eq!(via_wrapper.ticks, w.ticks);
        assert_eq!(via_wrapper.makespan_s.to_bits(), w.makespan_s.to_bits());
        assert_eq!(via_wrapper.total_cost.to_bits(), w.total_cost.to_bits());
        assert_eq!(via_wrapper.busy_cpus.points(), w.busy_cpus.points());
    }
}
