//! Criterion-style measurement harness for `benches/` (criterion itself is
//! not in the offline crate cache).
//!
//! Usage from a `harness = false` bench binary:
//!
//! ```ignore
//! let mut b = Bench::new("figure3");
//! b.iter("deadline=10h", || run_experiment(10.0));
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to pass a
//! minimum measurement window; mean / p50 / p95 wall times are printed in a
//! fixed-width table that the EXPERIMENTS.md tables are copied from.

use std::time::{Duration, Instant};

/// One measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

/// A named group of measurements.
pub struct Bench {
    group: String,
    warmup: Duration,
    window: Duration,
    max_iters: u32,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Bench {
            group: group.to_string(),
            warmup: Duration::from_millis(200),
            window: Duration::from_secs(1),
            max_iters: 200,
            results: Vec::new(),
        }
    }

    /// Shrink the measurement window (for slow end-to-end cases).
    pub fn fast(mut self) -> Self {
        self.warmup = Duration::from_millis(0);
        self.window = Duration::from_millis(200);
        self.max_iters = 20;
        self
    }

    /// Measure `f`, discarding its result.
    pub fn iter<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.window && (samples.len() as u32) < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let p50 = samples[samples.len() / 2];
        let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
        self.results.push(Measurement {
            name: name.to_string(),
            iters: samples.len() as u32,
            mean,
            p50,
            p95,
        });
        // lint:allow(PANIC-BUDGET): the measurement was pushed two lines up, so last() is always Some
        self.results.last().unwrap()
    }

    /// Print the fixed-width results table.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<44} {:>7} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "p95"
        );
        for m in &self.results {
            println!(
                "{:<44} {:>7} {:>12} {:>12} {:>12}",
                m.name,
                m.iters,
                fmt_dur(m.mean),
                fmt_dur(m.p50),
                fmt_dur(m.p95)
            );
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Human format with µs/ms/s autoscale.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").fast();
        let m = b.iter("noop", || 1 + 1).clone();
        assert!(m.iters >= 1);
        assert!(m.p95 >= m.p50 || m.iters < 3);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.00s");
    }
}
