//! Tiny leveled stderr logger, controlled by the `NIMROD_LOG` env var
//! (`error`, `warn`, `info`, `debug`, `trace`; default `warn`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static INIT: Once = Once::new();

/// Initialize the logger from `NIMROD_LOG`. Idempotent.
pub fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("NIMROD_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("info") => Level::Info,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Warn,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

/// Explicitly set the level (tests).
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
