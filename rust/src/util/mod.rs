//! In-tree utility layer.
//!
//! This image builds offline from a baked cargo cache that carries only the
//! `xla` crate closure, so the usual ecosystem crates (serde, rand, clap,
//! criterion, proptest) are implemented here at the scale this system needs:
//!
//! * [`rng`] — deterministic xoshiro256++ PRNG with the distributions the
//!   grid simulator draws from;
//! * [`json`] — a small JSON value model, parser and writer used by the
//!   persistence journal, the wire protocol and the artifact manifest;
//! * [`bench`] — a criterion-style measurement harness for `benches/`;
//! * [`logging`] — a leveled stderr logger controlled by `NIMROD_LOG`;
//! * [`prop`] — a seeded property-testing loop used by the invariant tests.

pub mod bench;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
