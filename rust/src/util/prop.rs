//! Seeded property-testing loop (proptest is not in the offline crate
//! cache). No shrinking — failures report the exact case seed so the case is
//! reproducible with `prop_check_seeded`.
//!
//! ```ignore
//! prop_check(256, |rng| {
//!     let n = rng.below(100) + 1;
//!     let xs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     // ... assert invariant, return Result<(), String>
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Base seed for the suite; change to re-roll every property in the repo.
pub const SUITE_SEED: u64 = 0x5EED_0F_9172;

/// Run `cases` random cases; panics with the failing case seed on error.
pub fn prop_check<F>(cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = SUITE_SEED.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single case by seed (for debugging a reported failure).
pub fn prop_check_seeded<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper producing `Result` instead of panicking, so properties can
/// carry context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(32, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(8, |rng| {
            if rng.f64() >= 0.0 {
                Err("always fails".to_string())
            } else {
                Ok(())
            }
        });
    }
}
