//! Minimal JSON: value model, recursive-descent parser, compact writer.
//!
//! Used by the persistence journal (one JSON object per line), the Clustor
//! wire protocol (length-prefixed JSON frames), testbed/experiment config
//! files, and the AOT artifact manifest. Supports the full JSON grammar
//! except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;

/// A JSON value. Objects use a BTreeMap so serialized output is stable —
/// important for journal diffing and protocol tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }

    // -- accessors -----------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required typed field accessors (error messages name the key).
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| JsonError::Field(key.to_string()))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .as_str()
            .ok_or_else(|| JsonError::Field(key.to_string()))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| JsonError::Field(key.to_string()))
    }

    /// Serialize to a compact single-line string.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse / access error.
#[derive(Debug)]
pub enum JsonError {
    Parse(usize, &'static str),
    Field(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse(at, what) => {
                write!(f, "json parse error at byte {at}: {what}")
            }
            JsonError::Field(name) => {
                write!(f, "missing or mistyped field `{name}`")
            }
        }
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(JsonError::Parse(p.i, "trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8, what: &'static str) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Parse(self.i, what))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Parse(self.i, "bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::Parse(self.i, "expected value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[', "expected [")?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.ws();
            xs.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(JsonError::Parse(self.i, "expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{', "expected {")?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':', "expected :")?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(JsonError::Parse(self.i, "expected , or }")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"', "expected string")?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::Parse(self.i, "unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(JsonError::Parse(self.i, "bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| {
                                        JsonError::Parse(self.i, "bad \\u")
                                    })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(JsonError::Parse(self.i, "bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| JsonError::Parse(self.i, "bad utf8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        // lint:allow(PANIC-BUDGET): the scanned range holds only ASCII digit/sign bytes, always valid UTF-8
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Parse(start, "bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":{"e":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n":4,"s":"t","a":[1],"b":true}"#).unwrap();
        assert_eq!(v.req_f64("n").unwrap(), 4.0);
        assert_eq!(v.req_str("s").unwrap(), "t");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert!(v.req_f64("missing").is_err());
        assert!(matches!(v.get("missing"), Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("quote\" backslash\\ newline\n tab\t");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn object_order_stable() {
        let a = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }
}
