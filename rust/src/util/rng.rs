//! Deterministic PRNG + distributions for the grid simulator.
//!
//! xoshiro256++ seeded through SplitMix64 — fast, well-tested generator with
//! exactly reproducible streams, which the discrete-event simulator depends
//! on (every experiment is replayable from its seed).

/// xoshiro256++ pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Derive an independent child stream (for per-resource processes).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift (Lemire); bias < 2^-64 — fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial with success probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (single-value form).
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + sd * z
    }

    /// Exponential with the given mean (inverse-CDF).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64();
        -mean * u.ln()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mean = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(21);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
