//! Core identifier and unit types shared across the system.

use std::fmt;

/// Identifier of a job within an experiment (dense, 0-based).
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct JobId(pub u32);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// Identifier of a grid resource (a machine visible through MDS).
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct ResourceId(pub u32);

impl fmt::Display for ResourceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Identifier of an administrative site (one owner / one GASS server).
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct SiteId(pub u32);

impl fmt::Display for SiteId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Grid currency used by the computational economy, after the G$ of the
/// Nimrod/G papers. 1 G$ buys one CPU-second on the reference machine at the
/// base (off-peak) rate.
pub type GridDollars = f64;

/// Seconds of virtual experiment time (t = 0 at experiment start).
pub type SimTime = f64;

/// Hours → seconds.
pub const HOUR: SimTime = 3600.0;
/// Minutes → seconds.
pub const MINUTE: SimTime = 60.0;

/// Machine architecture, as reported through the directory service.
/// Ord (declaration order) so architecture sets can live in BTree
/// containers — tick-adjacent state must iterate deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Arch {
    Intel,
    Sparc,
    Alpha,
    Mips,
    PowerPc,
}

impl fmt::Display for Arch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Arch::Intel => "intel",
            Arch::Sparc => "sparc",
            Arch::Alpha => "alpha",
            Arch::Mips => "mips",
            Arch::PowerPc => "powerpc",
        };
        f.write_str(s)
    }
}

/// Operating system, for plan task constraints. Ord for the same
/// deterministic-iteration reason as [`Arch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Os {
    Linux,
    Solaris,
    Irix,
    Tru64,
    Aix,
}

impl fmt::Display for Os {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Os::Linux => "linux",
            Os::Solaris => "solaris",
            Os::Irix => "irix",
            Os::Tru64 => "tru64",
            Os::Aix => "aix",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        assert_eq!(JobId(3).to_string(), "j3");
        assert_eq!(ResourceId(7).to_string(), "r7");
        assert_eq!(SiteId(1).to_string(), "s1");
        assert_eq!(Arch::Intel.to_string(), "intel");
        assert_eq!(Os::Linux.to_string(), "linux");
    }

    #[test]
    fn ids_order_and_hash() {
        use std::collections::BTreeSet;
        let mut set = BTreeSet::new();
        set.insert(JobId(1));
        set.insert(JobId(1));
        set.insert(JobId(2));
        assert_eq!(set.len(), 2);
        assert!(JobId(1) < JobId(2));
    }

    #[test]
    fn arch_and_os_are_ordered() {
        use std::collections::BTreeSet;
        let archs: BTreeSet<Arch> =
            [Arch::Sparc, Arch::Intel, Arch::Sparc].into_iter().collect();
        assert_eq!(archs.len(), 2);
        let in_order: Vec<Arch> = archs.into_iter().collect();
        assert_eq!(in_order, vec![Arch::Intel, Arch::Sparc]);
        assert!(Os::Linux < Os::Solaris);
    }
}
