//! The parametric engine (paper §2): "a persistent job control agent and
//! the central component from where the whole experiment is managed".
//!
//! Owns the job table and its state machine, enforces legal transitions,
//! tracks attempts, and journals every transition to persistent storage so
//! the experiment "can be restarted if the node running Nimrod goes down"
//! ([`journal`]).

pub mod journal;

use crate::plan::JobSpec;
use crate::types::{GridDollars, JobId, ResourceId, SimTime};

/// Job lifecycle. Legal transitions:
///
/// ```text
/// Ready ─→ Dispatched ─→ Running ─→ Done
///   ↑          │            │
///   └──────────┴────────────┘  (failure / cancel, attempts < max)
///                └─→ Failed     (attempts exhausted)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    /// Awaiting dispatch (initial, or re-queued after failure).
    Ready,
    /// Submitted to a resource's job manager (staging/queued).
    Dispatched { rid: ResourceId, at: SimTime },
    /// Executing.
    Running { rid: ResourceId, started: SimTime },
    /// Finished; terminal.
    Done {
        rid: ResourceId,
        finished: SimTime,
        cpu_s: f64,
        cost: GridDollars,
    },
    /// Attempts exhausted; terminal.
    Failed,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done { .. } | JobState::Failed)
    }

    /// The resource currently responsible for the job, if any.
    pub fn resource(&self) -> Option<ResourceId> {
        match self {
            JobState::Dispatched { rid, .. } | JobState::Running { rid, .. } => {
                Some(*rid)
            }
            _ => None,
        }
    }
}

/// One job: its spec plus runtime state.
#[derive(Debug, Clone)]
pub struct Job {
    pub spec: JobSpec,
    pub state: JobState,
    pub attempts: u32,
}

/// Transition error — indicates a driver bug, surfaced loudly.
#[derive(Debug)]
pub struct BadTransition {
    pub job: JobId,
    pub from: JobState,
    pub to: &'static str,
}

impl std::fmt::Display for BadTransition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "illegal transition for {}: {:?} -> {}",
            self.job, self.from, self.to
        )
    }
}

impl std::error::Error for BadTransition {}

/// The experiment: job table + deadline/budget envelope.
#[derive(Debug)]
pub struct Experiment {
    pub jobs: Vec<Job>,
    pub deadline: SimTime,
    pub budget: Option<GridDollars>,
    pub user: String,
    pub max_attempts: u32,
}

impl Experiment {
    pub fn new(
        specs: Vec<JobSpec>,
        deadline: SimTime,
        budget: Option<GridDollars>,
        user: &str,
        max_attempts: u32,
    ) -> Experiment {
        Experiment {
            jobs: specs
                .into_iter()
                .map(|spec| Job {
                    spec,
                    state: JobState::Ready,
                    attempts: 0,
                })
                .collect(),
            deadline,
            budget,
            user: user.to_string(),
            max_attempts,
        }
    }

    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    fn job_mut(&mut self, id: JobId) -> &mut Job {
        &mut self.jobs[id.0 as usize]
    }

    // -- queries -------------------------------------------------------------

    /// Jobs not yet in a terminal state (the scheduler's `remaining_jobs`).
    pub fn remaining(&self) -> u32 {
        self.jobs.iter().filter(|j| !j.state.is_terminal()).count() as u32
    }

    pub fn completed(&self) -> u32 {
        self.jobs
            .iter()
            .filter(|j| matches!(j.state, JobState::Done { .. }))
            .count() as u32
    }

    pub fn failed(&self) -> u32 {
        self.jobs
            .iter()
            .filter(|j| matches!(j.state, JobState::Failed))
            .count() as u32
    }

    /// All terminal ⇒ the experiment is over.
    pub fn finished(&self) -> bool {
        self.jobs.iter().all(|j| j.state.is_terminal())
    }

    /// Iterator over Ready jobs in id order (dispatch order).
    pub fn ready_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.jobs
            .iter()
            .filter(|j| j.state == JobState::Ready)
            .map(|j| j.spec.id)
    }

    /// Total settled cost across Done jobs.
    pub fn total_cost(&self) -> GridDollars {
        self.jobs
            .iter()
            .filter_map(|j| match j.state {
                JobState::Done { cost, .. } => Some(cost),
                _ => None,
            })
            .sum()
    }

    /// Virtual time the last job finished.
    pub fn makespan(&self) -> SimTime {
        self.jobs
            .iter()
            .filter_map(|j| match j.state {
                JobState::Done { finished, .. } => Some(finished),
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    // -- transitions ---------------------------------------------------------

    pub fn dispatch(
        &mut self,
        id: JobId,
        rid: ResourceId,
        now: SimTime,
    ) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        if job.state != JobState::Ready {
            return Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Dispatched",
            });
        }
        job.attempts += 1;
        job.state = JobState::Dispatched { rid, at: now };
        Ok(())
    }

    pub fn start(&mut self, id: JobId, now: SimTime) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        match job.state {
            JobState::Dispatched { rid, .. } => {
                job.state = JobState::Running { rid, started: now };
                Ok(())
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Running",
            }),
        }
    }

    pub fn complete(
        &mut self,
        id: JobId,
        now: SimTime,
        cpu_s: f64,
        cost: GridDollars,
    ) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        match job.state {
            JobState::Running { rid, .. } => {
                job.state = JobState::Done {
                    rid,
                    finished: now,
                    cpu_s,
                    cost,
                };
                Ok(())
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Done",
            }),
        }
    }

    /// Failure or cancellation of an in-flight job: re-queues while attempts
    /// remain, otherwise terminal-fails. Returns the resulting state.
    pub fn fail_attempt(&mut self, id: JobId) -> Result<&JobState, BadTransition> {
        let max = self.max_attempts;
        let job = self.job_mut(id);
        match job.state {
            JobState::Dispatched { .. } | JobState::Running { .. } => {
                job.state = if job.attempts >= max {
                    JobState::Failed
                } else {
                    JobState::Ready
                };
                Ok(&job.state)
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Ready/Failed",
            }),
        }
    }

    /// Scheduler-initiated withdrawal of a queued (not yet Running) job:
    /// back to Ready with the dispatch attempt refunded — migration must
    /// never burn attempts (only failures do).
    pub fn release(&mut self, id: JobId) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        match job.state {
            JobState::Dispatched { .. } => {
                job.attempts = job.attempts.saturating_sub(1);
                job.state = JobState::Ready;
                Ok(())
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Ready (release)",
            }),
        }
    }

    /// In-flight job count per resource (drives dispatcher top-ups).
    pub fn in_flight_on(&self, rid: ResourceId) -> u32 {
        self.jobs
            .iter()
            .filter(|j| j.state.resource() == Some(rid))
            .count() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{expand, Plan};

    fn specs(n: usize) -> Vec<JobSpec> {
        let src = format!(
            "parameter i integer range from 1 to {n}\ntask main\nexecute run $i\nendtask"
        );
        expand(&Plan::parse(&src).unwrap(), 0).unwrap()
    }

    fn exp(n: usize) -> Experiment {
        Experiment::new(specs(n), 3600.0, None, "rajkumar", 3)
    }

    #[test]
    fn happy_path_lifecycle() {
        let mut e = exp(2);
        assert_eq!(e.remaining(), 2);
        e.dispatch(JobId(0), ResourceId(4), 10.0).unwrap();
        e.start(JobId(0), 20.0).unwrap();
        e.complete(JobId(0), 50.0, 30.0, 1.5).unwrap();
        assert_eq!(e.completed(), 1);
        assert_eq!(e.remaining(), 1);
        assert!(!e.finished());
        assert_eq!(e.total_cost(), 1.5);
        assert_eq!(e.makespan(), 50.0);
        assert_eq!(e.in_flight_on(ResourceId(4)), 0);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut e = exp(1);
        // Can't start or complete a Ready job.
        assert!(e.start(JobId(0), 0.0).is_err());
        assert!(e.complete(JobId(0), 0.0, 0.0, 0.0).is_err());
        e.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
        // Can't dispatch twice.
        assert!(e.dispatch(JobId(0), ResourceId(1), 0.0).is_err());
        e.start(JobId(0), 0.0).unwrap();
        e.complete(JobId(0), 1.0, 1.0, 0.1).unwrap();
        // Terminal is terminal.
        assert!(e.fail_attempt(JobId(0)).is_err());
        assert!(e.dispatch(JobId(0), ResourceId(0), 2.0).is_err());
    }

    #[test]
    fn failure_requeues_until_attempts_exhausted() {
        let mut e = exp(1);
        for attempt in 1..=3 {
            e.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
            assert_eq!(e.job(JobId(0)).attempts, attempt);
            let state = e.fail_attempt(JobId(0)).unwrap().clone();
            if attempt < 3 {
                assert_eq!(state, JobState::Ready);
            } else {
                assert_eq!(state, JobState::Failed);
            }
        }
        assert_eq!(e.failed(), 1);
        assert!(e.finished());
    }

    #[test]
    fn running_failure_also_requeues() {
        let mut e = exp(1);
        e.dispatch(JobId(0), ResourceId(2), 0.0).unwrap();
        e.start(JobId(0), 1.0).unwrap();
        assert_eq!(e.in_flight_on(ResourceId(2)), 1);
        assert_eq!(*e.fail_attempt(JobId(0)).unwrap(), JobState::Ready);
        assert_eq!(e.in_flight_on(ResourceId(2)), 0);
    }

    #[test]
    fn ready_iteration_in_id_order() {
        let mut e = exp(3);
        e.dispatch(JobId(1), ResourceId(0), 0.0).unwrap();
        let ready: Vec<JobId> = e.ready_jobs().collect();
        assert_eq!(ready, vec![JobId(0), JobId(2)]);
    }
}
