//! The parametric engine (paper §2): "a persistent job control agent and
//! the central component from where the whole experiment is managed".
//!
//! Owns the job table and its state machine, enforces legal transitions,
//! tracks attempts, and journals every transition to persistent storage so
//! the experiment "can be restarted if the node running Nimrod goes down"
//! ([`journal`]).
//!
//! Every transition also maintains incremental rollups — terminal-state
//! counters, the Ready set, and per-resource in-flight/queued tables — so
//! the per-tick queries the scheduler pipeline hammers
//! ([`Experiment::remaining`], [`Experiment::finished`],
//! [`Experiment::in_flight_on`], [`Experiment::ready_jobs`]) are O(1) or
//! O(answer) instead of O(jobs). This is what keeps scheduler ticks
//! O(changed) on 10k-resource / 50k-job grids. The rollups are only
//! consistent while job state is mutated through the transition methods;
//! code that pokes `jobs[i].state` directly (don't) must re-establish them.

pub mod journal;

use crate::plan::JobSpec;
use crate::types::{GridDollars, JobId, ResourceId, SimTime};
use std::collections::{BTreeMap, BTreeSet};

/// Job lifecycle. Legal transitions:
///
/// ```text
/// Ready ─→ Dispatched ─→ Running ─→ Done
///   ↑          │            │
///   └──────────┴────────────┘  (failure / cancel, attempts < max)
///                └─→ Failed     (attempts exhausted)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    /// Awaiting dispatch (initial, or re-queued after failure).
    Ready,
    /// Submitted to a resource's job manager (staging/queued).
    Dispatched { rid: ResourceId, at: SimTime },
    /// Executing.
    Running { rid: ResourceId, started: SimTime },
    /// Finished; terminal.
    Done {
        rid: ResourceId,
        finished: SimTime,
        cpu_s: f64,
        cost: GridDollars,
    },
    /// Attempts exhausted; terminal.
    Failed,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done { .. } | JobState::Failed)
    }

    /// The resource currently responsible for the job, if any.
    pub fn resource(&self) -> Option<ResourceId> {
        match self {
            JobState::Dispatched { rid, .. } | JobState::Running { rid, .. } => {
                Some(*rid)
            }
            _ => None,
        }
    }
}

/// One job: its spec plus runtime state.
#[derive(Debug, Clone)]
pub struct Job {
    pub spec: JobSpec,
    pub state: JobState,
    pub attempts: u32,
}

/// Transition error — indicates a driver bug, surfaced loudly.
#[derive(Debug)]
pub struct BadTransition {
    pub job: JobId,
    pub from: JobState,
    pub to: &'static str,
}

impl std::fmt::Display for BadTransition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "illegal transition for {}: {:?} -> {}",
            self.job, self.from, self.to
        )
    }
}

impl std::error::Error for BadTransition {}

/// The experiment: job table + deadline/budget envelope.
#[derive(Debug)]
pub struct Experiment {
    pub jobs: Vec<Job>,
    pub deadline: SimTime,
    pub budget: Option<GridDollars>,
    pub user: String,
    pub max_attempts: u32,
    /// Incremental rollups, kept in lockstep by the transition methods.
    n_done: u32,
    n_failed: u32,
    /// Ready job ids (iterates in dispatch order).
    ready: BTreeSet<JobId>,
    /// In-flight (Dispatched + Running) count per resource, indexed by
    /// `ResourceId` and grown on demand.
    in_flight: Vec<u32>,
    /// Dispatched-but-not-Running jobs per resource, with dispatch time
    /// (the dispatcher's cancellation candidates).
    queued: BTreeMap<ResourceId, BTreeMap<JobId, SimTime>>,
}

impl Experiment {
    pub fn new(
        specs: Vec<JobSpec>,
        deadline: SimTime,
        budget: Option<GridDollars>,
        user: &str,
        max_attempts: u32,
    ) -> Experiment {
        let ready: BTreeSet<JobId> = specs.iter().map(|s| s.id).collect();
        Experiment {
            jobs: specs
                .into_iter()
                .map(|spec| Job {
                    spec,
                    state: JobState::Ready,
                    attempts: 0,
                })
                .collect(),
            deadline,
            budget,
            user: user.to_string(),
            max_attempts,
            n_done: 0,
            n_failed: 0,
            ready,
            in_flight: Vec::new(),
            queued: BTreeMap::new(),
        }
    }

    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    fn job_mut(&mut self, id: JobId) -> &mut Job {
        &mut self.jobs[id.0 as usize]
    }

    // -- queries -------------------------------------------------------------

    /// Jobs not yet in a terminal state (the scheduler's `remaining_jobs`).
    /// O(1): maintained incrementally by the transitions.
    pub fn remaining(&self) -> u32 {
        self.jobs.len() as u32 - self.n_done - self.n_failed
    }

    /// O(1): maintained incrementally by the transitions.
    pub fn completed(&self) -> u32 {
        self.n_done
    }

    /// O(1): maintained incrementally by the transitions.
    pub fn failed(&self) -> u32 {
        self.n_failed
    }

    /// All terminal ⇒ the experiment is over. O(1); the event loop asks
    /// after every event.
    pub fn finished(&self) -> bool {
        (self.n_done + self.n_failed) as usize == self.jobs.len()
    }

    /// Iterator over Ready jobs in id order (dispatch order). O(answer):
    /// walks the maintained Ready set, not the whole job table.
    pub fn ready_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.ready.iter().copied()
    }

    /// Total settled cost across Done jobs.
    pub fn total_cost(&self) -> GridDollars {
        self.jobs
            .iter()
            .filter_map(|j| match j.state {
                JobState::Done { cost, .. } => Some(cost),
                _ => None,
            })
            .sum()
    }

    /// Virtual time the last job finished.
    pub fn makespan(&self) -> SimTime {
        self.jobs
            .iter()
            .filter_map(|j| match j.state {
                JobState::Done { finished, .. } => Some(finished),
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    // -- transitions ---------------------------------------------------------

    pub fn dispatch(
        &mut self,
        id: JobId,
        rid: ResourceId,
        now: SimTime,
    ) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        if job.state != JobState::Ready {
            return Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Dispatched",
            });
        }
        job.attempts += 1;
        job.state = JobState::Dispatched { rid, at: now };
        self.ready.remove(&id);
        let i = rid.0 as usize;
        if self.in_flight.len() <= i {
            self.in_flight.resize(i + 1, 0);
        }
        self.in_flight[i] += 1;
        self.queued.entry(rid).or_default().insert(id, now);
        Ok(())
    }

    pub fn start(&mut self, id: JobId, now: SimTime) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        match job.state {
            JobState::Dispatched { rid, .. } => {
                job.state = JobState::Running { rid, started: now };
                self.drop_queued(id, rid);
                Ok(())
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Running",
            }),
        }
    }

    pub fn complete(
        &mut self,
        id: JobId,
        now: SimTime,
        cpu_s: f64,
        cost: GridDollars,
    ) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        match job.state {
            JobState::Running { rid, .. } => {
                job.state = JobState::Done {
                    rid,
                    finished: now,
                    cpu_s,
                    cost,
                };
                self.n_done += 1;
                self.dec_in_flight(rid);
                Ok(())
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Done",
            }),
        }
    }

    /// Failure or cancellation of an in-flight job: re-queues while attempts
    /// remain, otherwise terminal-fails. Returns the resulting state.
    pub fn fail_attempt(&mut self, id: JobId) -> Result<&JobState, BadTransition> {
        let (rid, was_queued) = match self.job(id).state {
            JobState::Dispatched { rid, .. } => (rid, true),
            JobState::Running { rid, .. } => (rid, false),
            _ => {
                return Err(BadTransition {
                    job: id,
                    from: self.job(id).state.clone(),
                    to: "Ready/Failed",
                })
            }
        };
        if was_queued {
            self.drop_queued(id, rid);
        }
        self.dec_in_flight(rid);
        let max = self.max_attempts;
        let job = self.job_mut(id);
        if job.attempts >= max {
            job.state = JobState::Failed;
            self.n_failed += 1;
        } else {
            job.state = JobState::Ready;
            self.ready.insert(id);
        }
        Ok(&self.job(id).state)
    }

    /// Scheduler-initiated withdrawal of a queued (not yet Running) job:
    /// back to Ready with the dispatch attempt refunded — migration must
    /// never burn attempts (only failures do).
    pub fn release(&mut self, id: JobId) -> Result<(), BadTransition> {
        let job = self.job_mut(id);
        match job.state {
            JobState::Dispatched { rid, .. } => {
                job.attempts = job.attempts.saturating_sub(1);
                job.state = JobState::Ready;
                self.ready.insert(id);
                self.drop_queued(id, rid);
                self.dec_in_flight(rid);
                Ok(())
            }
            _ => Err(BadTransition {
                job: id,
                from: job.state.clone(),
                to: "Ready (release)",
            }),
        }
    }

    /// Journal-recovery support: roll every in-flight (Dispatched/Running)
    /// job back to Ready, refunding the dispatch attempt — a crash must not
    /// be able to exhaust attempts by itself. Returns how many rolled back.
    pub fn requeue_in_flight(&mut self) -> u32 {
        let mut n = 0;
        for idx in 0..self.jobs.len() {
            let Some(rid) = self.jobs[idx].state.resource() else {
                continue;
            };
            let id = self.jobs[idx].spec.id;
            self.jobs[idx].attempts = self.jobs[idx].attempts.saturating_sub(1);
            self.jobs[idx].state = JobState::Ready;
            self.ready.insert(id);
            self.drop_queued(id, rid);
            self.dec_in_flight(rid);
            n += 1;
        }
        n
    }

    /// In-flight job count per resource (drives dispatcher top-ups). O(1):
    /// read from the maintained counter, not a job-table scan.
    pub fn in_flight_on(&self, rid: ResourceId) -> u32 {
        self.in_flight.get(rid.0 as usize).copied().unwrap_or(0)
    }

    /// The maintained per-resource in-flight counters, indexed by
    /// `ResourceId` (may be shorter than the grid — untouched resources are
    /// implicitly zero).
    pub fn in_flight_counts(&self) -> &[u32] {
        &self.in_flight
    }

    /// Dispatched-but-not-Running jobs on `rid` as `(dispatched_at, job)`,
    /// in job-id order (the dispatcher's cancellation candidates).
    pub fn queued_on(
        &self,
        rid: ResourceId,
    ) -> impl Iterator<Item = (SimTime, JobId)> + '_ {
        self.queued
            .get(&rid)
            .into_iter()
            .flat_map(|m| m.iter().map(|(&id, &at)| (at, id)))
    }

    /// Resources currently holding at least one queued (Dispatched) job.
    pub fn resources_with_queued(&self) -> impl Iterator<Item = ResourceId> + '_ {
        self.queued.keys().copied()
    }

    /// Verify the incremental rollups against a full job-table scan
    /// (test/debug support).
    pub fn counts_consistent(&self) -> bool {
        let done = self
            .jobs
            .iter()
            .filter(|j| matches!(j.state, JobState::Done { .. }))
            .count() as u32;
        let failed = self
            .jobs
            .iter()
            .filter(|j| matches!(j.state, JobState::Failed))
            .count() as u32;
        if done != self.n_done || failed != self.n_failed {
            return false;
        }
        let ready: BTreeSet<JobId> = self
            .jobs
            .iter()
            .filter(|j| j.state == JobState::Ready)
            .map(|j| j.spec.id)
            .collect();
        if ready != self.ready {
            return false;
        }
        // Size the scratch to cover every rid the job table references, not
        // just the maintained vec: a drifted table could hold an in-flight
        // job on a rid the counters never saw, and the checker must report
        // that as inconsistent rather than index out of bounds.
        let max_rid = self
            .jobs
            .iter()
            .filter_map(|j| j.state.resource())
            .map(|r| r.0 as usize + 1)
            .max()
            .unwrap_or(0);
        let mut in_flight = vec![0u32; self.in_flight.len().max(max_rid)];
        let mut queued: BTreeMap<ResourceId, BTreeMap<JobId, SimTime>> =
            BTreeMap::new();
        for j in &self.jobs {
            match j.state {
                JobState::Dispatched { rid, at } => {
                    in_flight[rid.0 as usize] += 1;
                    queued.entry(rid).or_default().insert(j.spec.id, at);
                }
                JobState::Running { rid, .. } => {
                    in_flight[rid.0 as usize] += 1;
                }
                _ => {}
            }
        }
        // A longer scratch vec means a rid the counters never tracked —
        // that length mismatch is itself the drift signal.
        in_flight == self.in_flight && queued == self.queued
    }

    // -- rollup plumbing -----------------------------------------------------

    fn dec_in_flight(&mut self, rid: ResourceId) {
        let c = &mut self.in_flight[rid.0 as usize];
        debug_assert!(*c > 0, "in-flight underflow on {rid}");
        *c = c.saturating_sub(1);
    }

    fn drop_queued(&mut self, id: JobId, rid: ResourceId) {
        if let Some(q) = self.queued.get_mut(&rid) {
            q.remove(&id);
            if q.is_empty() {
                self.queued.remove(&rid);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{expand, Plan};

    fn specs(n: usize) -> Vec<JobSpec> {
        let src = format!(
            "parameter i integer range from 1 to {n}\ntask main\nexecute run $i\nendtask"
        );
        expand(&Plan::parse(&src).unwrap(), 0).unwrap()
    }

    fn exp(n: usize) -> Experiment {
        Experiment::new(specs(n), 3600.0, None, "rajkumar", 3)
    }

    #[test]
    fn happy_path_lifecycle() {
        let mut e = exp(2);
        assert_eq!(e.remaining(), 2);
        e.dispatch(JobId(0), ResourceId(4), 10.0).unwrap();
        e.start(JobId(0), 20.0).unwrap();
        e.complete(JobId(0), 50.0, 30.0, 1.5).unwrap();
        assert_eq!(e.completed(), 1);
        assert_eq!(e.remaining(), 1);
        assert!(!e.finished());
        assert_eq!(e.total_cost(), 1.5);
        assert_eq!(e.makespan(), 50.0);
        assert_eq!(e.in_flight_on(ResourceId(4)), 0);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut e = exp(1);
        // Can't start or complete a Ready job.
        assert!(e.start(JobId(0), 0.0).is_err());
        assert!(e.complete(JobId(0), 0.0, 0.0, 0.0).is_err());
        e.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
        // Can't dispatch twice.
        assert!(e.dispatch(JobId(0), ResourceId(1), 0.0).is_err());
        e.start(JobId(0), 0.0).unwrap();
        e.complete(JobId(0), 1.0, 1.0, 0.1).unwrap();
        // Terminal is terminal.
        assert!(e.fail_attempt(JobId(0)).is_err());
        assert!(e.dispatch(JobId(0), ResourceId(0), 2.0).is_err());
    }

    #[test]
    fn failure_requeues_until_attempts_exhausted() {
        let mut e = exp(1);
        for attempt in 1..=3 {
            e.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
            assert_eq!(e.job(JobId(0)).attempts, attempt);
            let state = e.fail_attempt(JobId(0)).unwrap().clone();
            if attempt < 3 {
                assert_eq!(state, JobState::Ready);
            } else {
                assert_eq!(state, JobState::Failed);
            }
        }
        assert_eq!(e.failed(), 1);
        assert!(e.finished());
    }

    #[test]
    fn running_failure_also_requeues() {
        let mut e = exp(1);
        e.dispatch(JobId(0), ResourceId(2), 0.0).unwrap();
        e.start(JobId(0), 1.0).unwrap();
        assert_eq!(e.in_flight_on(ResourceId(2)), 1);
        assert_eq!(*e.fail_attempt(JobId(0)).unwrap(), JobState::Ready);
        assert_eq!(e.in_flight_on(ResourceId(2)), 0);
    }

    #[test]
    fn ready_iteration_in_id_order() {
        let mut e = exp(3);
        e.dispatch(JobId(1), ResourceId(0), 0.0).unwrap();
        let ready: Vec<JobId> = e.ready_jobs().collect();
        assert_eq!(ready, vec![JobId(0), JobId(2)]);
    }

    #[test]
    fn incremental_rollups_survive_churn_and_recovery() {
        let mut e = exp(3);
        e.dispatch(JobId(0), ResourceId(1), 1.0).unwrap();
        e.dispatch(JobId(1), ResourceId(1), 2.0).unwrap();
        e.start(JobId(0), 3.0).unwrap();
        assert!(e.counts_consistent());
        assert_eq!(e.in_flight_on(ResourceId(1)), 2);
        assert_eq!(e.queued_on(ResourceId(1)).collect::<Vec<_>>(), vec![(2.0, JobId(1))]);
        assert_eq!(e.resources_with_queued().collect::<Vec<_>>(), vec![ResourceId(1)]);
        e.release(JobId(1)).unwrap();
        assert!(e.counts_consistent());
        assert_eq!(e.resources_with_queued().count(), 0);
        e.complete(JobId(0), 4.0, 1.0, 0.5).unwrap();
        assert!(e.counts_consistent());
        assert_eq!(e.in_flight_on(ResourceId(1)), 0);
        // Crash-recovery rollback keeps the rollups aligned too.
        e.dispatch(JobId(2), ResourceId(0), 5.0).unwrap();
        assert_eq!(e.requeue_in_flight(), 1);
        assert_eq!(e.job(JobId(2)).attempts, 0);
        assert!(e.counts_consistent());
        assert_eq!(e.remaining(), 2);
        assert_eq!(e.completed(), 1);
    }
}
