//! Experiment persistence: append-only JSON-lines journal + restart.
//!
//! "The parametric engine maintains the state of the whole experiment and
//! ensures that the state is recorded in persistent storage. This allows
//! the experiment to be restarted if the node running Nimrod goes down."
//!
//! Format: line 1 is a header (plan source, seed, envelope); every
//! subsequent line is one transition record. Recovery replays transitions
//! onto a freshly-expanded job table; jobs that were in flight at the crash
//! are rolled back to `Ready` (their attempt still counts — the work was
//! lost, the bill may not be recoverable, so we re-dispatch conservatively).

use super::Experiment;
use crate::plan::{expand, Plan};
use crate::types::{JobId, ResourceId};
use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Append-only journal writer.
pub struct Journal {
    out: BufWriter<File>,
}

impl Journal {
    /// Create a new journal, writing the header.
    pub fn create(
        path: &Path,
        plan_src: &str,
        seed: u64,
        exp: &Experiment,
    ) -> Result<Journal> {
        let file = File::create(path)
            .with_context(|| format!("create journal {}", path.display()))?;
        let mut out = BufWriter::new(file);
        let header = Json::obj(vec![
            ("type", Json::str("header")),
            ("plan", Json::str(plan_src)),
            ("seed", Json::num(seed as f64)),
            ("deadline", Json::num(exp.deadline)),
            (
                "budget",
                exp.budget.map(Json::num).unwrap_or(Json::Null),
            ),
            ("user", Json::str(&exp.user)),
            ("max_attempts", Json::num(exp.max_attempts as f64)),
        ]);
        writeln!(out, "{}", header.to_string())?;
        out.flush()?;
        Ok(Journal { out })
    }

    /// Open an existing journal for appending (after recovery).
    pub fn append_to(path: &Path) -> Result<Journal> {
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        Ok(Journal {
            out: BufWriter::new(file),
        })
    }

    fn record(&mut self, fields: Vec<(&str, Json)>) -> Result<()> {
        writeln!(self.out, "{}", Json::obj(fields).to_string())?;
        // Flush per record: the journal exists to survive crashes.
        self.out.flush()?;
        Ok(())
    }

    pub fn dispatched(&mut self, job: JobId, rid: ResourceId, at: f64) -> Result<()> {
        self.record(vec![
            ("type", Json::str("dispatch")),
            ("job", Json::num(job.0 as f64)),
            ("rid", Json::num(rid.0 as f64)),
            ("at", Json::num(at)),
        ])
    }

    pub fn started(&mut self, job: JobId, at: f64) -> Result<()> {
        self.record(vec![
            ("type", Json::str("start")),
            ("job", Json::num(job.0 as f64)),
            ("at", Json::num(at)),
        ])
    }

    pub fn completed(
        &mut self,
        job: JobId,
        at: f64,
        cpu_s: f64,
        cost: f64,
    ) -> Result<()> {
        self.record(vec![
            ("type", Json::str("complete")),
            ("job", Json::num(job.0 as f64)),
            ("at", Json::num(at)),
            ("cpu_s", Json::num(cpu_s)),
            ("cost", Json::num(cost)),
        ])
    }

    pub fn failed_attempt(&mut self, job: JobId) -> Result<()> {
        self.record(vec![
            ("type", Json::str("fail")),
            ("job", Json::num(job.0 as f64)),
        ])
    }

    pub fn released(&mut self, job: JobId) -> Result<()> {
        self.record(vec![
            ("type", Json::str("release")),
            ("job", Json::num(job.0 as f64)),
        ])
    }

    /// An advance-reservation hold was taken on `rid`.
    pub fn reserved(
        &mut self,
        rid: ResourceId,
        slots: u32,
        rate: f64,
        expires: f64,
    ) -> Result<()> {
        self.record(vec![
            ("type", Json::str("reserve")),
            ("rid", Json::num(rid.0 as f64)),
            ("slots", Json::num(slots as f64)),
            ("rate", Json::num(rate)),
            ("expires", Json::num(expires)),
        ])
    }

    /// The hold on `rid` was committed (binding until `expires`).
    pub fn reservation_committed(
        &mut self,
        rid: ResourceId,
        expires: f64,
    ) -> Result<()> {
        self.record(vec![
            ("type", Json::str("res-commit")),
            ("rid", Json::num(rid.0 as f64)),
            ("expires", Json::num(expires)),
        ])
    }

    /// The hold on `rid` ended (cancelled, expired or fully consumed):
    /// whatever slots it still held are free again.
    pub fn reservation_closed(&mut self, rid: ResourceId) -> Result<()> {
        self.record(vec![
            ("type", Json::str("res-close")),
            ("rid", Json::num(rid.0 as f64)),
        ])
    }
}

/// A hold that was still open when the journal stopped. Recovery *releases*
/// these (a fresh world re-derives occupancy from the engines, so a
/// crashed run's holds must not leak reserved capacity); they are surfaced
/// so the resuming driver can audit what was forfeited and re-reserve if
/// the work still needs the capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveredReservation {
    pub rid: ResourceId,
    pub slots: u32,
    pub rate: f64,
    pub expires: f64,
    pub committed: bool,
}

/// Recovered state: the rebuilt experiment plus the header metadata and
/// any reservation holds that were open at the crash (released, not
/// restored — see [`RecoveredReservation`]).
pub struct Recovered {
    pub experiment: Experiment,
    pub plan_src: String,
    pub seed: u64,
    pub open_reservations: Vec<RecoveredReservation>,
}

/// Replay a journal into an [`Experiment`].
pub fn recover(path: &Path) -> Result<Recovered> {
    let file = File::open(path)
        .with_context(|| format!("open journal {}", path.display()))?;
    let mut lines = BufReader::new(file).lines();
    let header_line = match lines.next() {
        Some(l) => l?,
        None => bail!("journal is empty"),
    };
    let header = parse(&header_line).context("parse journal header")?;
    if header.req_str("type")? != "header" {
        bail!("first journal line is not a header");
    }
    let plan_src = header.req_str("plan")?.to_string();
    let seed = header.req_f64("seed")? as u64;
    let plan = Plan::parse(&plan_src).context("re-parse journaled plan")?;
    let specs = expand(&plan, seed).context("re-expand journaled plan")?;
    let mut exp = Experiment::new(
        specs,
        header.req_f64("deadline")?,
        header.get("budget").as_f64(),
        header.req_str("user")?,
        header.req_f64("max_attempts")? as u32,
    );

    // Reservation holds are tracked separately from the job table: a
    // reserve opens one, res-commit hardens it, res-close ends it. What
    // survives the replay is exactly what the crashed run still held.
    let mut holds: std::collections::BTreeMap<u32, RecoveredReservation> =
        std::collections::BTreeMap::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue; // torn tail write
        }
        let Ok(rec) = parse(&line) else {
            continue; // torn tail write: stop-loss, keep what we have
        };
        let jid = |rec: &Json| -> Result<JobId> {
            Ok(JobId(rec.req_f64("job")? as u32))
        };
        match rec.req_str("type")? {
            "dispatch" => {
                let rid = ResourceId(rec.req_f64("rid")? as u32);
                exp.dispatch(jid(&rec)?, rid, rec.req_f64("at")?)?;
            }
            "start" => exp.start(jid(&rec)?, rec.req_f64("at")?)?,
            "complete" => exp.complete(
                jid(&rec)?,
                rec.req_f64("at")?,
                rec.req_f64("cpu_s")?,
                rec.req_f64("cost")?,
            )?,
            "fail" => {
                exp.fail_attempt(jid(&rec)?)?;
            }
            "release" => {
                exp.release(jid(&rec)?)?;
            }
            "reserve" => {
                let rid = rec.req_f64("rid")? as u32;
                holds.insert(
                    rid,
                    RecoveredReservation {
                        rid: ResourceId(rid),
                        slots: rec.req_f64("slots")? as u32,
                        rate: rec.req_f64("rate")?,
                        expires: rec.req_f64("expires")?,
                        committed: false,
                    },
                );
            }
            "res-commit" => {
                let rid = rec.req_f64("rid")? as u32;
                if let Some(h) = holds.get_mut(&rid) {
                    h.committed = true;
                    h.expires = rec.req_f64("expires")?;
                }
            }
            "res-close" => {
                holds.remove(&(rec.req_f64("rid")? as u32));
            }
            other => bail!("unknown journal record type `{other}`"),
        }
    }

    // Roll in-flight jobs back to Ready: the engine died holding them. The
    // attempt is refunded (a crash must not exhaust attempts by itself);
    // going through the engine keeps its incremental rollups consistent.
    exp.requeue_in_flight();
    Ok(Recovered {
        experiment: exp,
        plan_src,
        seed,
        open_reservations: holds.into_values().collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::JobState;
    use crate::plan::Plan;

    const PLAN: &str = "parameter i integer range from 1 to 4\ntask main\nexecute run $i\nendtask";

    fn fresh(dir: &Path) -> (Experiment, Journal, std::path::PathBuf) {
        let specs =
            expand(&Plan::parse(PLAN).unwrap(), 9).unwrap();
        let exp = Experiment::new(specs, 7200.0, Some(500.0), "davida", 3);
        let path = dir.join("exp.journal");
        let j = Journal::create(&path, PLAN, 9, &exp).unwrap();
        (exp, j, path)
    }

    #[test]
    fn roundtrip_mixed_states() {
        let dir = std::env::temp_dir().join(format!("nimrod-j-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (mut exp, mut j, path) = fresh(&dir);

        // j0: done. j1: running (in-flight at crash). j2: failed once,
        // requeued. j3: untouched.
        exp.dispatch(JobId(0), ResourceId(5), 10.0).unwrap();
        j.dispatched(JobId(0), ResourceId(5), 10.0).unwrap();
        exp.start(JobId(0), 20.0).unwrap();
        j.started(JobId(0), 20.0).unwrap();
        exp.complete(JobId(0), 100.0, 80.0, 3.5).unwrap();
        j.completed(JobId(0), 100.0, 80.0, 3.5).unwrap();

        exp.dispatch(JobId(1), ResourceId(6), 15.0).unwrap();
        j.dispatched(JobId(1), ResourceId(6), 15.0).unwrap();
        exp.start(JobId(1), 25.0).unwrap();
        j.started(JobId(1), 25.0).unwrap();

        exp.dispatch(JobId(2), ResourceId(7), 18.0).unwrap();
        j.dispatched(JobId(2), ResourceId(7), 18.0).unwrap();
        exp.fail_attempt(JobId(2)).unwrap();
        j.failed_attempt(JobId(2)).unwrap();
        drop(j); // crash

        let rec = recover(&path).unwrap();
        let e = rec.experiment;
        assert_eq!(rec.seed, 9);
        assert_eq!(e.user, "davida");
        assert_eq!(e.budget, Some(500.0));
        assert_eq!(e.jobs.len(), 4);
        // j0 stays Done with its cost.
        assert!(matches!(e.job(JobId(0)).state, JobState::Done { cost, .. } if cost == 3.5));
        // j1 rolled back to Ready with the attempt refunded.
        assert_eq!(e.job(JobId(1)).state, JobState::Ready);
        assert_eq!(e.job(JobId(1)).attempts, 0);
        // j2 Ready with one burned attempt.
        assert_eq!(e.job(JobId(2)).state, JobState::Ready);
        assert_eq!(e.job(JobId(2)).attempts, 1);
        // j3 untouched.
        assert_eq!(e.job(JobId(3)).state, JobState::Ready);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_line_tolerated() {
        let dir =
            std::env::temp_dir().join(format!("nimrod-j2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (mut exp, mut j, path) = fresh(&dir);
        exp.dispatch(JobId(0), ResourceId(1), 5.0).unwrap();
        j.dispatched(JobId(0), ResourceId(1), 5.0).unwrap();
        drop(j);
        // Simulate a torn write at crash.
        use std::io::Write as _;
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"type\":\"comp").unwrap();
        drop(f);
        let rec = recover(&path).unwrap();
        assert_eq!(rec.experiment.job(JobId(0)).state, JobState::Ready);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restart_mid_reservation_releases_open_holds() {
        let dir =
            std::env::temp_dir().join(format!("nimrod-j4-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (mut exp, mut j, path) = fresh(&dir);

        // r2: reserved then committed then closed — fully settled, must
        // not resurface. r5: committed and still open at the crash.
        // r8: reserved (never committed) and still open at the crash.
        j.reserved(ResourceId(2), 3, 0.8, 600.0).unwrap();
        j.reservation_committed(ResourceId(2), 4000.0).unwrap();
        j.reservation_closed(ResourceId(2)).unwrap();
        j.reserved(ResourceId(5), 2, 1.5, 700.0).unwrap();
        j.reservation_committed(ResourceId(5), 5000.0).unwrap();
        j.reserved(ResourceId(8), 4, 0.5, 900.0).unwrap();
        // Job records interleave with reservation records.
        exp.dispatch(JobId(0), ResourceId(5), 10.0).unwrap();
        j.dispatched(JobId(0), ResourceId(5), 10.0).unwrap();
        drop(j); // crash

        let rec = recover(&path).unwrap();
        // The job table replays as before.
        assert_eq!(rec.experiment.job(JobId(0)).state, JobState::Ready);
        // Only the two open holds survive, in resource order, with the
        // commit state and binding expiry the crashed run last recorded.
        assert_eq!(
            rec.open_reservations,
            vec![
                RecoveredReservation {
                    rid: ResourceId(5),
                    slots: 2,
                    rate: 1.5,
                    expires: 5000.0,
                    committed: true,
                },
                RecoveredReservation {
                    rid: ResourceId(8),
                    slots: 4,
                    rate: 0.5,
                    expires: 900.0,
                    committed: false,
                },
            ]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_journal_is_error() {
        let dir =
            std::env::temp_dir().join(format!("nimrod-j3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.journal");
        std::fs::write(&path, "").unwrap();
        assert!(recover(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
