//! The Clustor-style component network protocol (paper §4).
//!
//! "Nimrod/G components use TCP/IP sockets for exchanging commands and
//! information between them." Frames are a 4-byte big-endian length prefix
//! followed by one JSON document; [`Message`] enumerates the commands the
//! components exchange. The same framing serves the engine↔client monitor
//! channel and the engine↔worker dispatch channel in live mode.

use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Maximum accepted frame (guards against corrupt length prefixes).
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Handshake: component kind + protocol version.
    Hello { component: String, version: u32 },
    /// Client → engine: request an experiment status snapshot.
    StatusRequest,
    /// Engine → client: status snapshot.
    Status {
        jobs_total: u32,
        jobs_completed: u32,
        jobs_failed: u32,
        jobs_running: u32,
        spent: f64,
        busy_workers: u32,
        elapsed_s: f64,
    },
    /// Client → engine: adjust the experiment envelope mid-run (the paper's
    /// client can "vary parameters related to time and cost").
    SetDeadline { deadline_s: f64 },
    SetBudget { budget: f64 },
    /// Client → engine: stop the experiment.
    Stop,
    /// Engine → client: generic acknowledgement.
    Ok,
    /// Engine → client: error report.
    Error { reason: String },
}

impl Message {
    pub fn to_json(&self) -> Json {
        match self {
            Message::Hello { component, version } => Json::obj(vec![
                ("type", Json::str("hello")),
                ("component", Json::str(component)),
                ("version", Json::num(*version as f64)),
            ]),
            Message::StatusRequest => {
                Json::obj(vec![("type", Json::str("status_request"))])
            }
            Message::Status {
                jobs_total,
                jobs_completed,
                jobs_failed,
                jobs_running,
                spent,
                busy_workers,
                elapsed_s,
            } => Json::obj(vec![
                ("type", Json::str("status")),
                ("jobs_total", Json::num(*jobs_total as f64)),
                ("jobs_completed", Json::num(*jobs_completed as f64)),
                ("jobs_failed", Json::num(*jobs_failed as f64)),
                ("jobs_running", Json::num(*jobs_running as f64)),
                ("spent", Json::num(*spent)),
                ("busy_workers", Json::num(*busy_workers as f64)),
                ("elapsed_s", Json::num(*elapsed_s)),
            ]),
            Message::SetDeadline { deadline_s } => Json::obj(vec![
                ("type", Json::str("set_deadline")),
                ("deadline_s", Json::num(*deadline_s)),
            ]),
            Message::SetBudget { budget } => Json::obj(vec![
                ("type", Json::str("set_budget")),
                ("budget", Json::num(*budget)),
            ]),
            Message::Stop => Json::obj(vec![("type", Json::str("stop"))]),
            Message::Ok => Json::obj(vec![("type", Json::str("ok"))]),
            Message::Error { reason } => Json::obj(vec![
                ("type", Json::str("error")),
                ("reason", Json::str(reason)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Message> {
        Ok(match v.req_str("type")? {
            "hello" => Message::Hello {
                component: v.req_str("component")?.to_string(),
                version: v.req_f64("version")? as u32,
            },
            "status_request" => Message::StatusRequest,
            "status" => Message::Status {
                jobs_total: v.req_f64("jobs_total")? as u32,
                jobs_completed: v.req_f64("jobs_completed")? as u32,
                jobs_failed: v.req_f64("jobs_failed")? as u32,
                jobs_running: v.req_f64("jobs_running")? as u32,
                spent: v.req_f64("spent")?,
                busy_workers: v.req_f64("busy_workers")? as u32,
                elapsed_s: v.req_f64("elapsed_s")?,
            },
            "set_deadline" => Message::SetDeadline {
                deadline_s: v.req_f64("deadline_s")?,
            },
            "set_budget" => Message::SetBudget {
                budget: v.req_f64("budget")?,
            },
            "stop" => Message::Stop,
            "ok" => Message::Ok,
            "error" => Message::Error {
                reason: v.req_str("reason")?.to_string(),
            },
            other => bail!("unknown message type `{other}`"),
        })
    }
}

/// Write one framed message.
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    let body = msg.to_json().to_string();
    let len = body.len() as u32;
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read one framed message.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf).context("read frame length")?;
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("oversized frame: {len} bytes");
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).context("read frame body")?;
    let text = std::str::from_utf8(&body).context("frame not utf-8")?;
    Message::from_json(&parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        let back = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Hello {
            component: "client".into(),
            version: 1,
        });
        roundtrip(Message::StatusRequest);
        roundtrip(Message::Status {
            jobs_total: 165,
            jobs_completed: 42,
            jobs_failed: 1,
            jobs_running: 8,
            spent: 1234.5,
            busy_workers: 8,
            elapsed_s: 77.7,
        });
        roundtrip(Message::SetDeadline { deadline_s: 3600.0 });
        roundtrip(Message::SetBudget { budget: 500.0 });
        roundtrip(Message::Stop);
        roundtrip(Message::Ok);
        roundtrip(Message::Error {
            reason: "boom".into(),
        });
    }

    #[test]
    fn multiple_frames_in_sequence() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::StatusRequest).unwrap();
        write_frame(&mut buf, &Message::Stop).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Message::StatusRequest);
        assert_eq!(read_frame(&mut r).unwrap(), Message::Stop);
    }

    #[test]
    fn truncated_frame_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::Stop).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn oversized_length_rejected() {
        let buf = (MAX_FRAME + 1).to_be_bytes().to_vec();
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn garbage_body_rejected() {
        let body = b"not json";
        let mut buf = (body.len() as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(body);
        assert!(read_frame(&mut &buf[..]).is_err());
    }
}
