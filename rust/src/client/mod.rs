//! Client / user station (paper §2): monitoring console + control channel.
//!
//! The engine side runs a [`StatusServer`] (a TCP listener thread serving
//! the Clustor protocol); any number of [`MonitorClient`]s can connect
//! concurrently — the paper runs clients at Monash and Argonne against one
//! experiment — to poll status, adjust deadline/budget, or stop the run.

use crate::protocol::{read_frame, write_frame, Message};
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Shared experiment status the engine keeps current and the server reads.
#[derive(Debug, Default)]
pub struct StatusBoard {
    pub jobs_total: AtomicU32,
    pub jobs_completed: AtomicU32,
    pub jobs_failed: AtomicU32,
    pub jobs_running: AtomicU32,
    /// Spend in milli-G$ (atomics carry integers).
    pub spent_milli: AtomicU64,
    pub busy_workers: AtomicU32,
    pub elapsed_ms: AtomicU64,
    /// Control intents raised by clients for the engine to apply.
    pub stop_requested: AtomicBool,
    /// New deadline in seconds ×1000 (0 = none pending).
    pub new_deadline_ms: AtomicU64,
    /// New budget in milli-G$ (0 = none pending).
    pub new_budget_milli: AtomicU64,
}

impl StatusBoard {
    fn snapshot(&self) -> Message {
        Message::Status {
            jobs_total: self.jobs_total.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            jobs_running: self.jobs_running.load(Ordering::Relaxed),
            spent: self.spent_milli.load(Ordering::Relaxed) as f64 / 1000.0,
            busy_workers: self.busy_workers.load(Ordering::Relaxed),
            elapsed_s: self.elapsed_ms.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }
}

/// The engine-side status/control server.
pub struct StatusServer {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl StatusServer {
    /// Start serving on an ephemeral localhost port.
    pub fn start(board: Arc<StatusBoard>) -> Result<StatusServer> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("bind status server")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = shutdown.clone();
        let handle = std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let board = board.clone();
                        std::thread::spawn(move || {
                            let _ = serve_connection(stream, &board);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(StatusServer {
            addr,
            shutdown,
            handle: Some(handle),
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_connection(mut stream: TcpStream, board: &StatusBoard) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Expect a handshake first.
    match read_frame(&mut stream)? {
        Message::Hello { .. } => write_frame(&mut stream, &Message::Ok)?,
        _ => {
            write_frame(
                &mut stream,
                &Message::Error {
                    reason: "expected hello".into(),
                },
            )?;
            bail!("bad handshake");
        }
    }
    loop {
        let msg = match read_frame(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // client hung up
        };
        match msg {
            Message::StatusRequest => {
                write_frame(&mut stream, &board.snapshot())?;
            }
            Message::SetDeadline { deadline_s } => {
                board
                    .new_deadline_ms
                    .store((deadline_s * 1000.0) as u64, Ordering::Relaxed);
                write_frame(&mut stream, &Message::Ok)?;
            }
            Message::SetBudget { budget } => {
                board
                    .new_budget_milli
                    .store((budget * 1000.0) as u64, Ordering::Relaxed);
                write_frame(&mut stream, &Message::Ok)?;
            }
            Message::Stop => {
                board.stop_requested.store(true, Ordering::Relaxed);
                write_frame(&mut stream, &Message::Ok)?;
                return Ok(());
            }
            other => {
                write_frame(
                    &mut stream,
                    &Message::Error {
                        reason: format!("unexpected {other:?}"),
                    },
                )?;
            }
        }
    }
}

/// A monitoring/control client connection.
pub struct MonitorClient {
    stream: TcpStream,
}

impl MonitorClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<MonitorClient> {
        let mut stream = TcpStream::connect(addr).context("connect to engine")?;
        stream.set_nodelay(true).ok();
        write_frame(
            &mut stream,
            &Message::Hello {
                component: "client".into(),
                version: 1,
            },
        )?;
        match read_frame(&mut stream)? {
            Message::Ok => Ok(MonitorClient { stream }),
            other => bail!("handshake rejected: {other:?}"),
        }
    }

    /// Poll the experiment status.
    pub fn status(&mut self) -> Result<Message> {
        write_frame(&mut self.stream, &Message::StatusRequest)?;
        let msg = read_frame(&mut self.stream)?;
        match msg {
            Message::Status { .. } => Ok(msg),
            other => bail!("expected status, got {other:?}"),
        }
    }

    /// Tighten/relax the deadline mid-run.
    pub fn set_deadline(&mut self, deadline_s: f64) -> Result<()> {
        write_frame(&mut self.stream, &Message::SetDeadline { deadline_s })?;
        self.expect_ok()
    }

    /// Adjust the budget mid-run.
    pub fn set_budget(&mut self, budget: f64) -> Result<()> {
        write_frame(&mut self.stream, &Message::SetBudget { budget })?;
        self.expect_ok()
    }

    /// Ask the engine to stop the experiment.
    pub fn stop_experiment(&mut self) -> Result<()> {
        write_frame(&mut self.stream, &Message::Stop)?;
        self.expect_ok()
    }

    fn expect_ok(&mut self) -> Result<()> {
        match read_frame(&mut self.stream)? {
            Message::Ok => Ok(()),
            other => bail!("expected ok, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_roundtrip_over_tcp() {
        let board = Arc::new(StatusBoard::default());
        board.jobs_total.store(10, Ordering::Relaxed);
        board.jobs_completed.store(4, Ordering::Relaxed);
        board.spent_milli.store(1500, Ordering::Relaxed);
        let server = StatusServer::start(board.clone()).unwrap();
        let mut client = MonitorClient::connect(server.addr).unwrap();
        match client.status().unwrap() {
            Message::Status {
                jobs_total,
                jobs_completed,
                spent,
                ..
            } => {
                assert_eq!(jobs_total, 10);
                assert_eq!(jobs_completed, 4);
                assert!((spent - 1.5).abs() < 1e-9);
            }
            other => panic!("bad reply {other:?}"),
        }
        server.stop();
    }

    #[test]
    fn multiple_concurrent_clients() {
        let board = Arc::new(StatusBoard::default());
        board.jobs_total.store(3, Ordering::Relaxed);
        let server = StatusServer::start(board.clone()).unwrap();
        // The paper monitors one experiment from two continents; here, two
        // sockets.
        let mut a = MonitorClient::connect(server.addr).unwrap();
        let mut b = MonitorClient::connect(server.addr).unwrap();
        assert!(matches!(a.status().unwrap(), Message::Status { .. }));
        assert!(matches!(b.status().unwrap(), Message::Status { .. }));
        server.stop();
    }

    #[test]
    fn control_intents_reach_the_board() {
        let board = Arc::new(StatusBoard::default());
        let server = StatusServer::start(board.clone()).unwrap();
        let mut c = MonitorClient::connect(server.addr).unwrap();
        c.set_deadline(7200.0).unwrap();
        c.set_budget(99.5).unwrap();
        assert_eq!(board.new_deadline_ms.load(Ordering::Relaxed), 7_200_000);
        assert_eq!(board.new_budget_milli.load(Ordering::Relaxed), 99_500);
        c.stop_experiment().unwrap();
        assert!(board.stop_requested.load(Ordering::Relaxed));
        server.stop();
    }
}
