//! Workload definitions: the ionization-chamber calibration study and the
//! per-job work sampler.
//!
//! The Figure-3 experiment "ran the code across different design
//! parameters" — voltage, pressure and beam energy in our surrogate model
//! (python/compile/model.py). [`ionization_plan`] emits the corresponding
//! plan-language source; [`WorkSampler`] draws the per-job compute demand
//! the simulator charges for.

use crate::config::WorkloadConfig;
use crate::plan::{expand, JobSpec, Plan};
use crate::types::JobId;
use crate::util::rng::Rng;

/// Parameter ranges mirrored from the L2 model's physical ranges.
pub const VOLTAGE_RANGE: (f64, f64) = (100.0, 1000.0);
pub const PRESSURE_RANGE: (f64, f64) = (0.5, 2.0);
pub const ENERGY_RANGE: (f64, f64) = (1.0, 20.0);

/// Emit the calibration-study plan: an `nv × np × ne` sweep. The paper-scale
/// default (`ionization_plan(11, 5, 3)`) expands to 165 jobs, matching the
/// trial in [4] (Abramson, Giddy, Kotler, IPDPS 2000).
pub fn ionization_plan(nv: usize, np: usize, ne: usize) -> String {
    assert!(nv >= 1 && np >= 1 && ne >= 1);
    let vstep = (VOLTAGE_RANGE.1 - VOLTAGE_RANGE.0) / (nv.max(2) - 1) as f64;
    let estep = (ENERGY_RANGE.1 - ENERGY_RANGE.0) / (ne.max(2) - 1) as f64;
    let mut plan = String::new();
    plan.push_str("# ionization chamber calibration (paper Figure 3 workload)\n");
    plan.push_str(&format!(
        "parameter voltage label \"electrode voltage (V)\" float range from {} to {} step {}\n",
        VOLTAGE_RANGE.0, VOLTAGE_RANGE.1, vstep
    ));
    plan.push_str(&format!(
        "parameter pressure label \"gas pressure (atm)\" float random from {} to {} count {}\n",
        PRESSURE_RANGE.0, PRESSURE_RANGE.1, np
    ));
    plan.push_str(&format!(
        "parameter energy label \"beam energy (MeV)\" float range from {} to {} step {}\n",
        ENERGY_RANGE.0, ENERGY_RANGE.1, estep
    ));
    plan.push_str("constant chamber text \"icc-mk2\"\n");
    plan.push_str("task main\n");
    plan.push_str("    copy chamber.cfg node:chamber.cfg\n");
    plan.push_str(
        "    execute ./icc_sim -v $voltage -p $pressure -e $energy -c $chamber -o results.dat\n",
    );
    plan.push_str("    copy node:results.dat results.$jobname.dat\n");
    plan.push_str("endtask\n");
    plan
}

/// Parse + expand the paper-scale study (165 jobs).
pub fn ionization_jobs(seed: u64) -> Vec<JobSpec> {
    let src = ionization_plan(11, 5, 3);
    // lint:allow(PANIC-BUDGET): the plan text is a compile-time constant exercised by the tier-1 tests
    let plan = Plan::parse(&src).expect("generated plan must parse");
    // lint:allow(PANIC-BUDGET): expansion of the constant plan is deterministic and covered by tests
    expand(&plan, seed).expect("generated plan must expand")
}

/// Draws per-job compute demand: lognormal jitter around the configured
/// mean so job sizes are heterogeneous but reproducible per (seed, job).
#[derive(Debug, Clone)]
pub struct WorkSampler {
    base_ref_h: f64,
    sigma: f64,
    seed: u64,
}

impl WorkSampler {
    pub fn new(cfg: &WorkloadConfig, seed: u64) -> WorkSampler {
        WorkSampler {
            base_ref_h: cfg.job_work_ref_h,
            sigma: cfg.work_jitter_sigma,
            seed,
        }
    }

    /// Work (reference CPU-hours) for one job. Deterministic in (seed, id):
    /// re-dispatching a failed job costs the same work again.
    pub fn work_ref_h(&self, job: JobId) -> f64 {
        if self.sigma <= 0.0 {
            return self.base_ref_h;
        }
        let mut rng = Rng::new(self.seed ^ (job.0 as u64).wrapping_mul(0x9E37_79B9));
        // E[lognormal(mu, sigma)] = exp(mu + sigma²/2) ⇒ mu keeps the mean.
        let mu = self.base_ref_h.ln() - self.sigma * self.sigma / 2.0;
        rng.lognormal(mu, self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_is_165_jobs() {
        let jobs = ionization_jobs(1);
        assert_eq!(jobs.len(), 165);
        // Every job carries the three swept parameters plus constants.
        assert!(jobs[0].bindings.contains_key("voltage"));
        assert!(jobs[0].bindings.contains_key("pressure"));
        assert!(jobs[0].bindings.contains_key("energy"));
        assert!(jobs[0].bindings.contains_key("chamber"));
    }

    #[test]
    fn parameters_inside_model_ranges() {
        for job in ionization_jobs(2) {
            let v = job.f64_binding("voltage").unwrap();
            let p = job.f64_binding("pressure").unwrap();
            let e = job.f64_binding("energy").unwrap();
            assert!((VOLTAGE_RANGE.0..=VOLTAGE_RANGE.1).contains(&v));
            assert!((PRESSURE_RANGE.0..=PRESSURE_RANGE.1).contains(&p));
            assert!((ENERGY_RANGE.0..=ENERGY_RANGE.1).contains(&e));
        }
    }

    #[test]
    fn custom_sweep_sizes() {
        let src = ionization_plan(3, 2, 2);
        let plan = Plan::parse(&src).unwrap();
        assert_eq!(plan.job_count(), 12);
    }

    #[test]
    fn work_sampler_mean_and_determinism() {
        let cfg = WorkloadConfig {
            job_work_ref_h: 2.0,
            work_jitter_sigma: 0.25,
            ..Default::default()
        };
        let s = WorkSampler::new(&cfg, 7);
        // Deterministic per job.
        assert_eq!(s.work_ref_h(JobId(5)), s.work_ref_h(JobId(5)));
        // Jobs differ.
        assert_ne!(s.work_ref_h(JobId(5)), s.work_ref_h(JobId(6)));
        // Mean close to configured value.
        let n = 4000;
        let mean: f64 =
            (0..n).map(|i| s.work_ref_h(JobId(i))).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zero_sigma_is_deterministic_work() {
        let cfg = WorkloadConfig {
            job_work_ref_h: 1.5,
            work_jitter_sigma: 0.0,
            ..Default::default()
        };
        let s = WorkSampler::new(&cfg, 7);
        assert_eq!(s.work_ref_h(JobId(0)), 1.5);
        assert_eq!(s.work_ref_h(JobId(1)), 1.5);
    }
}
