//! Experiment configuration (what the client hands the parametric engine).

use crate::economy::market::{GraceConfig, MarketKind};
use crate::economy::reservation::ReservationConfig;
use crate::grid::competition::CompetitionModel;
use crate::types::{GridDollars, SimTime, HOUR};
use crate::util::json::Json;

/// Workload shape: how much compute and I/O one job costs. The Figure-3
/// ionization study uses the defaults; benches sweep them.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Mean per-job work in reference-machine CPU-hours.
    pub job_work_ref_h: f64,
    /// Log-normal sigma of per-job work jitter (0 = deterministic).
    pub work_jitter_sigma: f64,
    /// Stage-in bytes per job (inputs + executable).
    pub input_bytes: f64,
    /// Stage-out bytes per job (results).
    pub output_bytes: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        // Paper-scale: ~165 jobs × ~2 h on a ~70-machine testbed fills a
        // 10-20 h deadline window; inputs are config + binary, outputs a
        // modest results file.
        WorkloadConfig {
            job_work_ref_h: 2.0,
            work_jitter_sigma: 0.25,
            input_bytes: 2.0e6,
            output_bytes: 0.5e6,
        }
    }
}

/// One experiment run description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Grid identity the experiment runs as.
    pub user: String,
    /// Deadline, seconds from experiment start.
    pub deadline: SimTime,
    /// Budget in G$ (None = unconstrained).
    pub budget: Option<GridDollars>,
    /// Scheduling policy spec resolved through
    /// [`crate::broker::PolicyRegistry`]: a registered name, optionally
    /// with parameters (`"cost"`, `"cost?safety=0.9"`).
    pub policy: String,
    /// Scheduler tick period, seconds.
    pub tick_period_s: SimTime,
    /// Max dispatch attempts per job before it is marked failed.
    pub max_attempts: u32,
    /// UTC hour-of-day at experiment start (drives time-of-day pricing).
    pub start_utc_hour: f64,
    /// Master RNG seed for the run.
    pub seed: u64,
    pub workload: WorkloadConfig,
    /// Background competing-experiment process (paper §3: "the cost changes
    /// as other competing experiments are put on the grid"); None = the
    /// foreground experiment has the grid to itself.
    pub competition: Option<CompetitionModel>,
    /// Market mechanism the world prices resources through (paper §7).
    /// World-level like `competition`: in a multi-tenant world only
    /// tenant 0's setting is honoured.
    pub market: MarketKind,
    /// Advance-reservation subsystem (probe → reserve → commit).
    /// World-level like `market`; `None` (the default) disables it and the
    /// world replays bit-exactly like the pre-reservation pipeline.
    pub reservations: Option<ReservationConfig>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            user: "rajkumar".to_string(),
            deadline: 15.0 * HOUR,
            budget: None,
            policy: "cost".to_string(),
            tick_period_s: 120.0,
            max_attempts: 4,
            start_utc_hour: 22.0,
            seed: 0xD15EA5E,
            workload: WorkloadConfig::default(),
            competition: None,
            market: MarketKind::PostedPrice,
            reservations: None,
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("user", Json::str(&self.user)),
            ("deadline_s", Json::num(self.deadline)),
            (
                "budget",
                self.budget.map(Json::num).unwrap_or(Json::Null),
            ),
            ("policy", Json::str(&self.policy)),
            ("tick_period_s", Json::num(self.tick_period_s)),
            ("max_attempts", Json::num(self.max_attempts as f64)),
            ("start_utc_hour", Json::num(self.start_utc_hour)),
            ("seed", Json::num(self.seed as f64)),
            ("job_work_ref_h", Json::num(self.workload.job_work_ref_h)),
            (
                "work_jitter_sigma",
                Json::num(self.workload.work_jitter_sigma),
            ),
            ("input_bytes", Json::num(self.workload.input_bytes)),
            ("output_bytes", Json::num(self.workload.output_bytes)),
            (
                "competition",
                match &self.competition {
                    None => Json::Null,
                    Some(c) => Json::obj(vec![
                        ("mean_interarrival_s", Json::num(c.mean_interarrival_s)),
                        ("mean_duration_s", Json::num(c.mean_duration_s)),
                        ("mean_cpus", Json::num(c.mean_cpus)),
                    ]),
                },
            ),
            (
                "market",
                match &self.market {
                    MarketKind::PostedPrice => Json::Null,
                    MarketKind::GraceAuction(g) => Json::obj(vec![
                        ("max_rounds", Json::num(g.max_rounds as f64)),
                        ("escalation", Json::num(g.escalation)),
                        ("agreement_ttl_s", Json::num(g.agreement_ttl_s)),
                        (
                            "opening_rate_factor",
                            Json::num(g.opening_rate_factor),
                        ),
                        ("idle_discount", Json::num(g.idle_discount)),
                    ]),
                },
            ),
            (
                "reservations",
                match &self.reservations {
                    None => Json::Null,
                    Some(r) => Json::obj(vec![
                        ("commit_timeout_s", Json::num(r.commit_timeout_s)),
                        ("hold_s", Json::num(r.hold_s)),
                        ("cancel_penalty", Json::num(r.cancel_penalty)),
                        ("trigger_frac", Json::num(r.trigger_frac)),
                        ("probe_sets", Json::num(r.probe_sets as f64)),
                        ("max_slots", Json::num(r.max_slots as f64)),
                    ]),
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ExperimentConfig> {
        Ok(ExperimentConfig {
            user: v.req_str("user")?.to_string(),
            deadline: v.req_f64("deadline_s")?,
            budget: v.get("budget").as_f64(),
            policy: v.req_str("policy")?.to_string(),
            tick_period_s: v.req_f64("tick_period_s")?,
            max_attempts: v.req_f64("max_attempts")? as u32,
            start_utc_hour: v.req_f64("start_utc_hour")?,
            seed: v.req_f64("seed")? as u64,
            workload: WorkloadConfig {
                job_work_ref_h: v.req_f64("job_work_ref_h")?,
                work_jitter_sigma: v.req_f64("work_jitter_sigma")?,
                input_bytes: v.req_f64("input_bytes")?,
                output_bytes: v.req_f64("output_bytes")?,
            },
            competition: match v.get("competition") {
                Json::Null => None,
                c => Some(CompetitionModel {
                    mean_interarrival_s: c.req_f64("mean_interarrival_s")?,
                    mean_duration_s: c.req_f64("mean_duration_s")?,
                    mean_cpus: c.req_f64("mean_cpus")?,
                }),
            },
            // Absent/null (pre-market configs included) reads posted-price.
            market: match v.get("market") {
                Json::Null => MarketKind::PostedPrice,
                m => {
                    let cfg = GraceConfig {
                        max_rounds: m.req_f64("max_rounds")? as u32,
                        escalation: m.req_f64("escalation")?,
                        agreement_ttl_s: m.req_f64("agreement_ttl_s")?,
                        opening_rate_factor: m.req_f64("opening_rate_factor")?,
                        idle_discount: m.req_f64("idle_discount")?,
                    };
                    // Same guard the builder applies: a corrupted config
                    // must not load a market the builder would refuse.
                    cfg.validate()?;
                    MarketKind::GraceAuction(cfg)
                }
            },
            // Absent/null (pre-reservation configs included) reads off.
            reservations: match v.get("reservations") {
                Json::Null => None,
                r => {
                    let cfg = ReservationConfig {
                        commit_timeout_s: r.req_f64("commit_timeout_s")?,
                        hold_s: r.req_f64("hold_s")?,
                        cancel_penalty: r.req_f64("cancel_penalty")?,
                        trigger_frac: r.req_f64("trigger_frac")?,
                        probe_sets: r.req_f64("probe_sets")? as u32,
                        max_slots: r.req_f64("max_slots")? as u32,
                    };
                    // Same guard the builder applies: a corrupted config
                    // must not load a setup the builder would refuse.
                    cfg.validate()?;
                    Some(cfg)
                }
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ExperimentConfig::default();
        assert!(c.deadline > 0.0);
        assert!(c.tick_period_s > 0.0);
        assert!(c.max_attempts >= 1);
        assert!((0.0..24.0).contains(&c.start_utc_hour));
    }

    #[test]
    fn json_roundtrip() {
        let c = ExperimentConfig {
            budget: Some(5000.0),
            policy: "time".into(),
            ..Default::default()
        };
        let j = c.to_json().to_string();
        let back =
            ExperimentConfig::from_json(&crate::util::json::parse(&j).unwrap())
                .unwrap();
        assert_eq!(back.user, c.user);
        assert_eq!(back.budget, c.budget);
        assert_eq!(back.policy, "time");
        assert_eq!(back.seed, c.seed);
        assert!((back.workload.job_work_ref_h - c.workload.job_work_ref_h).abs() < 1e-12);
    }

    #[test]
    fn null_budget_roundtrips() {
        let c = ExperimentConfig::default();
        let j = c.to_json().to_string();
        let back =
            ExperimentConfig::from_json(&crate::util::json::parse(&j).unwrap())
                .unwrap();
        assert_eq!(back.budget, None);
        assert_eq!(back.market, MarketKind::PostedPrice);
        assert_eq!(back.reservations, None);
    }

    #[test]
    fn reservations_roundtrip() {
        let c = ExperimentConfig {
            reservations: Some(ReservationConfig {
                commit_timeout_s: 240.0,
                hold_s: 3600.0,
                cancel_penalty: 0.5,
                trigger_frac: 0.3,
                probe_sets: 4,
                max_slots: 6,
            }),
            ..Default::default()
        };
        let j = c.to_json().to_string();
        let back =
            ExperimentConfig::from_json(&crate::util::json::parse(&j).unwrap())
                .unwrap();
        assert_eq!(back.reservations, c.reservations);
        // Corrupted reservation tuning is rejected at load, like the
        // builder rejects it at construction.
        let bad = j.replace("\"cancel_penalty\":0.5", "\"cancel_penalty\":2");
        assert_ne!(bad, j, "replacement must hit the serialized penalty");
        assert!(ExperimentConfig::from_json(
            &crate::util::json::parse(&bad).unwrap()
        )
        .is_err());
    }

    #[test]
    fn grace_market_roundtrips() {
        let c = ExperimentConfig {
            market: MarketKind::GraceAuction(GraceConfig {
                max_rounds: 7,
                escalation: 1.25,
                agreement_ttl_s: 480.0,
                opening_rate_factor: 0.4,
                idle_discount: 0.3,
            }),
            ..Default::default()
        };
        let j = c.to_json().to_string();
        let back =
            ExperimentConfig::from_json(&crate::util::json::parse(&j).unwrap())
                .unwrap();
        assert_eq!(back.market, c.market);
        // Corrupted market tuning is rejected at load, like the builder
        // rejects it at construction.
        let bad = j.replace("\"agreement_ttl_s\":480", "\"agreement_ttl_s\":-1");
        assert_ne!(bad, j, "replacement must hit the serialized TTL");
        assert!(ExperimentConfig::from_json(
            &crate::util::json::parse(&bad).unwrap()
        )
        .is_err());
    }
}
