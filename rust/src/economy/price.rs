//! Owner-set resource pricing (paper §3).
//!
//! "The cost of resources can vary dynamically from time to time and the
//! resource owner will have the full control over deciding access cost.
//! Further, the cost can vary from one user to another."
//!
//! Prices are quoted in G$ per CPU-second on the priced machine. A
//! [`PriceModel`] composes:
//!
//! * a **base rate** — the owner's price for one CPU-second, off-peak;
//!   owners of faster machines typically (but not always) charge more;
//! * a **peak multiplier** applied during the owner's local business hours
//!   ("high @ daytime and low @ night");
//! * optional **per-user discounts** negotiated out of band;
//! * an optional **demand slope** — the owner reprices with utilization of
//!   the machine (tenant jobs + background claims), so "the cost changes as
//!   other competing experiments are put on the grid" holds when real
//!   co-scheduled brokers, not just the synthetic background process,
//!   contend for a resource. Disabled (slope 0) by default.

use crate::types::GridDollars;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Peak window in the owner's local time (hours).
pub const PEAK_START_H: f64 = 8.0;
pub const PEAK_END_H: f64 = 18.0;

/// A resource owner's pricing policy.
#[derive(Debug, Clone)]
pub struct PriceModel {
    /// G$ per CPU-second, off-peak, before discounts.
    pub base_rate: GridDollars,
    /// Multiplier during local business hours (1.0 = flat pricing).
    pub peak_multiplier: f64,
    /// Whether the owner uses time-of-day pricing at all.
    pub time_of_day: bool,
    /// Per-user rate multipliers (e.g. 0.8 = 20% discount).
    pub user_discounts: BTreeMap<String, f64>,
    /// Demand-responsive repricing slope: the quoted rate is multiplied by
    /// `1 + demand_slope × utilization` where utilization ∈ [0, 1] is the
    /// fraction of the machine's CPUs occupied (all tenants' in-flight jobs
    /// plus background competition claims). 0 disables demand pricing.
    pub demand_slope: f64,
}

impl PriceModel {
    /// Flat price, no peak, no discounts.
    pub fn flat(base_rate: GridDollars) -> PriceModel {
        PriceModel {
            base_rate,
            peak_multiplier: 1.0,
            time_of_day: false,
            user_discounts: BTreeMap::new(),
            demand_slope: 0.0,
        }
    }

    /// The generator used by the testbed builder: an owner prices a machine
    /// of relative `speed` with an idiosyncratic `margin`, an optional
    /// peak policy, and no standing discounts.
    pub fn owner_policy(
        speed: f64,
        margin: f64,
        peak_multiplier: f64,
        time_of_day: bool,
    ) -> PriceModel {
        PriceModel {
            // Faster machines cost more per second; the margin models owners
            // who under- or over-price relative to capability, which is what
            // gives the cost-optimizing scheduler something to exploit.
            base_rate: speed * margin,
            peak_multiplier,
            time_of_day,
            user_discounts: BTreeMap::new(),
            demand_slope: 0.0,
        }
    }

    /// Quoted G$ per CPU-second for `user` when the owner's local clock
    /// reads `local_hour` (0..24).
    pub fn rate_at(&self, local_hour: f64, user: &str) -> GridDollars {
        let mut rate = self.base_rate;
        if self.time_of_day && (PEAK_START_H..PEAK_END_H).contains(&local_hour) {
            rate *= self.peak_multiplier;
        }
        if let Some(d) = self.user_discounts.get(user) {
            rate *= d;
        }
        rate
    }

    /// True when the owner's peak window covers `local_hour`.
    pub fn is_peak(&self, local_hour: f64) -> bool {
        self.time_of_day && (PEAK_START_H..PEAK_END_H).contains(&local_hour)
    }

    /// Demand-responsive premium multiplier for the given machine
    /// `utilization` (fraction of CPUs occupied by tenants + competition):
    /// 1.0 when idle or when demand pricing is off, up to
    /// `1 + demand_slope` when fully occupied.
    pub fn demand_premium(&self, utilization: f64) -> f64 {
        if self.demand_slope <= 0.0 {
            return 1.0;
        }
        1.0 + self.demand_slope * utilization.clamp(0.0, 1.0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("base", Json::num(self.base_rate)),
            ("peak_mult", Json::num(self.peak_multiplier)),
            ("tod", Json::Bool(self.time_of_day)),
            ("demand_slope", Json::num(self.demand_slope)),
            (
                "discounts",
                Json::Obj(
                    self.user_discounts
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<PriceModel> {
        let mut user_discounts = BTreeMap::new();
        if let Some(m) = v.get("discounts").as_obj() {
            for (k, d) in m {
                user_discounts.insert(
                    k.clone(),
                    d.as_f64().ok_or_else(|| anyhow::anyhow!("bad discount"))?,
                );
            }
        }
        Ok(PriceModel {
            base_rate: v.req_f64("base")?,
            peak_multiplier: v.req_f64("peak_mult")?,
            time_of_day: v.get("tod").as_bool().unwrap_or(false),
            user_discounts,
            demand_slope: v.get("demand_slope").as_f64().unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_pricing_ignores_time() {
        let p = PriceModel::flat(2.0);
        assert_eq!(p.rate_at(3.0, "u"), 2.0);
        assert_eq!(p.rate_at(12.0, "u"), 2.0);
    }

    #[test]
    fn peak_hours_cost_more() {
        let p = PriceModel {
            base_rate: 1.0,
            peak_multiplier: 2.5,
            time_of_day: true,
            user_discounts: BTreeMap::new(),
            demand_slope: 0.0,
        };
        assert_eq!(p.rate_at(12.0, "u"), 2.5); // noon local = peak
        assert_eq!(p.rate_at(3.0, "u"), 1.0); // 3am local = off-peak
        assert_eq!(p.rate_at(7.99, "u"), 1.0);
        assert_eq!(p.rate_at(8.0, "u"), 2.5);
        assert_eq!(p.rate_at(18.0, "u"), 1.0); // end exclusive
        assert!(p.is_peak(9.0));
        assert!(!p.is_peak(20.0));
    }

    #[test]
    fn per_user_discounts() {
        let mut p = PriceModel::flat(4.0);
        p.user_discounts.insert("rajkumar".into(), 0.5);
        assert_eq!(p.rate_at(0.0, "rajkumar"), 2.0);
        assert_eq!(p.rate_at(0.0, "other"), 4.0);
    }

    #[test]
    fn discount_composes_with_peak() {
        let mut p = PriceModel {
            base_rate: 1.0,
            peak_multiplier: 3.0,
            time_of_day: true,
            user_discounts: BTreeMap::new(),
            demand_slope: 0.0,
        };
        p.user_discounts.insert("u".into(), 0.5);
        assert_eq!(p.rate_at(10.0, "u"), 1.5);
    }

    #[test]
    fn owner_policy_scales_with_speed() {
        let slow = PriceModel::owner_policy(0.5, 1.0, 2.0, false);
        let fast = PriceModel::owner_policy(2.0, 1.0, 2.0, false);
        assert!(fast.base_rate > slow.base_rate);
    }

    #[test]
    fn json_roundtrip() {
        let mut p = PriceModel::owner_policy(1.3, 0.9, 2.2, true);
        p.user_discounts.insert("davida".into(), 0.75);
        p.demand_slope = 0.6;
        let j = p.to_json().to_string();
        let back = PriceModel::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert!((back.base_rate - p.base_rate).abs() < 1e-12);
        assert_eq!(back.time_of_day, p.time_of_day);
        assert_eq!(back.user_discounts.get("davida"), Some(&0.75));
        assert!((back.demand_slope - 0.6).abs() < 1e-12);
    }

    #[test]
    fn demand_premium_rises_with_utilization_and_defaults_off() {
        // Slope 0 (the default everywhere): premium pinned at 1 so every
        // pre-demand-pricing trace replays unchanged.
        let flat = PriceModel::flat(2.0);
        assert_eq!(flat.demand_premium(0.0), 1.0);
        assert_eq!(flat.demand_premium(1.0), 1.0);
        let mut p = PriceModel::flat(2.0);
        p.demand_slope = 0.8;
        assert_eq!(p.demand_premium(0.0), 1.0);
        assert!((p.demand_premium(0.5) - 1.4).abs() < 1e-12);
        assert!((p.demand_premium(1.0) - 1.8).abs() < 1e-12);
        // Utilization is clamped into [0, 1].
        assert!((p.demand_premium(3.0) - 1.8).abs() < 1e-12);
        assert_eq!(p.demand_premium(-1.0), 1.0);
    }
}
