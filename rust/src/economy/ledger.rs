//! Experiment spend accounting.
//!
//! The scheduler works against a user budget ("price the user is willing to
//! pay", §3). To make "never exceed the budget" a checkable invariant, the
//! ledger is two-phase:
//!
//! 1. **commit** — when a job is dispatched, the *estimated* cost is
//!    committed. Dispatch is refused if `settled + committed + estimate`
//!    would exceed the budget.
//! 2. **settle** — on completion the commitment is replaced by the actual
//!    metered cost (actual may exceed the estimate — machines slow down —
//!    but the committed envelope keeps aggregate spend inside the budget up
//!    to estimation error on in-flight jobs).
//! 3. **release** — a failed/cancelled job releases its commitment; any
//!    partial CPU time already consumed is settled (grid owners bill for
//!    cycles used, finished or not).

use crate::types::{GridDollars, JobId};
use std::collections::BTreeMap;

/// Per-experiment spend ledger.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    budget: Option<GridDollars>,
    settled: GridDollars,
    committed: BTreeMap<JobId, GridDollars>,
    /// Cumulative settled cost per resource name (reporting).
    by_resource: BTreeMap<String, GridDollars>,
}

impl Ledger {
    pub fn new(budget: Option<GridDollars>) -> Ledger {
        Ledger {
            budget,
            ..Default::default()
        }
    }

    pub fn budget(&self) -> Option<GridDollars> {
        self.budget
    }

    /// Actually-incurred cost so far.
    pub fn settled(&self) -> GridDollars {
        self.settled
    }

    /// Outstanding commitments for in-flight jobs.
    pub fn committed(&self) -> GridDollars {
        self.committed.values().sum()
    }

    /// Settled + committed — the scheduler's planning figure.
    pub fn exposure(&self) -> GridDollars {
        self.settled + self.committed()
    }

    /// Budget remaining against exposure (`None` = unlimited), clamped at
    /// zero. Actual settled cost can exceed the committed estimate
    /// (machines slow down mid-run), pushing exposure past the budget; a
    /// negative headroom must read as "nothing left" — policy budget
    /// guards do arithmetic on this figure and a sign flip would corrupt
    /// per-job caps and projected-spend sheds.
    pub fn headroom(&self) -> Option<GridDollars> {
        self.budget.map(|b| (b - self.exposure()).max(0.0))
    }

    /// Try to commit `estimate` for `job`. Returns false (and commits
    /// nothing) if that would push exposure past the budget.
    pub fn commit(&mut self, job: JobId, estimate: GridDollars) -> bool {
        debug_assert!(estimate >= 0.0);
        debug_assert!(
            !self.committed.contains_key(&job),
            "double commit for {job}"
        );
        if let Some(b) = self.budget {
            if self.exposure() + estimate > b + 1e-9 {
                return false;
            }
        }
        self.committed.insert(job, estimate);
        true
    }

    /// Settle `job` at its actual metered cost, replacing the commitment.
    pub fn settle(&mut self, job: JobId, actual: GridDollars, resource: &str) {
        debug_assert!(actual >= 0.0);
        self.committed.remove(&job);
        self.settled += actual;
        *self.by_resource.entry(resource.to_string()).or_insert(0.0) += actual;
    }

    /// Release `job`'s commitment (failure/cancel), billing any partial use.
    pub fn release(&mut self, job: JobId, partial: GridDollars, resource: &str) {
        self.committed.remove(&job);
        if partial > 0.0 {
            self.settled += partial;
            *self.by_resource.entry(resource.to_string()).or_insert(0.0) +=
                partial;
        }
    }

    /// Per-resource settled totals (reporting).
    pub fn by_resource(&self) -> &BTreeMap<String, GridDollars> {
        &self.by_resource
    }

    /// Invariant check: per-resource totals sum to the settled figure.
    pub fn check_conservation(&self) -> bool {
        let sum: GridDollars = self.by_resource.values().sum();
        (sum - self.settled).abs() <= 1e-6 * self.settled.abs().max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_settle_flow() {
        let mut l = Ledger::new(Some(100.0));
        assert!(l.commit(JobId(0), 40.0));
        assert!(l.commit(JobId(1), 40.0));
        assert_eq!(l.exposure(), 80.0);
        // Third commit would exceed the budget.
        assert!(!l.commit(JobId(2), 40.0));
        assert_eq!(l.exposure(), 80.0);
        // Settle below estimate frees headroom.
        l.settle(JobId(0), 25.0, "lemon0.anl.gov");
        assert_eq!(l.settled(), 25.0);
        assert_eq!(l.exposure(), 65.0);
        assert!(l.commit(JobId(2), 30.0));
    }

    #[test]
    fn unlimited_budget_always_commits() {
        let mut l = Ledger::new(None);
        for i in 0..1000 {
            assert!(l.commit(JobId(i), 1e6));
        }
        assert_eq!(l.headroom(), None);
    }

    #[test]
    fn release_with_partial_billing() {
        let mut l = Ledger::new(Some(50.0));
        assert!(l.commit(JobId(0), 20.0));
        l.release(JobId(0), 5.0, "tuva1.isi.edu");
        assert_eq!(l.committed(), 0.0);
        assert_eq!(l.settled(), 5.0);
        assert!(l.check_conservation());
    }

    #[test]
    fn per_resource_accumulation() {
        let mut l = Ledger::new(None);
        l.commit(JobId(0), 1.0);
        l.commit(JobId(1), 1.0);
        l.commit(JobId(2), 1.0);
        l.settle(JobId(0), 3.0, "a");
        l.settle(JobId(1), 4.0, "a");
        l.settle(JobId(2), 5.0, "b");
        assert_eq!(l.by_resource()["a"], 7.0);
        assert_eq!(l.by_resource()["b"], 5.0);
        assert!(l.check_conservation());
    }

    #[test]
    fn headroom_tracks_exposure() {
        let mut l = Ledger::new(Some(10.0));
        assert_eq!(l.headroom(), Some(10.0));
        l.commit(JobId(0), 4.0);
        assert_eq!(l.headroom(), Some(6.0));
        l.settle(JobId(0), 6.0, "a"); // actual over estimate
        assert_eq!(l.headroom(), Some(4.0));
    }

    #[test]
    fn headroom_clamps_at_zero_when_actuals_overrun() {
        // Regression: a job settling above both its estimate and the whole
        // budget used to drive headroom negative, which flipped signs in
        // policy budget guards downstream. It must clamp at zero.
        let mut l = Ledger::new(Some(10.0));
        assert!(l.commit(JobId(0), 8.0));
        l.settle(JobId(0), 14.0, "a"); // machine slowed down mid-run
        assert_eq!(l.headroom(), Some(0.0));
        // And nothing further can be committed against the blown budget.
        assert!(!l.commit(JobId(1), 0.1));
        // Partial billing on a failure can overrun the same way.
        let mut l2 = Ledger::new(Some(5.0));
        assert!(l2.commit(JobId(0), 5.0));
        l2.release(JobId(0), 7.5, "b");
        assert_eq!(l2.headroom(), Some(0.0));
    }
}
