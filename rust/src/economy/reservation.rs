//! Advance reservations: the three-level commitment lifecycle (paper §7's
//! "secure resources ahead of need", following the VRM line of work).
//!
//! GRACE agreements (PR 4) fix *prices* but hold no *capacity*: a tenant
//! that won an auction can still find the machine full when its jobs
//! arrive. This module adds the missing mechanism, a per-tenant
//! [`ReservationStore`] that moves capacity through three commitment
//! levels:
//!
//! 1. **Probe** — a non-binding quote for slots on a resource, priced off
//!    the tenant's live [`ResourceView`]s (which already fold in demand
//!    premiums and any won agreement). Probes mutate nothing.
//! 2. **Reserve** — slots are *held*: they leave every other tenant's
//!    visible capacity and enter the slot-conservation invariant, but the
//!    hold is free to cancel and lapses on its own after a short commit
//!    timeout.
//! 3. **Commit** — the hold becomes binding for a longer window and a
//!    cancellation penalty (a fraction of the quoted cost of the still
//!    unused slots) is billed through the tenant's
//!    [`Ledger`](crate::economy::Ledger) if the tenant walks away or lets
//!    the hold expire. Jobs dispatched into a committed hold consume its
//!    slots one by one at the locked rate.
//!
//! Probing happens against a [`ShadowSchedule`]: a sandbox overlay of the
//! tenant's view table that can be tentatively reserved against to cost
//! out a what-if plan — several candidate resource sets can be priced and
//! compared without touching live state. The world's reserve-ahead move
//! ([`crate::sim::GridWorld`]) shadow-prices ≥ 2 candidate sets near the
//! deadline, really reserves the top plans, commits the cheapest feasible
//! one and cancels the rest while cancellation is still free.
//!
//! Every live transition (reserve / commit / cancel / expiry / slot
//! consumption) is the *world's* job to book: it updates the shared
//! `total_reserved` occupancy, dirties the touched resource's view *and*
//! candidate-index entry for every tenant (the standing rule), and journals
//! the transition for crash recovery. This module only owns the per-tenant
//! hold state and its accounting.

use crate::scheduler::ResourceView;
use crate::types::{GridDollars, ResourceId, SimTime};
use anyhow::ensure;
use std::collections::BTreeMap;

/// Tuning for the advance-reservation subsystem. World-level: in a
/// multi-tenant world only tenant 0's setting is honoured (reservations
/// hold shared grid capacity, like competition and the market). `None` in
/// the config means the subsystem is off and the world runs bit-exactly
/// like the pre-reservation pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ReservationConfig {
    /// Seconds an uncommitted hold survives before it lapses (free).
    pub commit_timeout_s: SimTime,
    /// Seconds a committed hold stays binding before unused slots expire
    /// (and the cancellation penalty on them falls due).
    pub hold_s: SimTime,
    /// Fraction of the quoted cost of *unused* committed slots billed on
    /// cancellation or expiry (0 = commitments are free to break,
    /// 1 = full quoted cost).
    pub cancel_penalty: f64,
    /// The reserve-ahead move arms once `now ≥ trigger_frac × deadline`
    /// and the tenant still has undispatched jobs.
    pub trigger_frac: f64,
    /// Candidate resource sets probed per reserve-ahead cycle (≥ 2, so
    /// "commit the cheapest" is a real choice).
    pub probe_sets: u32,
    /// Most slots one reserve-ahead cycle may hold.
    pub max_slots: u32,
}

impl Default for ReservationConfig {
    fn default() -> Self {
        ReservationConfig {
            commit_timeout_s: 300.0,
            hold_s: 2.0 * 3600.0,
            cancel_penalty: 0.25,
            trigger_frac: 0.4,
            probe_sets: 3,
            max_slots: 8,
        }
    }
}

impl ReservationConfig {
    /// Validate tuning values (builder construction guard).
    pub fn validate(&self) -> anyhow::Result<()> {
        ensure!(
            self.commit_timeout_s.is_finite() && self.commit_timeout_s > 0.0,
            "reservation commit timeout must be positive, got {} s",
            self.commit_timeout_s
        );
        ensure!(
            self.hold_s.is_finite() && self.hold_s > 0.0,
            "reservation hold must be positive, got {} s",
            self.hold_s
        );
        ensure!(
            (0.0..=1.0).contains(&self.cancel_penalty),
            "cancellation penalty must be in [0, 1], got {}",
            self.cancel_penalty
        );
        ensure!(
            self.trigger_frac.is_finite()
                && self.trigger_frac > 0.0
                && self.trigger_frac < 1.0,
            "reserve-ahead trigger must be in (0, 1), got {}",
            self.trigger_frac
        );
        ensure!(
            self.probe_sets >= 2,
            "reserve-ahead needs at least 2 candidate sets to compare, got {}",
            self.probe_sets
        );
        ensure!(
            self.max_slots >= 1,
            "a reservation cycle must be allowed at least one slot"
        );
        Ok(())
    }
}

/// How binding a hold currently is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitLevel {
    /// Held with free cancellation; lapses after the commit timeout.
    Reserved,
    /// Binding; cancellation/expiry of unused slots draws the penalty.
    Committed,
}

/// One live hold on one resource: `slots` CPUs at a locked `rate`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reservation {
    /// Slots still held (consumption decrements this).
    pub slots: u32,
    /// G$/CPU-second locked when the hold was taken.
    pub rate: GridDollars,
    /// Quoted cost of running one job on one held slot (penalty base).
    pub cost_per_slot: GridDollars,
    pub level: CommitLevel,
    /// Virtual time the hold lapses (exclusive, like
    /// [`crate::economy::PriceAgreement`]: a hold is already dead at
    /// exactly its expiry instant).
    pub expires: SimTime,
    /// Virtual time the hold was taken (held-slot-seconds accounting).
    pub opened_at: SimTime,
}

impl Reservation {
    /// Whether the hold still stands at `now`.
    pub fn active(&self, now: SimTime) -> bool {
        self.expires > now
    }
}

/// What a consumed slot hands the dispatch path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConsumedSlot {
    /// The locked rate the job will be billed at.
    pub rate: GridDollars,
    /// The consumption used the hold's last slot: the hold is gone and the
    /// caller must release its ledger envelope (no penalty — fully used).
    pub closed: bool,
}

/// Per-tenant hold table over the shared grid (index = `ResourceId`), plus
/// lifetime accounting for the world report. At most one hold per
/// (tenant, resource) — a second reserve on a held machine is refused.
#[derive(Debug, Clone)]
pub struct ReservationStore {
    holds: Vec<Option<Reservation>>,
    /// Earliest `expires` among live holds (∞ when none): the tick-time
    /// expiry sweep is O(1) until something is actually due.
    next_expiry: SimTime,
    /// Lifetime counters (world report / CSV).
    pub probes: u64,
    pub reserves: u32,
    pub commits: u32,
    pub cancels: u32,
    pub expiries: u32,
    pub consumed: u32,
    /// Σ over slots of (seconds between entering and leaving a hold).
    pub held_slot_seconds: f64,
    /// Cancellation penalties billed through the ledger.
    pub penalty_spend: GridDollars,
}

impl ReservationStore {
    pub fn new(n_resources: usize) -> ReservationStore {
        ReservationStore {
            holds: vec![None; n_resources],
            next_expiry: SimTime::INFINITY,
            probes: 0,
            reserves: 0,
            commits: 0,
            cancels: 0,
            expiries: 0,
            consumed: 0,
            held_slot_seconds: 0.0,
            penalty_spend: 0.0,
        }
    }

    pub fn get(&self, rid: ResourceId) -> Option<&Reservation> {
        self.holds.get(rid.0 as usize).and_then(|h| h.as_ref())
    }

    /// Slots this tenant holds on `rid` (0 without a hold).
    pub fn held_on(&self, rid: ResourceId) -> u32 {
        self.get(rid).map(|r| r.slots).unwrap_or(0)
    }

    /// Number of resources currently held.
    pub fn active_holds(&self) -> usize {
        self.holds.iter().flatten().count()
    }

    /// Take a hold: `slots` CPUs on `rid` at `rate`, lapsing at `expires`
    /// unless committed first. Refused (false) if the tenant already holds
    /// this resource or asks for zero slots.
    pub fn reserve(
        &mut self,
        rid: ResourceId,
        slots: u32,
        rate: GridDollars,
        cost_per_slot: GridDollars,
        now: SimTime,
        expires: SimTime,
    ) -> bool {
        let i = rid.0 as usize;
        if slots == 0 || i >= self.holds.len() || self.holds[i].is_some() {
            return false;
        }
        self.holds[i] = Some(Reservation {
            slots,
            rate,
            cost_per_slot,
            level: CommitLevel::Reserved,
            expires,
            opened_at: now,
        });
        self.next_expiry = self.next_expiry.min(expires);
        self.reserves += 1;
        true
    }

    /// Harden a `Reserved` hold into a binding commitment lapsing at
    /// `expires`. Refused (false) without an uncommitted live hold.
    pub fn commit(&mut self, rid: ResourceId, now: SimTime, expires: SimTime) -> bool {
        let i = rid.0 as usize;
        let Some(r) = self.holds.get_mut(i).and_then(|h| h.as_mut()) else {
            return false;
        };
        if r.level == CommitLevel::Committed || !r.active(now) {
            return false;
        }
        r.level = CommitLevel::Committed;
        r.expires = expires;
        self.next_expiry = self.next_expiry.min(expires);
        self.commits += 1;
        true
    }

    /// Drop a hold. Free while `Reserved`; the caller bills the penalty on
    /// the returned reservation if it was `Committed`.
    pub fn cancel(&mut self, rid: ResourceId, now: SimTime) -> Option<Reservation> {
        let i = rid.0 as usize;
        let r = self.holds.get_mut(i)?.take()?;
        self.held_slot_seconds += r.slots as f64 * (now - r.opened_at).max(0.0);
        self.cancels += 1;
        Some(r)
    }

    /// Dispatch a job into a committed hold: one slot leaves the hold at
    /// the locked rate. `None` without a live committed hold with slots.
    pub fn consume_slot(
        &mut self,
        rid: ResourceId,
        now: SimTime,
    ) -> Option<ConsumedSlot> {
        let i = rid.0 as usize;
        let slot = self.holds.get_mut(i)?;
        let r = slot.as_mut()?;
        if r.level != CommitLevel::Committed || !r.active(now) || r.slots == 0 {
            return None;
        }
        r.slots -= 1;
        self.held_slot_seconds += (now - r.opened_at).max(0.0);
        self.consumed += 1;
        let rate = r.rate;
        let closed = r.slots == 0;
        if closed {
            *slot = None;
        }
        Some(ConsumedSlot { rate, closed })
    }

    /// Lapse every hold whose expiry is at or before `now`, in ascending
    /// resource-index order. O(1) until an expiry is actually due, then
    /// O(resources) for that one sweep (the agreement-expiry pattern).
    /// Returns the lapsed holds for the caller to unbook and bill.
    pub fn expire_due(&mut self, now: SimTime) -> Vec<(ResourceId, Reservation)> {
        if now < self.next_expiry {
            return Vec::new();
        }
        let mut lapsed = Vec::new();
        let mut next = SimTime::INFINITY;
        for i in 0..self.holds.len() {
            let Some(r) = self.holds[i] else {
                continue;
            };
            if r.active(now) {
                next = next.min(r.expires);
            } else {
                self.holds[i] = None;
                self.held_slot_seconds +=
                    r.slots as f64 * (now - r.opened_at).max(0.0);
                self.expiries += 1;
                lapsed.push((ResourceId(i as u32), r));
            }
        }
        self.next_expiry = next;
        lapsed
    }
}

/// A non-binding probe quote for capacity on one resource, priced off the
/// tenant's live view (demand premiums and won agreements included).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeQuote {
    pub rid: ResourceId,
    /// Slots still free after earlier tentative holds in the same shadow.
    pub free: u32,
    pub rate: GridDollars,
    pub planning_speed: f64,
}

impl ProbeQuote {
    /// Quoted cost of running one job of `job_work_ref_h` reference hours
    /// on one slot here.
    pub fn cost_per_slot(&self, job_work_ref_h: f64) -> GridDollars {
        self.rate * job_work_ref_h * 3600.0 / self.planning_speed
    }
}

/// One priced what-if plan out of a shadow schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ShadowPlan {
    /// Granted holds: (resource, slots, locked rate, quoted cost/slot).
    pub holds: Vec<(ResourceId, u32, GridDollars, GridDollars)>,
    /// Total slots granted.
    pub slots: u32,
    /// Quoted cost of running one job on every granted slot.
    pub quoted_cost: GridDollars,
    /// Probes issued pricing this plan.
    pub probes: u32,
}

impl ShadowPlan {
    /// Mean quoted cost per granted slot — the comparator between plans of
    /// different sizes (∞ for an empty plan).
    pub fn cost_per_slot(&self) -> GridDollars {
        if self.slots == 0 {
            f64::INFINITY
        } else {
            self.quoted_cost / self.slots as f64
        }
    }
}

/// A sandbox overlay of one tenant's view table: probe quotes and
/// tentative holds against it cost out a candidate plan without mutating
/// any live state. Tentative holds only exist inside the shadow; nothing
/// is booked until the caller really reserves through the world.
pub struct ShadowSchedule<'a> {
    views: &'a [ResourceView],
    /// Tentatively held slots by resource index.
    overlay: BTreeMap<u32, u32>,
}

impl<'a> ShadowSchedule<'a> {
    pub fn new(views: &'a [ResourceView]) -> ShadowSchedule<'a> {
        ShadowSchedule {
            views,
            overlay: BTreeMap::new(),
        }
    }

    /// Non-binding quote for `rid` net of earlier tentative holds. `None`
    /// for machines the view says are unusable (down, unauthorized, zero
    /// speed) or already fully held in this shadow.
    pub fn probe(&self, rid: ResourceId) -> Option<ProbeQuote> {
        let v = self.views.get(rid.0 as usize)?;
        if v.planning_speed <= 0.0 {
            return None;
        }
        let held = self.overlay.get(&rid.0).copied().unwrap_or(0);
        let free = v.slots.saturating_sub(held);
        if free == 0 {
            return None;
        }
        Some(ProbeQuote {
            rid,
            free,
            rate: v.rate,
            planning_speed: v.planning_speed,
        })
    }

    /// Tentatively hold up to `want` slots on `rid` inside the shadow.
    /// Returns the slots actually granted (capped at the probe's `free`).
    pub fn tentative_reserve(&mut self, rid: ResourceId, want: u32) -> u32 {
        let Some(q) = self.probe(rid) else {
            return 0;
        };
        let granted = want.min(q.free);
        *self.overlay.entry(rid.0).or_insert(0) += granted;
        granted
    }

    /// Drop every tentative hold (start the next what-if from live state).
    pub fn reset(&mut self) {
        self.overlay.clear();
    }

    /// Price one candidate set: probe each member, grant slots to those
    /// that can turn a job of `job_work_ref_h` reference hours around
    /// inside `window_h` hours, and total the quoted cost. Resets the
    /// overlay first, so plans are independent what-ifs.
    pub fn plan(
        &mut self,
        set: &[(ResourceId, u32)],
        job_work_ref_h: f64,
        window_h: f64,
    ) -> ShadowPlan {
        self.reset();
        let mut plan = ShadowPlan {
            holds: Vec::new(),
            slots: 0,
            quoted_cost: 0.0,
            probes: 0,
        };
        for &(rid, want) in set {
            plan.probes += 1;
            let Some(q) = self.probe(rid) else {
                continue;
            };
            // One job must fit the guarded window — an infeasible member
            // contributes nothing to the plan.
            if job_work_ref_h / q.planning_speed > window_h {
                continue;
            }
            let granted = self.tentative_reserve(rid, want);
            if granted == 0 {
                continue;
            }
            let per_slot = q.cost_per_slot(job_work_ref_h);
            plan.holds.push((rid, granted, q.rate, per_slot));
            plan.slots += granted;
            plan.quoted_cost += per_slot * granted as f64;
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: u32, slots: u32, speed: f64, rate: f64) -> ResourceView {
        ResourceView {
            id: ResourceId(id),
            slots,
            planning_speed: speed,
            rate,
            in_flight: 0,
            measured_jphps: None,
            batch_queue: false,
        }
    }

    #[test]
    fn default_config_validates() {
        assert!(ReservationConfig::default().validate().is_ok());
    }

    #[test]
    fn validation_rejects_nonsense() {
        let ok = ReservationConfig::default();
        assert!(ReservationConfig {
            commit_timeout_s: 0.0,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(ReservationConfig {
            hold_s: f64::NAN,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(ReservationConfig {
            cancel_penalty: 1.1,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(ReservationConfig {
            trigger_frac: 1.0,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(ReservationConfig {
            probe_sets: 1,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(ReservationConfig { max_slots: 0, ..ok }.validate().is_err());
    }

    #[test]
    fn lifecycle_reserve_commit_consume() {
        let mut s = ReservationStore::new(4);
        assert!(s.reserve(ResourceId(1), 2, 0.5, 100.0, 10.0, 310.0));
        // A second hold on the same machine is refused.
        assert!(!s.reserve(ResourceId(1), 1, 0.5, 100.0, 10.0, 310.0));
        // Uncommitted holds cannot be consumed.
        assert!(s.consume_slot(ResourceId(1), 20.0).is_none());
        assert!(s.commit(ResourceId(1), 20.0, 7220.0));
        assert!(!s.commit(ResourceId(1), 20.0, 9000.0), "double commit");
        let c = s.consume_slot(ResourceId(1), 30.0).unwrap();
        assert_eq!(c.rate, 0.5);
        assert!(!c.closed);
        let c = s.consume_slot(ResourceId(1), 40.0).unwrap();
        assert!(c.closed, "last slot closes the hold");
        assert!(s.get(ResourceId(1)).is_none());
        assert_eq!(s.reserves, 1);
        assert_eq!(s.commits, 1);
        assert_eq!(s.consumed, 2);
        // Slot 1 held 10→30 s, slot 2 held 10→40 s.
        assert!((s.held_slot_seconds - 50.0).abs() < 1e-9);
    }

    #[test]
    fn expiry_is_exclusive_and_in_resource_order() {
        let mut s = ReservationStore::new(4);
        assert!(s.reserve(ResourceId(3), 1, 1.0, 10.0, 0.0, 100.0));
        assert!(s.reserve(ResourceId(0), 2, 1.0, 10.0, 0.0, 100.0));
        assert!(s.expire_due(99.9).is_empty(), "O(1) before anything is due");
        let lapsed = s.expire_due(100.0);
        assert_eq!(
            lapsed.iter().map(|(r, _)| r.0).collect::<Vec<_>>(),
            vec![0, 3],
            "sweep order is ascending resource index"
        );
        assert_eq!(s.expiries, 2);
        assert_eq!(s.active_holds(), 0);
        assert!((s.held_slot_seconds - 300.0).abs() < 1e-9);
    }

    #[test]
    fn cancel_is_free_while_reserved() {
        let mut s = ReservationStore::new(2);
        assert!(s.reserve(ResourceId(0), 3, 1.0, 10.0, 5.0, 305.0));
        let r = s.cancel(ResourceId(0), 6.0).unwrap();
        assert_eq!(r.level, CommitLevel::Reserved);
        assert_eq!(s.cancels, 1);
        assert!(s.cancel(ResourceId(0), 6.0).is_none());
    }

    #[test]
    fn shadow_overlays_do_not_touch_live_views() {
        let views = vec![view(0, 4, 1.0, 0.2), view(1, 0, 1.0, 0.1), view(2, 8, 0.0, 0.1)];
        let mut shadow = ShadowSchedule::new(&views);
        // Down/full machines cannot be probed.
        assert!(shadow.probe(ResourceId(1)).is_none());
        assert!(shadow.probe(ResourceId(2)).is_none());
        let q = shadow.probe(ResourceId(0)).unwrap();
        assert_eq!(q.free, 4);
        assert_eq!(shadow.tentative_reserve(ResourceId(0), 3), 3);
        assert_eq!(shadow.probe(ResourceId(0)).unwrap().free, 1);
        assert_eq!(shadow.tentative_reserve(ResourceId(0), 3), 1, "capped");
        assert!(shadow.probe(ResourceId(0)).is_none(), "fully held");
        // The live table never moved.
        assert_eq!(views[0].slots, 4);
    }

    #[test]
    fn shadow_plans_price_and_reset_independently() {
        let views = vec![view(0, 2, 1.0, 0.2), view(1, 4, 2.0, 0.3)];
        let mut shadow = ShadowSchedule::new(&views);
        // 1 ref-h job: machine 0 costs 0.2·3600 = 720/slot, machine 1
        // costs 0.3·3600/2 = 540/slot.
        let a = shadow.plan(&[(ResourceId(0), 2), (ResourceId(1), 1)], 1.0, 10.0);
        assert_eq!(a.slots, 3);
        assert_eq!(a.probes, 2);
        assert!((a.quoted_cost - (2.0 * 720.0 + 540.0)).abs() < 1e-9);
        // The next plan starts from live state again.
        let b = shadow.plan(&[(ResourceId(1), 4)], 1.0, 10.0);
        assert_eq!(b.slots, 4);
        assert!(b.cost_per_slot() < a.cost_per_slot());
        // A member too slow for the window contributes nothing.
        let c = shadow.plan(&[(ResourceId(0), 2)], 20.0, 10.0);
        assert_eq!(c.slots, 0);
        assert!(c.cost_per_slot().is_infinite());
    }
}
