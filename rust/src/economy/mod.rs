//! The computational economy (paper §3).
//!
//! * [`price`] — owner-set resource pricing: base rate scaled by machine
//!   speed, peak/off-peak time-of-day multipliers in the *owner's* timezone,
//!   and per-user discounts ("cost can vary from one user to another").
//! * [`ledger`] — double-entry accounting of experiment spend: funds are
//!   *committed* when a job is dispatched (so the scheduler can never
//!   over-commit a budget) and *settled* to actual CPU-time cost when the
//!   job completes.
//! * [`grace`] — the GRACE trading layer sketched in §7 (future work in the
//!   paper, implemented here as the extension feature): broker posts
//!   tenders, per-owner bid-servers answer with priced offers, and the
//!   bid-manager runs a deadline-aware selection over the offers.

pub mod grace;
pub mod ledger;
pub mod price;

pub use ledger::Ledger;
pub use price::PriceModel;
