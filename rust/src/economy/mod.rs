//! The computational economy (paper §3, §7).
//!
//! * [`price`] — owner-set resource pricing: base rate scaled by machine
//!   speed, peak/off-peak time-of-day multipliers in the *owner's* timezone,
//!   per-user discounts ("cost can vary from one user to another"), and an
//!   optional demand slope that reprices with real machine utilization.
//! * [`ledger`] — double-entry accounting of experiment spend: funds are
//!   *committed* when a job is dispatched (so the scheduler can never
//!   over-commit a budget) and *settled* to actual CPU-time cost when the
//!   job completes.
//! * [`market`] — the pluggable market layer: a world prices resources
//!   either by posted rates (the default, [`market::MarketKind::PostedPrice`])
//!   or through periodic GRACE tender/bid auctions
//!   ([`market::MarketKind::GraceAuction`]) whose awards become
//!   time-limited per-(tenant, resource) [`market::PriceAgreement`]s.
//! * [`grace`] — the GRACE trading layer sketched in §7: broker posts
//!   tenders, per-owner bid-servers answer with priced offers, and the
//!   bid-manager runs a deterministic deadline-aware selection over the
//!   offers, with capped concession rounds. [`crate::sim::GridWorld`] runs
//!   this negotiation at every directory refresh when the market is
//!   `GraceAuction`, deriving each tenant's tender from its live DBC state.
//! * [`reservation`] — advance reservations with the three-level
//!   commitment lifecycle: non-binding **probe** quotes priced off live
//!   views, a **reserve** step that holds slots with a commit timeout and
//!   free cancellation, and a binding **commit** whose cancellation
//!   penalty is billed through the [`Ledger`]. Candidate plans are costed
//!   against a [`reservation::ShadowSchedule`] — a sandbox overlay of the
//!   tenant's view table — before anything is booked for real.

pub mod grace;
pub mod ledger;
pub mod market;
pub mod price;
pub mod reservation;

pub use ledger::Ledger;
pub use market::{GraceConfig, MarketKind, PriceAgreement};
pub use price::PriceModel;
pub use reservation::{ReservationConfig, ReservationStore, ShadowSchedule};
