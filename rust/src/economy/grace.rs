//! GRACE — Grid Architecture for Computational Economy (paper §7).
//!
//! The paper sketches GRACE as future work: a broker, bid-manager,
//! directory server and per-owner bid-servers that let the user "enter into
//! bidding and negotiate for the best possible resources". This module
//! implements that layer over the simulated testbed:
//!
//! * the **broker** posts a [`Tender`] describing the work (jobs, work per
//!   job, deadline, reservation rate);
//! * each owner's [`BidServer`] answers with a [`Bid`] priced by its
//!   strategy (idle machines discount, busy machines charge a premium,
//!   premium owners never discount);
//! * the **bid-manager** ([`select_bids`]) picks the cheapest bid set whose
//!   aggregate rate meets the deadline;
//! * [`Broker::negotiate`] runs tender → bids → select rounds, raising the
//!   reservation rate between rounds if no feasible set exists — the
//!   "renegotiate either by changing the deadline and/or the cost" loop of
//!   §3, with the answer known *before* the experiment starts.

use crate::types::{GridDollars, ResourceId, SimTime};

/// A broker's call for offers.
#[derive(Debug, Clone)]
pub struct Tender {
    pub user: String,
    /// Number of jobs to place.
    pub jobs: u32,
    /// CPU-hours per job on the reference machine.
    pub job_work_ref_h: f64,
    /// Seconds from now in which all jobs must finish.
    pub time_to_deadline_s: f64,
    /// Reservation rate: maximum acceptable G$/CPU-second. Bids above this
    /// are rejected in the current round.
    pub max_rate: GridDollars,
}

/// One owner's offer against a tender.
#[derive(Debug, Clone)]
pub struct Bid {
    pub resource: ResourceId,
    pub resource_name: String,
    /// Offered price, G$/CPU-second.
    pub rate: GridDollars,
    /// Concurrent job slots offered.
    pub capacity: u32,
    /// Relative speed of the offering machine (jobs of work w take
    /// `w / speed` reference-hours each).
    pub speed: f64,
    /// Offer expiry (virtual time).
    pub valid_until: SimTime,
}

impl Bid {
    /// Jobs/hour this bid completes at full committed capacity.
    pub fn throughput_jobs_per_h(&self, job_work_ref_h: f64) -> f64 {
        self.capacity as f64 * self.speed / job_work_ref_h
    }

    /// G$ to run one job under this bid.
    pub fn cost_per_job(&self, job_work_ref_h: f64) -> GridDollars {
        // CPU-seconds consumed on this machine = work / speed * 3600.
        self.rate * job_work_ref_h / self.speed * 3600.0
    }
}

/// Owner bidding temperament.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BidStrategy {
    /// Fills idle cycles: discounts up to 40% when lightly loaded.
    Aggressive,
    /// Posts the list price regardless of load.
    ListPrice,
    /// Charges a scarcity premium as the machine fills.
    Premium,
}

/// A per-owner bid server: quotes offers for this resource.
#[derive(Debug, Clone)]
pub struct BidServer {
    pub resource: ResourceId,
    pub resource_name: String,
    pub speed: f64,
    pub cpus: u32,
    /// Posted G$/CPU-second at quote time (already time-of-day adjusted).
    pub posted_rate: GridDollars,
    /// Fraction of CPUs currently busy (0..1).
    pub utilization: f64,
    pub strategy: BidStrategy,
}

impl BidServer {
    /// Produce an offer, or `None` if the tender is not worth bidding on
    /// (reservation rate below what this owner would ever accept, or no
    /// spare capacity).
    pub fn quote(&self, tender: &Tender, now: SimTime) -> Option<Bid> {
        let free = ((1.0 - self.utilization) * self.cpus as f64).floor() as u32;
        if free == 0 {
            return None;
        }
        let rate = match self.strategy {
            BidStrategy::Aggressive => {
                // Idle machines shave the price to win work.
                self.posted_rate * (0.6 + 0.4 * self.utilization)
            }
            BidStrategy::ListPrice => self.posted_rate,
            BidStrategy::Premium => self.posted_rate * (1.0 + self.utilization),
        };
        if rate > tender.max_rate {
            return None;
        }
        Some(Bid {
            resource: self.resource,
            resource_name: self.resource_name.clone(),
            rate,
            capacity: free.min(tender.jobs),
            speed: self.speed,
            valid_until: now + 600.0,
        })
    }
}

/// Bid-manager selection: cheapest-per-job-first subset whose aggregate
/// throughput meets the deadline. Returns `None` when even all bids together
/// cannot finish in time.
pub fn select_bids(tender: &Tender, bids: &[Bid]) -> Option<Vec<Bid>> {
    let needed_jobs_per_h =
        tender.jobs as f64 / (tender.time_to_deadline_s / 3600.0);
    let mut sorted: Vec<&Bid> = bids.iter().collect();
    sorted.sort_by(|a, b| {
        a.cost_per_job(tender.job_work_ref_h)
            .total_cmp(&b.cost_per_job(tender.job_work_ref_h))
    });
    let mut chosen = Vec::new();
    let mut rate = 0.0;
    for bid in sorted {
        if rate >= needed_jobs_per_h {
            break;
        }
        rate += bid.throughput_jobs_per_h(tender.job_work_ref_h);
        chosen.push(bid.clone());
    }
    if rate >= needed_jobs_per_h {
        Some(chosen)
    } else {
        None
    }
}

/// Outcome of a negotiation.
#[derive(Debug, Clone)]
pub struct NegotiationOutcome {
    pub selected: Vec<Bid>,
    /// Tender rounds used (1 = first call succeeded).
    pub rounds: u32,
    /// Final reservation rate that produced a feasible set.
    pub final_max_rate: GridDollars,
    /// Estimated total cost of the experiment under the selected bids.
    pub est_total_cost: GridDollars,
}

/// The GRACE broker: runs up to `max_rounds` tender rounds, escalating the
/// reservation rate by `escalation` per round until a feasible bid set
/// appears. Mirrors the §3 contract negotiation: the user learns up front
/// whether the deadline is attainable and at what price.
pub struct Broker {
    pub max_rounds: u32,
    pub escalation: f64,
}

impl Default for Broker {
    fn default() -> Self {
        Broker {
            max_rounds: 5,
            escalation: 1.5,
        }
    }
}

impl Broker {
    pub fn negotiate(
        &self,
        mut tender: Tender,
        servers: &[BidServer],
        now: SimTime,
    ) -> Option<NegotiationOutcome> {
        for round in 1..=self.max_rounds {
            let bids: Vec<Bid> =
                servers.iter().filter_map(|s| s.quote(&tender, now)).collect();
            if let Some(selected) = select_bids(&tender, &bids) {
                // Cost estimate: spread jobs over the selected set
                // proportionally to throughput.
                let total_rate: f64 = selected
                    .iter()
                    .map(|b| b.throughput_jobs_per_h(tender.job_work_ref_h))
                    .sum();
                let est_total_cost = selected
                    .iter()
                    .map(|b| {
                        let share = b.throughput_jobs_per_h(tender.job_work_ref_h)
                            / total_rate;
                        share * tender.jobs as f64
                            * b.cost_per_job(tender.job_work_ref_h)
                    })
                    .sum();
                return Some(NegotiationOutcome {
                    selected,
                    rounds: round,
                    final_max_rate: tender.max_rate,
                    est_total_cost,
                });
            }
            tender.max_rate *= self.escalation;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(
        id: u32,
        rate: f64,
        cpus: u32,
        util: f64,
        strategy: BidStrategy,
    ) -> BidServer {
        BidServer {
            resource: ResourceId(id),
            resource_name: format!("r{id}"),
            speed: 1.0,
            cpus,
            posted_rate: rate,
            utilization: util,
            strategy,
        }
    }

    fn tender(jobs: u32, hours: f64, max_rate: f64) -> Tender {
        Tender {
            user: "rajkumar".into(),
            jobs,
            job_work_ref_h: 1.0,
            time_to_deadline_s: hours * 3600.0,
            max_rate,
        }
    }

    #[test]
    fn aggressive_idle_discounts() {
        let s = server(0, 1.0, 4, 0.0, BidStrategy::Aggressive);
        let bid = s.quote(&tender(10, 10.0, 5.0), 0.0).unwrap();
        assert!((bid.rate - 0.6).abs() < 1e-9);
    }

    #[test]
    fn premium_busy_charges_more() {
        let s = server(0, 1.0, 8, 0.5, BidStrategy::Premium);
        let bid = s.quote(&tender(10, 10.0, 5.0), 0.0).unwrap();
        assert!((bid.rate - 1.5).abs() < 1e-9);
        assert_eq!(bid.capacity, 4); // half the cpus are busy
    }

    #[test]
    fn no_bid_above_reservation_rate() {
        let s = server(0, 10.0, 4, 0.0, BidStrategy::ListPrice);
        assert!(s.quote(&tender(10, 10.0, 5.0), 0.0).is_none());
    }

    #[test]
    fn saturated_machine_does_not_bid() {
        let s = server(0, 1.0, 4, 1.0, BidStrategy::Aggressive);
        assert!(s.quote(&tender(10, 10.0, 5.0), 0.0).is_none());
    }

    #[test]
    fn selection_prefers_cheap_bids() {
        let t = tender(16, 4.0, 100.0); // need 4 jobs/h
        let bids = vec![
            Bid {
                resource: ResourceId(0),
                resource_name: "cheap".into(),
                rate: 0.5,
                capacity: 4,
                speed: 1.0,
                valid_until: 600.0,
            },
            Bid {
                resource: ResourceId(1),
                resource_name: "dear".into(),
                rate: 5.0,
                capacity: 16,
                speed: 1.0,
                valid_until: 600.0,
            },
        ];
        let sel = select_bids(&t, &bids).unwrap();
        assert_eq!(sel[0].resource_name, "cheap");
        // The cheap bid alone gives 4 jobs/h — exactly enough.
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn selection_fails_when_infeasible() {
        let t = tender(1000, 1.0, 100.0); // need 1000 jobs/h
        let bids = vec![Bid {
            resource: ResourceId(0),
            resource_name: "small".into(),
            rate: 0.1,
            capacity: 2,
            speed: 1.0,
            valid_until: 600.0,
        }];
        assert!(select_bids(&t, &bids).is_none());
    }

    #[test]
    fn broker_escalates_until_feasible() {
        // Owner prices at 2.0; tender starts at 0.5 ⇒ needs 2 escalations
        // of 1.5x (0.5 → 0.75 → 1.125 → 1.6875... wait for >= 2.0 needs 3).
        let servers = vec![server(0, 2.0, 64, 0.0, BidStrategy::ListPrice)];
        let broker = Broker::default();
        let out = broker
            .negotiate(tender(10, 10.0, 0.5), &servers, 0.0)
            .unwrap();
        assert!(out.rounds > 1, "should need escalation, rounds={}", out.rounds);
        assert!(out.final_max_rate >= 2.0);
        assert_eq!(out.selected.len(), 1);
        assert!(out.est_total_cost > 0.0);
    }

    #[test]
    fn broker_gives_up_after_max_rounds() {
        let servers = vec![server(0, 1e9, 64, 0.0, BidStrategy::ListPrice)];
        let broker = Broker {
            max_rounds: 3,
            escalation: 1.1,
        };
        assert!(broker.negotiate(tender(10, 10.0, 0.01), &servers, 0.0).is_none());
    }

    #[test]
    fn cost_per_job_accounts_for_speed() {
        let bid = Bid {
            resource: ResourceId(0),
            resource_name: "fast".into(),
            rate: 1.0,
            capacity: 1,
            speed: 2.0,
            valid_until: 0.0,
        };
        // 1 ref-hour of work at speed 2 = 1800 cpu-seconds = 1800 G$.
        assert!((bid.cost_per_job(1.0) - 1800.0).abs() < 1e-9);
    }
}
