//! GRACE — Grid Architecture for Computational Economy (paper §7).
//!
//! The paper sketches GRACE as future work: a broker, bid-manager,
//! directory server and per-owner bid-servers that let the user "enter into
//! bidding and negotiate for the best possible resources". This module
//! implements that layer over the simulated testbed:
//!
//! * the **broker** posts a [`Tender`] describing the work (jobs, work per
//!   job, deadline, reservation rate, and an optional budget-derived hard
//!   cap on how far the reservation may concede);
//! * each owner's [`BidServer`] answers with a [`Bid`] priced by its
//!   strategy (idle machines discount, busy machines charge a premium,
//!   demand-responsive owners compose both through their
//!   [`crate::economy::PriceModel`] demand slope);
//! * the **bid-manager** ([`select_bids`]) picks the cheapest bid set whose
//!   aggregate rate meets the deadline, deterministically — ties break by
//!   (cost, rate, resource id), never input order;
//! * [`Broker::negotiate`] runs tender → bids → select rounds, raising the
//!   reservation rate between rounds — the "renegotiate either by changing
//!   the deadline and/or the cost" loop of §3. Concessions are capped by
//!   both the round limit and [`Tender::hard_rate_cap`], and a failed
//!   negotiation returns the final rejected tender so callers can report
//!   *why* the market said no.
//!
//! [`crate::sim::GridWorld`] runs this negotiation as a periodic auction
//! when the world's [`crate::economy::market::MarketKind`] is
//! `GraceAuction` — see [`crate::economy::market`] for the wiring.

use crate::types::{GridDollars, ResourceId};

/// A broker's call for offers.
#[derive(Debug, Clone)]
pub struct Tender {
    pub user: String,
    /// Number of jobs to place.
    pub jobs: u32,
    /// CPU-hours per job on the reference machine.
    pub job_work_ref_h: f64,
    /// Seconds from now in which all jobs must finish.
    pub time_to_deadline_s: f64,
    /// Reservation rate: maximum acceptable G$/CPU-second. Bids above this
    /// are rejected in the current round.
    pub max_rate: GridDollars,
    /// Absolute ceiling on concession: renegotiation rounds never raise
    /// `max_rate` past this (typically a budget-derived affordability cap).
    /// `None` leaves escalation bounded only by the round limit.
    pub hard_rate_cap: Option<GridDollars>,
}

/// One owner's offer against a tender. Carries only the [`ResourceId`] —
/// display names resolve at the presentation edge (the negotiation path
/// runs per tenant at every directory refresh, so the offer structs stay
/// allocation-free). An offer binds for the synchronous negotiation that
/// solicited it; the *award's* lifetime is the market's agreement TTL
/// ([`crate::economy::market::GraceConfig::agreement_ttl_s`]).
#[derive(Debug, Clone)]
pub struct Bid {
    pub resource: ResourceId,
    /// Offered price, G$/CPU-second.
    pub rate: GridDollars,
    /// Concurrent job slots offered.
    pub capacity: u32,
    /// Relative speed of the offering machine (jobs of work w take
    /// `w / speed` reference-hours each).
    pub speed: f64,
}

impl Bid {
    /// Jobs/hour this bid completes at full committed capacity.
    pub fn throughput_jobs_per_h(&self, job_work_ref_h: f64) -> f64 {
        self.capacity as f64 * self.speed / job_work_ref_h
    }

    /// G$ to run one job under this bid.
    pub fn cost_per_job(&self, job_work_ref_h: f64) -> GridDollars {
        // CPU-seconds consumed on this machine = work / speed * 3600.
        self.rate * job_work_ref_h / self.speed * 3600.0
    }
}

/// Owner bidding temperament.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BidStrategy {
    /// Fills idle cycles: discounts up to 40% when lightly loaded.
    Aggressive,
    /// Posts the list price regardless of load.
    ListPrice,
    /// Charges a scarcity premium as the machine fills.
    Premium,
    /// Demand-responsive owner (the live-market strategy §3 + §7 compose):
    /// discounts idle cycles by up to `idle_discount`, and charges the
    /// [`crate::economy::PriceModel`] demand premium (`1 + slope × util`)
    /// as the machine fills — so auction offers move on the same real
    /// utilization signal posted-price quotes do.
    Demand { slope: f64, idle_discount: f64 },
}

/// A per-owner bid server: quotes offers for this resource.
#[derive(Debug, Clone)]
pub struct BidServer {
    pub resource: ResourceId,
    pub speed: f64,
    /// Concurrent job slots this owner can actually offer — already net of
    /// every occupancy source (all tenants' in-flight jobs plus background
    /// competition claims; drivers compute this with the one shared
    /// [`crate::grid::competition::visible_slots`] formula).
    pub free_slots: u32,
    /// Posted G$/CPU-second at quote time (time-of-day and per-user
    /// adjusted, before the bidding strategy moves it).
    pub posted_rate: GridDollars,
    /// Fraction of the machine occupied (0..1) — the demand signal the
    /// strategy prices on.
    pub utilization: f64,
    pub strategy: BidStrategy,
}

impl BidServer {
    /// Produce an offer, or `None` if the tender is not worth bidding on
    /// (reservation rate below what this owner would ever accept, or no
    /// spare capacity).
    pub fn quote(&self, tender: &Tender) -> Option<Bid> {
        if self.free_slots == 0 {
            return None;
        }
        let util = self.utilization.clamp(0.0, 1.0);
        let rate = match self.strategy {
            BidStrategy::Aggressive => {
                // Idle machines shave the price to win work.
                self.posted_rate * (0.6 + 0.4 * util)
            }
            BidStrategy::ListPrice => self.posted_rate,
            BidStrategy::Premium => self.posted_rate * (1.0 + util),
            BidStrategy::Demand {
                slope,
                idle_discount,
            } => {
                self.posted_rate
                    * (1.0 - idle_discount * (1.0 - util))
                    * (1.0 + slope.max(0.0) * util)
            }
        };
        if rate > tender.max_rate {
            return None;
        }
        Some(Bid {
            resource: self.resource,
            rate,
            capacity: self.free_slots.min(tender.jobs),
            speed: self.speed,
        })
    }
}

/// Bid-manager selection: cheapest-per-job-first subset whose aggregate
/// throughput meets the deadline. Returns `None` when even all bids together
/// cannot finish in time. A zero-job tender is trivially satisfiable: it
/// selects nothing and succeeds.
pub fn select_bids(tender: &Tender, bids: &[Bid]) -> Option<Vec<Bid>> {
    let needed_jobs_per_h =
        tender.jobs as f64 / (tender.time_to_deadline_s / 3600.0);
    let mut sorted: Vec<&Bid> = bids.iter().collect();
    // Deterministic order: cheapest per job first, ties broken by offered
    // rate and then resource id — never input order, so grids full of
    // identically-priced machines replay the same selection whatever order
    // the quotes arrived in.
    sorted.sort_by(|a, b| {
        a.cost_per_job(tender.job_work_ref_h)
            .total_cmp(&b.cost_per_job(tender.job_work_ref_h))
            .then(a.rate.total_cmp(&b.rate))
            .then(a.resource.0.cmp(&b.resource.0))
    });
    let mut chosen = Vec::new();
    let mut rate = 0.0;
    for bid in sorted {
        if rate >= needed_jobs_per_h {
            break;
        }
        rate += bid.throughput_jobs_per_h(tender.job_work_ref_h);
        chosen.push(bid.clone());
    }
    if rate >= needed_jobs_per_h {
        Some(chosen)
    } else {
        None
    }
}

/// Outcome of a negotiation. Always returned — a failed negotiation is an
/// outcome too, carrying the final rejected tender instead of a bid set.
#[derive(Debug, Clone)]
pub struct NegotiationOutcome {
    /// The winning bid set (empty when no deal was reached).
    pub selected: Vec<Bid>,
    /// Tender rounds used (1 = first call succeeded).
    pub rounds: u32,
    /// Final reservation rate offered (the feasible rate on a deal; the
    /// highest rejected rate otherwise).
    pub final_max_rate: GridDollars,
    /// Estimated total cost of the experiment under the selected bids.
    pub est_total_cost: GridDollars,
    /// `None` on a deal; on failure, the final escalated tender the market
    /// still rejected — the best offer the broker made, so callers can
    /// report exactly what was refused and at what price.
    pub best_rejected: Option<Tender>,
}

impl NegotiationOutcome {
    /// True when negotiation produced a feasible bid set.
    pub fn is_deal(&self) -> bool {
        self.best_rejected.is_none()
    }
}

/// The GRACE broker: runs up to `max_rounds` tender rounds, escalating the
/// reservation rate by `escalation` per round until a feasible bid set
/// appears. Mirrors the §3 contract negotiation: the user learns up front
/// whether the deadline is attainable and at what price.
pub struct Broker {
    pub max_rounds: u32,
    pub escalation: f64,
}

impl Default for Broker {
    fn default() -> Self {
        Broker {
            max_rounds: 5,
            escalation: 1.5,
        }
    }
}

impl Broker {
    /// Run tender → bids → select rounds. Concessions are capped twice
    /// over: at most `max_rounds` rounds, and the reservation rate never
    /// rises past [`Tender::hard_rate_cap`] — once the rate can no longer
    /// move, remaining rounds would be identical, so the loop stops early.
    pub fn negotiate(
        &self,
        mut tender: Tender,
        servers: &[BidServer],
    ) -> NegotiationOutcome {
        let max_rounds = self.max_rounds.max(1);
        let mut rounds = 0;
        for round in 1..=max_rounds {
            rounds = round;
            let bids: Vec<Bid> =
                servers.iter().filter_map(|s| s.quote(&tender)).collect();
            if let Some(selected) = select_bids(&tender, &bids) {
                // Cost estimate: spread jobs over the selected set
                // proportionally to throughput.
                let total_rate: f64 = selected
                    .iter()
                    .map(|b| b.throughput_jobs_per_h(tender.job_work_ref_h))
                    .sum();
                let est_total_cost = if total_rate > 0.0 {
                    selected
                        .iter()
                        .map(|b| {
                            let share = b
                                .throughput_jobs_per_h(tender.job_work_ref_h)
                                / total_rate;
                            share
                                * tender.jobs as f64
                                * b.cost_per_job(tender.job_work_ref_h)
                        })
                        .sum()
                } else {
                    0.0
                };
                return NegotiationOutcome {
                    selected,
                    rounds,
                    final_max_rate: tender.max_rate,
                    est_total_cost,
                    best_rejected: None,
                };
            }
            if round == max_rounds {
                // Out of rounds: leave the tender at the rate that was
                // actually quoted and refused, not one escalation past it.
                break;
            }
            // Concede: raise the reservation rate, clamped to the hard cap.
            let mut next = tender.max_rate * self.escalation;
            if let Some(cap) = tender.hard_rate_cap {
                next = next.min(cap);
            }
            if next <= tender.max_rate {
                break; // concession exhausted: further rounds are identical
            }
            tender.max_rate = next;
        }
        NegotiationOutcome {
            selected: Vec::new(),
            rounds,
            final_max_rate: tender.max_rate,
            est_total_cost: 0.0,
            best_rejected: Some(tender),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(
        id: u32,
        rate: f64,
        cpus: u32,
        util: f64,
        strategy: BidStrategy,
    ) -> BidServer {
        BidServer {
            resource: ResourceId(id),
            speed: 1.0,
            free_slots: ((1.0 - util) * cpus as f64).floor() as u32,
            posted_rate: rate,
            utilization: util,
            strategy,
        }
    }

    fn tender(jobs: u32, hours: f64, max_rate: f64) -> Tender {
        Tender {
            user: "rajkumar".into(),
            jobs,
            job_work_ref_h: 1.0,
            time_to_deadline_s: hours * 3600.0,
            max_rate,
            hard_rate_cap: None,
        }
    }

    fn bid(id: u32, rate: f64, capacity: u32) -> Bid {
        Bid {
            resource: ResourceId(id),
            rate,
            capacity,
            speed: 1.0,
        }
    }

    #[test]
    fn aggressive_idle_discounts() {
        let s = server(0, 1.0, 4, 0.0, BidStrategy::Aggressive);
        let bid = s.quote(&tender(10, 10.0, 5.0)).unwrap();
        assert!((bid.rate - 0.6).abs() < 1e-9);
    }

    #[test]
    fn premium_busy_charges_more() {
        let s = server(0, 1.0, 8, 0.5, BidStrategy::Premium);
        let bid = s.quote(&tender(10, 10.0, 5.0)).unwrap();
        assert!((bid.rate - 1.5).abs() < 1e-9);
        assert_eq!(bid.capacity, 4); // half the cpus are busy
    }

    #[test]
    fn demand_strategy_discounts_idle_and_prices_contention() {
        let strat = BidStrategy::Demand {
            slope: 0.8,
            idle_discount: 0.25,
        };
        // Idle machine: 25% off the posted rate.
        let idle = server(0, 2.0, 4, 0.0, strat);
        let b = idle.quote(&tender(10, 10.0, 5.0)).unwrap();
        assert!((b.rate - 1.5).abs() < 1e-9, "idle rate {}", b.rate);
        // Half-busy: discount shrinks, demand premium grows.
        let half = server(1, 2.0, 8, 0.5, strat);
        let b = half.quote(&tender(10, 10.0, 5.0)).unwrap();
        // 2.0 × (1 − 0.25 × 0.5) × (1 + 0.8 × 0.5) = 2.0 × 0.875 × 1.4
        assert!((b.rate - 2.45).abs() < 1e-9, "half rate {}", b.rate);
        // Slope 0 (flat owner) degenerates to a pure idle discount.
        let flat = server(
            2,
            2.0,
            4,
            0.0,
            BidStrategy::Demand {
                slope: 0.0,
                idle_discount: 0.25,
            },
        );
        let b = flat.quote(&tender(10, 10.0, 5.0)).unwrap();
        assert!((b.rate - 1.5).abs() < 1e-9);
    }

    #[test]
    fn no_bid_above_reservation_rate() {
        let s = server(0, 10.0, 4, 0.0, BidStrategy::ListPrice);
        assert!(s.quote(&tender(10, 10.0, 5.0)).is_none());
    }

    #[test]
    fn saturated_machine_does_not_bid() {
        let s = server(0, 1.0, 4, 1.0, BidStrategy::Aggressive);
        assert!(s.quote(&tender(10, 10.0, 5.0)).is_none());
    }

    #[test]
    fn selection_prefers_cheap_bids() {
        let t = tender(16, 4.0, 100.0); // need 4 jobs/h
        let cheap = bid(0, 0.5, 4);
        let dear = bid(1, 5.0, 16);
        let sel = select_bids(&t, &[cheap, dear]).unwrap();
        assert_eq!(sel[0].resource, ResourceId(0), "cheap bid wins");
        // The cheap bid alone gives 4 jobs/h — exactly enough.
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn selection_tie_breaks_by_resource_id_not_input_order() {
        // Regression: equal-priced bids used to keep input order, so the
        // same market replayed differently depending on quote arrival
        // order. Ties must break by resource id.
        let t = tender(8, 4.0, 100.0); // need 2 jobs/h
        let forward = vec![bid(3, 1.0, 1), bid(1, 1.0, 1), bid(2, 1.0, 1)];
        let mut reversed = forward.clone();
        reversed.reverse();
        let sel_f = select_bids(&t, &forward).unwrap();
        let sel_r = select_bids(&t, &reversed).unwrap();
        let ids = |sel: &[Bid]| sel.iter().map(|b| b.resource.0).collect::<Vec<_>>();
        assert_eq!(ids(&sel_f), vec![1, 2], "lowest ids win ties");
        assert_eq!(ids(&sel_f), ids(&sel_r), "input order must not matter");
    }

    #[test]
    fn selection_fails_when_infeasible() {
        let t = tender(1000, 1.0, 100.0); // need 1000 jobs/h
        let bids = vec![bid(0, 0.1, 2)];
        assert!(select_bids(&t, &bids).is_none());
    }

    #[test]
    fn zero_job_tender_is_a_trivial_deal() {
        // Nothing to place ⇒ nothing needed ⇒ empty selection succeeds
        // (callers with real work skip the market instead, but the
        // bid-manager must not misreport an empty tender as infeasible).
        let t = tender(0, 4.0, 100.0);
        let sel = select_bids(&t, &[bid(0, 1.0, 4)]).unwrap();
        assert!(sel.is_empty());
        let out = Broker::default().negotiate(t, &[]);
        assert!(out.is_deal());
        assert_eq!(out.rounds, 1);
        assert_eq!(out.est_total_cost, 0.0);
    }

    #[test]
    fn single_bidder_market() {
        // One owner with enough capacity: the deal is that single bid.
        let servers = vec![server(0, 1.0, 64, 0.0, BidStrategy::ListPrice)];
        let out = Broker::default().negotiate(tender(10, 10.0, 2.0), &servers);
        assert!(out.is_deal());
        assert_eq!(out.selected.len(), 1);
        // The same single owner, far too small for the deadline: no amount
        // of escalation conjures capacity — failure reports the final
        // rejected tender.
        let small = vec![server(0, 1.0, 1, 0.0, BidStrategy::ListPrice)];
        let out = Broker::default().negotiate(tender(1000, 1.0, 2.0), &small);
        assert!(!out.is_deal());
        let rejected = out.best_rejected.expect("failed outcome carries tender");
        assert_eq!(rejected.jobs, 1000);
        assert!(rejected.max_rate > 2.0, "tender escalated before giving up");
    }

    #[test]
    fn broker_escalates_until_feasible() {
        // Owner prices at 2.0; tender starts at 0.5 ⇒ needs escalations of
        // 1.5x until the reservation clears 2.0.
        let servers = vec![server(0, 2.0, 64, 0.0, BidStrategy::ListPrice)];
        let broker = Broker::default();
        let out = broker.negotiate(tender(10, 10.0, 0.5), &servers);
        assert!(out.is_deal());
        assert!(out.rounds > 1, "should need escalation, rounds={}", out.rounds);
        assert!(out.final_max_rate >= 2.0);
        assert_eq!(out.selected.len(), 1);
        assert!(out.est_total_cost > 0.0);
    }

    #[test]
    fn broker_gives_up_after_max_rounds() {
        let servers = vec![server(0, 1e9, 64, 0.0, BidStrategy::ListPrice)];
        let broker = Broker {
            max_rounds: 3,
            escalation: 1.1,
        };
        let out = broker.negotiate(tender(10, 10.0, 0.01), &servers);
        assert!(!out.is_deal());
        assert_eq!(out.rounds, 3);
        assert!(out.selected.is_empty());
        let rejected = out.best_rejected.expect("failure carries the tender");
        assert!(
            rejected.max_rate > 0.01 && rejected.max_rate < 1e9,
            "escalated but still far below the ask: {}",
            rejected.max_rate
        );
    }

    #[test]
    fn hard_rate_cap_stops_concessions_early() {
        // Budget affords at most 1.0 G$/CPU-s; the only owner wants 2.0.
        // Escalation hits the cap on round one and round two proves the
        // capped rate still fails — further rounds would be identical, so
        // the broker stops at 2 of its 10 rounds.
        let servers = vec![server(0, 2.0, 64, 0.0, BidStrategy::ListPrice)];
        let broker = Broker {
            max_rounds: 10,
            escalation: 2.0,
        };
        let mut t = tender(10, 10.0, 0.5);
        t.hard_rate_cap = Some(1.0);
        let out = broker.negotiate(t, &servers);
        assert!(!out.is_deal());
        assert_eq!(out.rounds, 2, "capped concession must stop early");
        let rejected = out.best_rejected.unwrap();
        assert!((rejected.max_rate - 1.0).abs() < 1e-12, "clamped at the cap");
    }

    #[test]
    fn budget_below_every_reserve_price_never_deals() {
        // Every owner's floor exceeds the affordability cap: negotiation
        // must fail however generous the round limit, reporting the capped
        // tender.
        let servers = vec![
            server(0, 5.0, 8, 0.0, BidStrategy::ListPrice),
            server(1, 7.0, 8, 0.0, BidStrategy::Premium),
        ];
        let broker = Broker {
            max_rounds: 50,
            escalation: 1.5,
        };
        let mut t = tender(4, 10.0, 0.1);
        t.hard_rate_cap = Some(2.0); // all reserves are above 2.0
        let out = broker.negotiate(t, &servers);
        assert!(!out.is_deal());
        assert!(out.rounds < 50, "cap must short-circuit the round budget");
        assert!((out.best_rejected.unwrap().max_rate - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cost_per_job_accounts_for_speed() {
        let b = Bid {
            resource: ResourceId(0),
            rate: 1.0,
            capacity: 1,
            speed: 2.0,
        };
        // 1 ref-hour of work at speed 2 = 1800 cpu-seconds = 1800 G$.
        assert!((b.cost_per_job(1.0) - 1800.0).abs() < 1e-9);
    }
}
