//! The pluggable market layer: *how* tenants acquire prices on the grid.
//!
//! The paper's §3 economy is posted-price — owners quote, brokers take.
//! §7 sketches GRACE, where brokers instead "enter into bidding and
//! negotiate for the best possible resources". This module is the seam
//! between the two: a world runs under one [`MarketKind`], selected through
//! [`crate::broker::ExperimentBuilder::market`] (or the
//! [`crate::broker::ExperimentBuilder::grace_market`] shorthand) and
//! honoured by [`crate::sim::GridWorld`]:
//!
//! * [`MarketKind::PostedPrice`] (the default) — the pre-GRACE economy:
//!   every quote is the owner's posted rate times competition/demand
//!   premiums. Traces are bit-exact with the pre-market-layer code.
//! * [`MarketKind::GraceAuction`] — periodic tender/bid auctions at
//!   directory-refresh boundaries: each tenant derives a
//!   [`crate::economy::grace::Tender`] from its live DBC state, per-owner
//!   bid servers quote on real utilization, and awards become time-limited
//!   [`PriceAgreement`]s that both the scheduler's resource views and the
//!   billing path honour until they expire.

use crate::types::{GridDollars, SimTime};
use anyhow::ensure;

/// Which market mechanism a world runs its economy through. World-level:
/// in a multi-tenant world only tenant 0's setting is honoured (the market
/// belongs to the grid, like competition and the start hour).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum MarketKind {
    /// Owners post rates; tenants take them (paper §3, the default).
    #[default]
    PostedPrice,
    /// Periodic GRACE tender/bid auctions (paper §7) at every MDS refresh.
    GraceAuction(GraceConfig),
}

impl MarketKind {
    /// Validate tuning values (builder construction guard).
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            MarketKind::PostedPrice => Ok(()),
            MarketKind::GraceAuction(cfg) => cfg.validate(),
        }
    }
}

/// Tuning for the periodic GRACE auction market.
#[derive(Debug, Clone, PartialEq)]
pub struct GraceConfig {
    /// Max tender rounds per negotiation before the broker gives up.
    pub max_rounds: u32,
    /// Reservation-rate escalation factor between rounds (≥ 1).
    pub escalation: f64,
    /// Seconds an awarded price agreement stays in force. Shorter than the
    /// directory-refresh period means every agreement lapses mid-sweep and
    /// pricing falls back to posted rates until the next auction.
    pub agreement_ttl_s: SimTime,
    /// Opening reservation rate as a fraction of the mean posted rate
    /// across bidding owners (< 1 starts the haggling below list price).
    pub opening_rate_factor: f64,
    /// Largest idle-cycle discount owners offer (0..1): a fully idle
    /// machine bids `posted × (1 − idle_discount)`; the discount vanishes
    /// as the machine fills and the owner's demand slope takes over.
    pub idle_discount: f64,
}

impl Default for GraceConfig {
    fn default() -> Self {
        GraceConfig {
            max_rounds: 5,
            escalation: 1.5,
            agreement_ttl_s: 600.0,
            opening_rate_factor: 0.5,
            idle_discount: 0.25,
        }
    }
}

impl GraceConfig {
    /// Validate tuning values.
    pub fn validate(&self) -> anyhow::Result<()> {
        ensure!(
            self.max_rounds >= 1,
            "grace market needs at least one tender round"
        );
        ensure!(
            self.escalation.is_finite() && self.escalation >= 1.0,
            "grace escalation must be >= 1, got {}",
            self.escalation
        );
        ensure!(
            self.agreement_ttl_s.is_finite() && self.agreement_ttl_s > 0.0,
            "grace agreement TTL must be positive, got {} s",
            self.agreement_ttl_s
        );
        ensure!(
            self.opening_rate_factor.is_finite()
                && self.opening_rate_factor > 0.0,
            "grace opening rate factor must be positive, got {}",
            self.opening_rate_factor
        );
        ensure!(
            (0.0..1.0).contains(&self.idle_discount),
            "grace idle discount must be in [0, 1), got {}",
            self.idle_discount
        );
        Ok(())
    }
}

/// A won, time-limited price. Scoped to one (tenant, resource) pair:
/// recorded by the world when a GRACE award lands, honoured by both the
/// scheduler's resource views and the billing path until it expires, then
/// pricing reverts to posted rates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriceAgreement {
    /// Agreed G$/CPU-second.
    pub rate: GridDollars,
    /// Virtual time the agreement lapses (exclusive: billing at exactly
    /// this instant already falls back to posted rates).
    pub valid_until: SimTime,
}

impl PriceAgreement {
    /// Whether the agreement still binds at `now`.
    pub fn active(&self, now: SimTime) -> bool {
        self.valid_until > now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_market_is_posted_price() {
        assert_eq!(MarketKind::default(), MarketKind::PostedPrice);
        assert!(MarketKind::default().validate().is_ok());
    }

    #[test]
    fn default_grace_config_validates() {
        let cfg = GraceConfig::default();
        assert!(cfg.validate().is_ok());
        assert!(MarketKind::GraceAuction(cfg).validate().is_ok());
    }

    #[test]
    fn validation_rejects_nonsense() {
        let ok = GraceConfig::default();
        assert!(GraceConfig { max_rounds: 0, ..ok.clone() }.validate().is_err());
        assert!(GraceConfig { escalation: 0.9, ..ok.clone() }
            .validate()
            .is_err());
        assert!(GraceConfig {
            escalation: f64::NAN,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(GraceConfig {
            agreement_ttl_s: 0.0,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(GraceConfig {
            opening_rate_factor: 0.0,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(GraceConfig {
            idle_discount: 1.0,
            ..ok.clone()
        }
        .validate()
        .is_err());
        assert!(GraceConfig {
            idle_discount: -0.1,
            ..ok
        }
        .validate()
        .is_err());
    }

    #[test]
    fn agreement_expiry_is_exclusive() {
        let a = PriceAgreement {
            rate: 1.0,
            valid_until: 100.0,
        };
        assert!(a.active(99.9));
        assert!(!a.active(100.0));
        assert!(!a.active(100.1));
    }
}
