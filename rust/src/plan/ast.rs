//! Plan AST: parameters, domains, constants, and the task script.

use std::fmt;

/// A parsed plan: the experiment's parameter space plus the per-job task.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    /// Swept parameters, in declaration order (expansion is the cross
    /// product, last parameter varying fastest).
    pub parameters: Vec<Parameter>,
    /// Fixed bindings available for substitution.
    pub constants: Vec<(String, ParamValue)>,
    /// The `task main` script run for every job.
    pub task: Vec<TaskOp>,
}

/// One `parameter` declaration.
#[derive(Debug, Clone)]
pub struct Parameter {
    pub name: String,
    /// Optional human label (`label "..."`).
    pub label: Option<String>,
    pub domain: Domain,
}

/// The value domain a parameter sweeps over.
#[derive(Debug, Clone)]
pub enum Domain {
    /// `float range from LO to HI step S` (inclusive of endpoints hit by the
    /// step), or `integer range ...`.
    Range {
        lo: f64,
        hi: f64,
        step: f64,
        integer: bool,
    },
    /// `float random from LO to HI count N` — N values drawn uniformly at
    /// expansion time (seeded; reproducible).
    Random { lo: f64, hi: f64, count: usize },
    /// `select anyof v1 v2 ...` — explicit value list (numbers or strings).
    Select { values: Vec<ParamValue> },
}

impl Domain {
    /// Number of values this domain contributes to the cross product.
    pub fn cardinality(&self) -> usize {
        match self {
            Domain::Range { lo, hi, step, .. } => {
                if *step <= 0.0 || hi < lo {
                    0
                } else {
                    ((hi - lo) / step + 1.0 + 1e-9).floor() as usize
                }
            }
            Domain::Random { count, .. } => *count,
            Domain::Select { values } => values.len(),
        }
    }
}

/// A concrete parameter value.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Float(f64),
    Int(i64),
    Text(String),
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::Float(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            ParamValue::Int(i) => write!(f, "{i}"),
            ParamValue::Text(s) => f.write_str(s),
        }
    }
}

impl ParamValue {
    /// Numeric view (used by the workload model and the runtime bridge).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParamValue::Float(x) => Some(*x),
            ParamValue::Int(i) => Some(*i as f64),
            ParamValue::Text(_) => None,
        }
    }
}

/// One operation in the per-job task script.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskOp {
    /// `copy SRC DST` — stage a file. Paths prefixed `node:` are on the
    /// compute node; others are on the root (experiment) store. Exactly one
    /// side should be `node:` (stage-in or stage-out).
    Copy { from: String, to: String },
    /// `execute CMD...` — run the application binary on the node.
    Execute { command: String },
}

impl TaskOp {
    /// True if this op stages a file from root storage to the node.
    pub fn is_stage_in(&self) -> bool {
        matches!(self, TaskOp::Copy { from, to }
            if !from.starts_with("node:") && to.starts_with("node:"))
    }

    /// True if this op stages a file from the node back to root storage.
    pub fn is_stage_out(&self) -> bool {
        matches!(self, TaskOp::Copy { from, to }
            if from.starts_with("node:") && !to.starts_with("node:"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_cardinality() {
        let d = Domain::Range {
            lo: 100.0,
            hi: 1000.0,
            step: 100.0,
            integer: false,
        };
        assert_eq!(d.cardinality(), 10);
        let d = Domain::Range {
            lo: 0.0,
            hi: 1.0,
            step: 0.25,
            integer: false,
        };
        assert_eq!(d.cardinality(), 5);
        // Degenerate cases.
        let d = Domain::Range {
            lo: 5.0,
            hi: 5.0,
            step: 1.0,
            integer: true,
        };
        assert_eq!(d.cardinality(), 1);
        let d = Domain::Range {
            lo: 5.0,
            hi: 1.0,
            step: 1.0,
            integer: true,
        };
        assert_eq!(d.cardinality(), 0);
    }

    #[test]
    fn value_display() {
        assert_eq!(ParamValue::Float(4.0).to_string(), "4");
        assert_eq!(ParamValue::Float(4.5).to_string(), "4.5");
        assert_eq!(ParamValue::Int(-2).to_string(), "-2");
        assert_eq!(ParamValue::Text("ab".into()).to_string(), "ab");
    }

    #[test]
    fn stage_direction() {
        let op = TaskOp::Copy {
            from: "in.dat".into(),
            to: "node:in.dat".into(),
        };
        assert!(op.is_stage_in() && !op.is_stage_out());
        let op = TaskOp::Copy {
            from: "node:out.dat".into(),
            to: "out.dat".into(),
        };
        assert!(op.is_stage_out() && !op.is_stage_in());
    }
}
