//! The Nimrod declarative parametric modeling language ("plans").
//!
//! A plan declares the parameter space of an experiment and the task script
//! each job runs (file staging + execution), after the Clustor plan-file
//! syntax the paper builds on (§1, refs [13][14]):
//!
//! ```text
//! # ionization chamber calibration sweep
//! parameter voltage label "electrode V" float range from 100 to 1000 step 100
//! parameter pressure float random from 0.5 to 2.0 count 4
//! parameter energy float select anyof 2.0 10.0 18.0
//! constant chamber text "icc-mk2"
//!
//! task main
//!     copy chamber.cfg node:chamber.cfg
//!     execute ./icc_sim -v $voltage -p $pressure -e $energy -c $chamber
//!     copy node:results.dat results.$jobname.dat
//! endtask
//! ```
//!
//! [`Plan::parse`] builds the AST; [`expand::expand`] produces the cross
//! product of parameter domains as concrete [`JobSpec`]s with `$var`
//! substitution applied to task commands.

pub mod ast;
pub mod expand;
pub mod lexer;
pub mod parser;

pub use ast::{Domain, ParamValue, Parameter, Plan, TaskOp};
pub use expand::{expand, JobSpec};

/// Errors from plan parsing or expansion.
#[derive(Debug)]
pub enum PlanError {
    Lex { line: u32, msg: String },
    Parse { line: u32, msg: String },
    Expand(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Lex { line, msg } => {
                write!(f, "line {line}: lex error: {msg}")
            }
            PlanError::Parse { line, msg } => {
                write!(f, "line {line}: parse error: {msg}")
            }
            PlanError::Expand(msg) => write!(f, "expansion error: {msg}"),
        }
    }
}

impl std::error::Error for PlanError {}

impl Plan {
    /// Parse a plan from source text.
    pub fn parse(src: &str) -> Result<Plan, PlanError> {
        let tokens = lexer::lex(src)?;
        parser::parse(&tokens)
    }

    /// Total number of jobs this plan expands to.
    pub fn job_count(&self) -> usize {
        self.parameters
            .iter()
            .map(|p| p.domain.cardinality())
            .product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PLAN: &str = r#"
# ionization chamber calibration
parameter voltage label "electrode V" float range from 100 to 300 step 100
parameter energy float select anyof 2.0 10.0
constant chamber text "icc-mk2"

task main
    copy chamber.cfg node:chamber.cfg
    execute ./icc_sim -v $voltage -e $energy -c $chamber
    copy node:results.dat results.$jobname.dat
endtask
"#;

    #[test]
    fn parse_and_count() {
        let plan = Plan::parse(PLAN).unwrap();
        assert_eq!(plan.parameters.len(), 2);
        assert_eq!(plan.constants.len(), 1);
        assert_eq!(plan.job_count(), 6); // 3 voltages x 2 energies
        assert_eq!(plan.task.len(), 3);
    }

    #[test]
    fn full_roundtrip_expansion() {
        let plan = Plan::parse(PLAN).unwrap();
        let jobs = expand(&plan, 12345).unwrap();
        assert_eq!(jobs.len(), 6);
        // Every job has distinct parameter bindings.
        let mut seen = std::collections::HashSet::new();
        for j in &jobs {
            let key = format!("{:?}", j.bindings);
            assert!(seen.insert(key), "duplicate binding set");
        }
        // Substitution applied in execute op.
        let exec = &jobs[0].script[1];
        if let TaskOp::Execute { command } = exec {
            assert!(command.contains("-c icc-mk2"), "constant substituted");
            assert!(!command.contains('$'), "no unresolved vars: {command}");
        } else {
            panic!("expected execute op");
        }
    }
}
