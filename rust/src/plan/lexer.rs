//! Line-oriented lexer for plan files.
//!
//! The Clustor plan grammar is line-structured: one declaration or task op
//! per line, `#` comments, quoted strings, and bare words/numbers. The lexer
//! produces a token stream with line numbers preserved for diagnostics, and
//! keeps the raw remainder-of-line for `execute` commands (which are free
//! text with `$var` references).

use super::PlanError;

/// A lexical token.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Bare word (keyword, identifier, or path fragment).
    Word(String),
    /// Quoted string literal (quotes stripped, escapes applied).
    Str(String),
    /// Numeric literal.
    Num(f64),
    /// End of line (statement separator).
    Eol,
}

/// Token with source line for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// Lex a plan source into tokens. Blank lines and comments are dropped, but
/// every non-empty line is terminated by an `Eol` token.
pub fn lex(src: &str) -> Result<Vec<Token>, PlanError> {
    let mut out = Vec::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = lineno as u32 + 1;
        let text = match raw.find('#') {
            Some(i) if !in_string(raw, i) => &raw[..i],
            _ => raw,
        };
        let text = text.trim();
        if text.is_empty() {
            continue;
        }
        lex_line(text, line, &mut out)?;
        out.push(Token {
            tok: Tok::Eol,
            line,
        });
    }
    Ok(out)
}

/// Check whether byte offset `i` falls inside a quoted string in `s`.
fn in_string(s: &str, i: usize) -> bool {
    let mut inside = false;
    for (j, c) in s.char_indices() {
        if j >= i {
            break;
        }
        if c == '"' {
            inside = !inside;
        }
    }
    inside
}

fn lex_line(text: &str, line: u32, out: &mut Vec<Token>) -> Result<(), PlanError> {
    let b = text.as_bytes();
    let mut i = 0;
    while i < b.len() {
        let c = b[i] as char;
        if c.is_whitespace() {
            i += 1;
        } else if c == '"' {
            let (s, len) = lex_string(&text[i..], line)?;
            out.push(Token {
                tok: Tok::Str(s),
                line,
            });
            i += len;
        } else {
            let start = i;
            while i < b.len() && !(b[i] as char).is_whitespace() {
                i += 1;
            }
            let word = &text[start..i];
            let tok = match word.parse::<f64>() {
                Ok(x) => Tok::Num(x),
                Err(_) => Tok::Word(word.to_string()),
            };
            out.push(Token { tok, line });
        }
    }
    Ok(())
}

fn lex_string(s: &str, line: u32) -> Result<(String, usize), PlanError> {
    debug_assert!(s.starts_with('"'));
    let mut out = String::new();
    let mut chars = s.char_indices().skip(1);
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((out, i + 1)),
            '\\' => match chars.next() {
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, e)) => out.push(e),
                None => break,
            },
            c => out.push(c),
        }
    }
    Err(PlanError::Lex {
        line,
        msg: "unterminated string literal".to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn words(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn words_numbers_strings() {
        let toks = words(r#"parameter x float range from 1 to 2.5 step 0.5"#);
        assert_eq!(toks[0], Tok::Word("parameter".into()));
        assert_eq!(toks[5], Tok::Num(1.0));
        assert_eq!(toks[7], Tok::Num(2.5));
        assert_eq!(*toks.last().unwrap(), Tok::Eol);
    }

    #[test]
    fn comments_and_blank_lines_dropped() {
        let toks = words("# full comment\n\nfoo # trailing\n");
        assert_eq!(toks, vec![Tok::Word("foo".into()), Tok::Eol]);
    }

    #[test]
    fn quoted_strings_with_escapes() {
        let toks = words(r#"label "a \"b\" c""#);
        assert_eq!(toks[1], Tok::Str("a \"b\" c".into()));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let toks = words(r#"name "with # hash""#);
        assert_eq!(toks[1], Tok::Str("with # hash".into()));
    }

    #[test]
    fn unterminated_string_errors() {
        assert!(lex(r#"bad "never ends"#).is_err());
    }

    #[test]
    fn line_numbers_tracked() {
        let toks = lex("a\nb\nc").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[2].line, 2);
        assert_eq!(toks[4].line, 3);
    }

    #[test]
    fn negative_numbers() {
        let toks = words("offset -3.5");
        assert_eq!(toks[1], Tok::Num(-3.5));
    }
}
