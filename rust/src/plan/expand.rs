//! Parameter-space expansion: plan → concrete jobs.
//!
//! The parametric engine calls [`expand`] once at experiment start. The
//! expansion is the cross product of all parameter domains (last parameter
//! varying fastest, matching Clustor), with `random` domains drawn from a
//! seeded stream so the same (plan, seed) pair always yields the same jobs —
//! required for restart-from-journal to be consistent.

use super::ast::{Domain, ParamValue, Plan, TaskOp};
use super::PlanError;
use crate::types::JobId;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// A fully-instantiated job: bindings plus the substituted task script.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    /// Parameter name → value (constants included).
    pub bindings: BTreeMap<String, ParamValue>,
    /// Task script with `$var` substitution applied.
    pub script: Vec<TaskOp>,
}

impl JobSpec {
    /// Numeric view of a binding (used by the runtime bridge).
    pub fn f64_binding(&self, name: &str) -> Option<f64> {
        self.bindings.get(name).and_then(|v| v.as_f64())
    }
}

/// Expand a plan into jobs. `seed` drives `random` domains only.
pub fn expand(plan: &Plan, seed: u64) -> Result<Vec<JobSpec>, PlanError> {
    // Materialize each domain's value list.
    let mut rng = Rng::new(seed);
    let mut axes: Vec<(String, Vec<ParamValue>)> = Vec::new();
    for p in &plan.parameters {
        let values = materialize(&p.domain, &mut rng);
        if values.is_empty() {
            return Err(PlanError::Expand(format!(
                "parameter `{}` has an empty domain",
                p.name
            )));
        }
        axes.push((p.name.clone(), values));
    }
    // Duplicate names would silently shadow; reject.
    for i in 0..axes.len() {
        for j in i + 1..axes.len() {
            if axes[i].0 == axes[j].0 {
                return Err(PlanError::Expand(format!(
                    "duplicate parameter `{}`",
                    axes[i].0
                )));
            }
        }
    }

    let total: usize = axes.iter().map(|(_, v)| v.len()).product();
    let mut jobs = Vec::with_capacity(total);
    let mut idx = vec![0usize; axes.len()];
    for jobno in 0..total {
        let mut bindings = BTreeMap::new();
        for (k, (name, values)) in axes.iter().enumerate() {
            bindings.insert(name.clone(), values[idx[k]].clone());
        }
        for (name, value) in &plan.constants {
            bindings.insert(name.clone(), value.clone());
        }
        let id = JobId(jobno as u32);
        bindings.insert(
            "jobname".to_string(),
            ParamValue::Text(format!("{id}")),
        );
        let script = plan
            .task
            .iter()
            .map(|op| substitute_op(op, &bindings))
            .collect::<Result<Vec<_>, _>>()?;
        jobs.push(JobSpec {
            id,
            bindings,
            script,
        });
        // Odometer increment, last axis fastest.
        for k in (0..axes.len()).rev() {
            idx[k] += 1;
            if idx[k] < axes[k].1.len() {
                break;
            }
            idx[k] = 0;
        }
    }
    Ok(jobs)
}

fn materialize(domain: &Domain, rng: &mut Rng) -> Vec<ParamValue> {
    match domain {
        Domain::Range {
            lo,
            hi,
            step,
            integer,
        } => {
            let n = domain.cardinality();
            (0..n)
                .map(|i| {
                    let x = lo + *step * i as f64;
                    let x = if x > *hi { *hi } else { x };
                    if *integer {
                        ParamValue::Int(x.round() as i64)
                    } else {
                        ParamValue::Float(x)
                    }
                })
                .collect()
        }
        Domain::Random { lo, hi, count } => (0..*count)
            .map(|_| ParamValue::Float(rng.uniform(*lo, *hi)))
            .collect(),
        Domain::Select { values } => values.clone(),
    }
}

fn substitute_op(
    op: &TaskOp,
    bindings: &BTreeMap<String, ParamValue>,
) -> Result<TaskOp, PlanError> {
    Ok(match op {
        TaskOp::Copy { from, to } => TaskOp::Copy {
            from: substitute(from, bindings)?,
            to: substitute(to, bindings)?,
        },
        TaskOp::Execute { command } => TaskOp::Execute {
            command: substitute(command, bindings)?,
        },
    })
}

/// Replace `$name` / `${name}` references. Unknown references are an error
/// (silently passing them to a remote shell is how experiments die quietly).
pub fn substitute(
    text: &str,
    bindings: &BTreeMap<String, ParamValue>,
) -> Result<String, PlanError> {
    let mut out = String::with_capacity(text.len());
    let b = text.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'$' {
            let (name, consumed) = if b.get(i + 1) == Some(&b'{') {
                let end = text[i + 2..].find('}').ok_or_else(|| {
                    PlanError::Expand(format!("unterminated ${{...}} in `{text}`"))
                })?;
                (&text[i + 2..i + 2 + end], end + 3)
            } else {
                let rest = &text[i + 1..];
                let end = rest
                    .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
                    .unwrap_or(rest.len());
                (&rest[..end], end + 1)
            };
            if name.is_empty() {
                out.push('$');
                i += 1;
                continue;
            }
            let value = bindings.get(name).ok_or_else(|| {
                PlanError::Expand(format!("unknown parameter `${name}` in `{text}`"))
            })?;
            out.push_str(&value.to_string());
            i += consumed;
        } else {
            let len = match b[i] {
                0x00..=0x7f => 1,
                0xc0..=0xdf => 2,
                0xe0..=0xef => 3,
                _ => 4,
            };
            out.push_str(&text[i..i + len]);
            i += len;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Plan;

    fn bindings(pairs: &[(&str, ParamValue)]) -> BTreeMap<String, ParamValue> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect()
    }

    #[test]
    fn substitution_forms() {
        let b = bindings(&[
            ("x", ParamValue::Int(7)),
            ("name", ParamValue::Text("run1".into())),
        ]);
        assert_eq!(substitute("a $x b", &b).unwrap(), "a 7 b");
        assert_eq!(substitute("${x}b", &b).unwrap(), "7b");
        assert_eq!(substitute("out.$name.dat", &b).unwrap(), "out.run1.dat");
        assert!(substitute("$missing", &b).is_err());
        assert!(substitute("${unclosed", &b).is_err());
        // Bare dollar passes through.
        assert_eq!(substitute("cost $ 5", &b).unwrap(), "cost $ 5");
    }

    #[test]
    fn cross_product_order_last_fastest() {
        let plan = Plan::parse(
            "parameter a float select anyof 1 2\nparameter b float select anyof 10 20 30\ntask main\nexecute r $a $b\nendtask",
        )
        .unwrap();
        let jobs = expand(&plan, 0).unwrap();
        assert_eq!(jobs.len(), 6);
        let cmds: Vec<String> = jobs
            .iter()
            .map(|j| match &j.script[0] {
                TaskOp::Execute { command } => command.clone(),
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(cmds[0], "r 1 10");
        assert_eq!(cmds[1], "r 1 20");
        assert_eq!(cmds[2], "r 1 30");
        assert_eq!(cmds[3], "r 2 10");
    }

    #[test]
    fn random_domains_reproducible() {
        let plan = Plan::parse(
            "parameter p float random from 0 to 1 count 4\ntask main\nexecute r $p\nendtask",
        )
        .unwrap();
        let a = expand(&plan, 99).unwrap();
        let b = expand(&plan, 99).unwrap();
        let c = expand(&plan, 100).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.bindings, y.bindings);
        }
        assert!(a.iter().zip(&c).any(|(x, y)| x.bindings != y.bindings));
    }

    #[test]
    fn jobname_binding_present() {
        let plan = Plan::parse(
            "parameter a float select anyof 1\ntask main\nexecute run out.$jobname\nendtask",
        )
        .unwrap();
        let jobs = expand(&plan, 0).unwrap();
        match &jobs[0].script[0] {
            TaskOp::Execute { command } => assert_eq!(command, "run out.j0"),
            _ => unreachable!(),
        }
    }

    #[test]
    fn integer_range_values() {
        let plan = Plan::parse(
            "parameter n integer range from 2 to 6 step 2\ntask main\nexecute r $n\nendtask",
        )
        .unwrap();
        let jobs = expand(&plan, 0).unwrap();
        let vals: Vec<i64> = jobs
            .iter()
            .map(|j| match j.bindings["n"] {
                ParamValue::Int(i) => i,
                _ => panic!("expected int"),
            })
            .collect();
        assert_eq!(vals, vec![2, 4, 6]);
    }
}
