//! Recursive-descent parser over the plan token stream.

use super::ast::{Domain, ParamValue, Parameter, Plan, TaskOp};
use super::lexer::{Tok, Token};
use super::PlanError;

/// Parse a token stream into a [`Plan`].
pub fn parse(tokens: &[Token]) -> Result<Plan, PlanError> {
    let mut p = P { toks: tokens, i: 0 };
    let mut plan = Plan::default();
    while !p.at_end() {
        match p.peek_word() {
            Some("parameter") => plan.parameters.push(p.parameter()?),
            Some("constant") => {
                let (name, value) = p.constant()?;
                plan.constants.push((name, value));
            }
            Some("task") => {
                if !plan.task.is_empty() {
                    return Err(p.err("duplicate task block"));
                }
                plan.task = p.task_block()?;
            }
            _ => return Err(p.err("expected `parameter`, `constant` or `task`")),
        }
    }
    if plan.task.is_empty() {
        return Err(PlanError::Parse {
            line: 0,
            msg: "plan has no task block".to_string(),
        });
    }
    Ok(plan)
}

struct P<'a> {
    toks: &'a [Token],
    i: usize,
}

impl<'a> P<'a> {
    fn at_end(&self) -> bool {
        self.i >= self.toks.len()
    }

    fn line(&self) -> u32 {
        self.toks
            .get(self.i.min(self.toks.len().saturating_sub(1)))
            .map(|t| t.line)
            .unwrap_or(0)
    }

    fn err(&self, msg: impl Into<String>) -> PlanError {
        PlanError::Parse {
            line: self.line(),
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i).map(|t| &t.tok)
    }

    fn peek_word(&self) -> Option<&str> {
        match self.peek() {
            Some(Tok::Word(w)) => Some(w.as_str()),
            _ => None,
        }
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.i).map(|t| t.tok.clone());
        self.i += 1;
        t
    }

    fn expect_word(&mut self, w: &str) -> Result<(), PlanError> {
        match self.next() {
            Some(Tok::Word(ref got)) if got == w => Ok(()),
            other => Err(self.err(format!("expected `{w}`, got {other:?}"))),
        }
    }

    fn ident(&mut self) -> Result<String, PlanError> {
        match self.next() {
            Some(Tok::Word(w)) => Ok(w),
            other => Err(self.err(format!("expected identifier, got {other:?}"))),
        }
    }

    fn num(&mut self) -> Result<f64, PlanError> {
        match self.next() {
            Some(Tok::Num(x)) => Ok(x),
            other => Err(self.err(format!("expected number, got {other:?}"))),
        }
    }

    fn eol(&mut self) -> Result<(), PlanError> {
        match self.next() {
            Some(Tok::Eol) | None => Ok(()),
            other => Err(self.err(format!("expected end of line, got {other:?}"))),
        }
    }

    /// `parameter NAME [label "..."] TYPE DOMAIN`
    fn parameter(&mut self) -> Result<Parameter, PlanError> {
        self.expect_word("parameter")?;
        let name = self.ident()?;
        let label = if self.peek_word() == Some("label") {
            self.next();
            match self.next() {
                Some(Tok::Str(s)) => Some(s.clone()),
                other => {
                    return Err(self.err(format!("expected label string, got {other:?}")))
                }
            }
        } else {
            None
        };
        let ty = self.ident()?; // float | integer | text
        let integer = match ty.as_str() {
            "float" => false,
            "integer" => true,
            "text" => {
                // text parameters only support `select anyof`.
                self.expect_word("select")?;
                self.expect_word("anyof")?;
                let values = self.value_list(true)?;
                self.eol()?;
                return Ok(Parameter {
                    name,
                    label,
                    domain: Domain::Select { values },
                });
            }
            other => return Err(self.err(format!("unknown parameter type `{other}`"))),
        };

        let domain = match self.peek_word() {
            Some("range") => {
                self.next();
                self.expect_word("from")?;
                let lo = self.num()?;
                self.expect_word("to")?;
                let hi = self.num()?;
                let step = if self.peek_word() == Some("step") {
                    self.next();
                    self.num()?
                } else {
                    1.0
                };
                if step <= 0.0 {
                    return Err(self.err("range step must be positive"));
                }
                if hi < lo {
                    return Err(self.err("range hi must be >= lo"));
                }
                Domain::Range {
                    lo,
                    hi,
                    step,
                    integer,
                }
            }
            Some("random") => {
                self.next();
                self.expect_word("from")?;
                let lo = self.num()?;
                self.expect_word("to")?;
                let hi = self.num()?;
                self.expect_word("count")?;
                let count = self.num()? as usize;
                if count == 0 {
                    return Err(self.err("random count must be >= 1"));
                }
                Domain::Random { lo, hi, count }
            }
            Some("select") => {
                self.next();
                self.expect_word("anyof")?;
                let values = self.value_list(false)?;
                Domain::Select { values }
            }
            other => return Err(self.err(format!("unknown domain {other:?}"))),
        };
        self.eol()?;
        Ok(Parameter {
            name,
            label,
            domain,
        })
    }

    fn value_list(&mut self, text: bool) -> Result<Vec<ParamValue>, PlanError> {
        let mut values = Vec::new();
        loop {
            match self.peek() {
                Some(Tok::Num(x)) => {
                    values.push(if text {
                        ParamValue::Text(format!("{x}"))
                    } else {
                        ParamValue::Float(*x)
                    });
                    self.next();
                }
                Some(Tok::Str(s)) => {
                    values.push(ParamValue::Text(s.clone()));
                    self.next();
                }
                Some(Tok::Word(w)) => {
                    values.push(ParamValue::Text(w.clone()));
                    self.next();
                }
                _ => break,
            }
        }
        if values.is_empty() {
            return Err(self.err("`anyof` needs at least one value"));
        }
        Ok(values)
    }

    /// `constant NAME TYPE VALUE`
    fn constant(&mut self) -> Result<(String, ParamValue), PlanError> {
        self.expect_word("constant")?;
        let name = self.ident()?;
        let ty = self.ident()?;
        let value = match (ty.as_str(), self.next()) {
            ("float", Some(Tok::Num(x))) => ParamValue::Float(x),
            ("integer", Some(Tok::Num(x))) => ParamValue::Int(x as i64),
            ("text", Some(Tok::Str(s))) => ParamValue::Text(s),
            ("text", Some(Tok::Word(w))) => ParamValue::Text(w),
            (ty, other) => {
                return Err(self.err(format!("bad constant {ty} value {other:?}")))
            }
        };
        self.eol()?;
        Ok((name, value))
    }

    /// `task main ... endtask` — ops are `copy` and `execute`.
    fn task_block(&mut self) -> Result<Vec<TaskOp>, PlanError> {
        self.expect_word("task")?;
        let _name = self.ident()?; // conventionally `main`
        self.eol()?;
        let mut ops = Vec::new();
        loop {
            match self.peek_word() {
                Some("endtask") => {
                    self.next();
                    let _ = self.eol();
                    break;
                }
                Some("copy") => {
                    self.next();
                    let from = self.path_word()?;
                    let to = self.path_word()?;
                    self.eol()?;
                    ops.push(TaskOp::Copy { from, to });
                }
                Some("execute") => {
                    self.next();
                    // Free text to end of line.
                    let mut parts: Vec<String> = Vec::new();
                    loop {
                        match self.peek() {
                            Some(Tok::Eol) | None => {
                                self.next();
                                break;
                            }
                            Some(Tok::Word(w)) => {
                                parts.push(w.clone());
                                self.next();
                            }
                            Some(Tok::Str(s)) => {
                                parts.push(format!("\"{s}\""));
                                self.next();
                            }
                            Some(Tok::Num(x)) => {
                                parts.push(format!("{x}"));
                                self.next();
                            }
                        }
                    }
                    if parts.is_empty() {
                        return Err(self.err("empty execute command"));
                    }
                    ops.push(TaskOp::Execute {
                        command: parts.join(" "),
                    });
                }
                None => return Err(self.err("unterminated task block")),
                other => return Err(self.err(format!("unknown task op {other:?}"))),
            }
        }
        if ops.is_empty() {
            return Err(self.err("task block has no operations"));
        }
        Ok(ops)
    }

    fn path_word(&mut self) -> Result<String, PlanError> {
        match self.next() {
            Some(Tok::Word(w)) => Ok(w),
            Some(Tok::Str(s)) => Ok(s),
            other => Err(self.err(format!("expected path, got {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse_src(src: &str) -> Result<Plan, PlanError> {
        parse(&lex(src).unwrap())
    }

    #[test]
    fn minimal_plan() {
        let plan = parse_src(
            "parameter x float range from 1 to 3\ntask main\nexecute run $x\nendtask",
        )
        .unwrap();
        assert_eq!(plan.parameters.len(), 1);
        assert_eq!(plan.job_count(), 3);
    }

    #[test]
    fn all_domain_kinds() {
        let plan = parse_src(
            r#"
parameter a float range from 0 to 1 step 0.5
parameter b integer range from 1 to 4
parameter c float random from 2 to 3 count 5
parameter d text select anyof "x" "y"
parameter e float select anyof 1.5 2.5 3.5
task main
execute run
endtask
"#,
        )
        .unwrap();
        let cards: Vec<usize> =
            plan.parameters.iter().map(|p| p.domain.cardinality()).collect();
        assert_eq!(cards, vec![3, 4, 5, 2, 3]);
        assert_eq!(plan.job_count(), 3 * 4 * 5 * 2 * 3);
    }

    #[test]
    fn labels_and_constants() {
        let plan = parse_src(
            r#"
parameter v label "voltage (V)" float range from 1 to 2
constant gas text "argon"
constant trials integer 5
task main
execute sim $v $gas $trials
endtask
"#,
        )
        .unwrap();
        assert_eq!(plan.parameters[0].label.as_deref(), Some("voltage (V)"));
        assert_eq!(plan.constants.len(), 2);
        assert_eq!(plan.constants[1].1, ParamValue::Int(5));
    }

    #[test]
    fn copy_ops_parsed() {
        let plan = parse_src(
            "parameter x float range from 1 to 2\ntask main\ncopy in.dat node:in.dat\nexecute run\ncopy node:out out.$jobname\nendtask",
        )
        .unwrap();
        assert!(plan.task[0].is_stage_in());
        assert!(plan.task[2].is_stage_out());
    }

    #[test]
    fn error_cases() {
        // No task block.
        assert!(parse_src("parameter x float range from 1 to 2").is_err());
        // Bad step.
        assert!(parse_src(
            "parameter x float range from 1 to 2 step 0\ntask main\nexecute r\nendtask"
        )
        .is_err());
        // hi < lo.
        assert!(parse_src(
            "parameter x float range from 5 to 2\ntask main\nexecute r\nendtask"
        )
        .is_err());
        // Unterminated task.
        assert!(parse_src("parameter x float range from 1 to 2\ntask main\nexecute r")
            .is_err());
        // Unknown op.
        assert!(parse_src(
            "parameter x float range from 1 to 2\ntask main\nfrobnicate\nendtask"
        )
        .is_err());
        // Duplicate task.
        assert!(parse_src(
            "task main\nexecute a\nendtask\ntask main\nexecute b\nendtask"
        )
        .is_err());
    }

    #[test]
    fn line_numbers_in_errors() {
        let err = parse_src("parameter x float range from 5 to 2\ntask main\nexecute r\nendtask")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 1"), "{msg}");
    }
}
