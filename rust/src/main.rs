//! `nimrod` — the Nimrod/G command-line launcher.
//!
//! Subcommands:
//!
//! ```text
//! nimrod run        --plan FILE [--deadline-h H] [--budget G] [--policy P]
//!                   [--seed S] [--scale X] [--journal FILE] [--csv DIR]
//! nimrod resume     --journal FILE            restart a crashed experiment
//! nimrod figure3    [--csv DIR] [--seed S]    reproduce the paper's Figure 3
//! nimrod testbed    [--seed S] [--scale X]    dump the GUSTO-like testbed JSON
//! nimrod policies                             list scheduling policies
//! nimrod live       [--workers N] [--jobs N]  real PJRT execution demo
//! ```
//!
//! (Argument parsing is hand-rolled: this image builds offline without
//! clap; see rust/src/util/.)

use anyhow::{bail, Context, Result};
use nimrod_g::config::ExperimentConfig;
use nimrod_g::engine::journal::{recover, Journal};
use nimrod_g::grid::Testbed;
use nimrod_g::plan::{expand, Plan};
use nimrod_g::sim::live::LiveRunner;
use nimrod_g::sim::GridSimulation;
use nimrod_g::types::HOUR;
use nimrod_g::util::logging;
use nimrod_g::workload;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("nimrod: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

/// Parsed `--key value` options.
struct Opts {
    flags: BTreeMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Opts> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                bail!("unexpected argument `{a}`");
            }
        }
        Ok(Opts { flags })
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad --{key} `{v}`")),
            None => Ok(default),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad --{key} `{v}`")),
            None => Ok(default),
        }
    }

    fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.flags.get(key) {
            Some(v) => Ok(Some(
                v.parse().with_context(|| format!("bad --{key} `{v}`"))?,
            )),
            None => Ok(None),
        }
    }

    fn path(&self, key: &str) -> Option<PathBuf> {
        self.flags.get(key).map(PathBuf::from)
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let opts = Opts::parse(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&opts),
        "resume" => cmd_resume(&opts),
        "figure3" => cmd_figure3(&opts),
        "testbed" => cmd_testbed(&opts),
        "policies" => {
            for p in nimrod_g::scheduler::ALL_POLICIES {
                println!("{p}");
            }
            Ok(())
        }
        "live" => cmd_live(&opts),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `nimrod help`)"),
    }
}

fn print_usage() {
    println!(
        "nimrod — Nimrod/G grid resource management and scheduling\n\n\
         usage:\n  nimrod run --plan FILE [--deadline-h H] [--budget G$] [--policy NAME]\n             [--seed S] [--scale X] [--journal FILE] [--csv DIR]\n  nimrod resume --journal FILE [--policy NAME] [--csv DIR]\n  nimrod figure3 [--csv DIR] [--seed S]\n  nimrod testbed [--seed S] [--scale X]\n  nimrod policies\n  nimrod live [--workers N] [--jobs N] [--policy NAME] [--workdir DIR]"
    );
}

fn experiment_cfg(opts: &Opts) -> Result<ExperimentConfig> {
    Ok(ExperimentConfig {
        user: opts.str("user", "rajkumar"),
        deadline: opts.f64("deadline-h", 15.0)? * HOUR,
        budget: opts.opt_f64("budget")?,
        policy: opts.str("policy", "cost"),
        seed: opts.u64("seed", 0xD15EA5E)?,
        ..Default::default()
    })
}

fn write_csvs(report: &nimrod_g::metrics::Report, dir: &Path, tag: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(
        dir.join(format!("{tag}_timeline.csv")),
        report.timeline_csv(300.0),
    )?;
    std::fs::write(
        dir.join(format!("{tag}_resources.csv")),
        report.per_resource_csv(),
    )?;
    println!("wrote {}/{{{tag}_timeline,{tag}_resources}}.csv", dir.display());
    Ok(())
}

fn cmd_run(opts: &Opts) -> Result<()> {
    let plan_path = opts
        .path("plan")
        .context("`nimrod run` needs --plan FILE")?;
    let src = std::fs::read_to_string(&plan_path)
        .with_context(|| format!("read plan {}", plan_path.display()))?;
    let plan = Plan::parse(&src)?;
    let cfg = experiment_cfg(opts)?;
    let specs = expand(&plan, cfg.seed)?;
    println!(
        "experiment: {} jobs, deadline {:.1} h, policy {}, budget {}",
        specs.len(),
        cfg.deadline / HOUR,
        cfg.policy,
        cfg.budget
            .map(|b| format!("{b:.0} G$"))
            .unwrap_or_else(|| "unlimited".into()),
    );
    let tb = Testbed::gusto(cfg.seed ^ 0x6057, opts.f64("scale", 1.0)?);
    println!(
        "testbed: {} resources / {} cpus across {} sites",
        tb.resources.len(),
        tb.total_cpus(),
        tb.sites.len()
    );
    let mut sim = GridSimulation::new(tb, specs, cfg.clone());
    if let Some(journal_path) = opts.path("journal") {
        let journal = Journal::create(&journal_path, &src, cfg.seed, &sim.exp)?;
        sim = sim.with_journal(journal);
    }
    let report = sim.run();
    println!("{}", report.summary());
    if let Some(dir) = opts.path("csv") {
        write_csvs(&report, &dir, "run")?;
    }
    Ok(())
}

fn cmd_resume(opts: &Opts) -> Result<()> {
    let journal_path = opts
        .path("journal")
        .context("`nimrod resume` needs --journal FILE")?;
    let rec = recover(&journal_path)?;
    println!(
        "recovered: {}/{} jobs done, {} remaining",
        rec.experiment.completed(),
        rec.experiment.jobs.len(),
        rec.experiment.remaining()
    );
    let mut cfg = experiment_cfg(opts)?;
    cfg.seed = rec.seed;
    cfg.deadline = rec.experiment.deadline;
    cfg.budget = rec.experiment.budget;
    let tb = Testbed::gusto(cfg.seed ^ 0x6057, opts.f64("scale", 1.0)?);
    let journal = Journal::append_to(&journal_path)?;
    let sim = GridSimulation::new(tb, Vec::new(), cfg)
        .with_experiment(rec.experiment)
        .with_journal(journal);
    let report = sim.run();
    println!("{}", report.summary());
    if let Some(dir) = opts.path("csv") {
        write_csvs(&report, &dir, "resume")?;
    }
    Ok(())
}

fn cmd_figure3(opts: &Opts) -> Result<()> {
    let seed = opts.u64("seed", 0xD15EA5E)?;
    let csv_dir = opts.path("csv");
    println!("Figure 3: GUSTO resource usage for 10 / 15 / 20 hour deadlines");
    println!("(165-job ionization chamber calibration, cost-optimizing DBC)\n");
    for deadline_h in [10.0, 15.0, 20.0] {
        let cfg = ExperimentConfig {
            deadline: deadline_h * HOUR,
            policy: "cost".into(),
            seed,
            ..Default::default()
        };
        let report = GridSimulation::gusto_ionization(cfg).run();
        println!("deadline {deadline_h:>4.0} h: {}", report.summary());
        println!(
            "              avg {:.1} busy cpus over the run",
            report.busy_cpus.average(report.makespan_s.max(1.0))
        );
        if let Some(dir) = &csv_dir {
            write_csvs(&report, dir, &format!("figure3_{}h", deadline_h as u32))?;
        }
    }
    Ok(())
}

fn cmd_testbed(opts: &Opts) -> Result<()> {
    let tb = Testbed::gusto(opts.u64("seed", 0xD15EA5E)?, opts.f64("scale", 1.0)?);
    println!("{}", tb.to_json().to_string());
    Ok(())
}

fn cmd_live(opts: &Opts) -> Result<()> {
    let workers = opts.u64("workers", 4)? as usize;
    let jobs = opts.u64("jobs", 24)? as usize;
    let nv = jobs.div_ceil(6).max(1);
    let src = workload::ionization_plan(nv, 3, 2);
    let plan = Plan::parse(&src)?;
    let cfg = ExperimentConfig {
        deadline: 3600.0, // wall-clock seconds in live mode
        policy: opts.str("policy", "time"),
        seed: opts.u64("seed", 7)?,
        ..Default::default()
    };
    let specs = expand(&plan, cfg.seed)?;
    let workdir = opts
        .path("workdir")
        .unwrap_or_else(|| std::env::temp_dir().join("nimrod-live"));
    println!(
        "live: {} jobs on {} PJRT workers under {}",
        specs.len(),
        workers,
        workdir.display()
    );
    let outcome = LiveRunner::new(workers, cfg, &workdir).run(specs)?;
    println!("{}", outcome.report.summary());
    for (jid, out) in outcome.outputs.iter().take(5) {
        println!("  {jid}: response={:.4} dose={:.3}", out.response, out.dose);
    }
    if outcome.outputs.len() > 5 {
        println!("  ... {} more", outcome.outputs.len() - 5);
    }
    Ok(())
}
