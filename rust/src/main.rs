//! `nimrod` — the Nimrod/G command-line launcher.
//!
//! Subcommands:
//!
//! ```text
//! nimrod run        --plan FILE | --scenario NAME  [--deadline-h H]
//!                   [--budget G] [--policy P[?k=v]] [--seed S] [--scale X]
//!                   [--user U] [--journal FILE] [--csv DIR]
//! nimrod resume     --journal FILE            restart a crashed experiment
//! nimrod figure3    [--csv DIR] [--seed S]    reproduce the paper's Figure 3
//! nimrod testbed    [--seed S] [--scale X]    dump the GUSTO-like testbed JSON
//! nimrod policies                             list scheduling policies
//! nimrod scenarios                            list scenario presets
//! nimrod live       [--workers N] [--jobs N]  real PJRT execution demo
//! ```
//!
//! Every subcommand takes `--help`; `--verbose` raises log level to info.
//! (Argument parsing is hand-rolled: this image builds offline without
//! clap; see rust/src/util/.)

use anyhow::{bail, Context, Result};
use nimrod_g::broker::{scenarios, Broker, ExperimentBuilder, PolicyRegistry};
use nimrod_g::engine::journal::{recover, Journal};
use nimrod_g::types::HOUR;
use nimrod_g::util::logging;
use nimrod_g::workload;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("nimrod: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

/// Parsed command-line flags: `--key value`, `--key=value`, or a bare
/// boolean `--key` (e.g. `--verbose`, `--help`).
struct Opts {
    flags: BTreeMap<String, Option<String>>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Opts> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = if a == "-h" {
                "help"
            } else if let Some(key) = a.strip_prefix("--") {
                key
            } else {
                bail!("unexpected argument `{a}` (flags look like `--key value`; try --help)");
            };
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), Some(v.to_string()));
                i += 1;
                continue;
            }
            match args.get(i + 1) {
                // A following token that is not itself a flag is this
                // flag's value; otherwise the flag is boolean.
                Some(v) if !v.starts_with("--") && v != "-h" => {
                    flags.insert(key.to_string(), Some(v.clone()));
                    i += 2;
                }
                _ => {
                    flags.insert(key.to_string(), None);
                    i += 1;
                }
            }
        }
        Ok(Opts { flags })
    }

    /// Reject flags outside `known` (help/verbose are always allowed).
    fn expect_known(&self, known: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if key != "help" && key != "verbose" && !known.contains(&key.as_str())
            {
                bail!(
                    "unknown flag --{key} (expected: {})",
                    known
                        .iter()
                        .map(|k| format!("--{k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(())
    }

    /// Boolean flag: present without a value (or with true/false).
    fn bool(&self, key: &str) -> Result<bool> {
        match self.flags.get(key) {
            None => Ok(false),
            Some(None) => Ok(true),
            Some(Some(v)) => match v.as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => bail!("bad --{key} `{other}` (expected true/false)"),
            },
        }
    }

    /// Raw value of a flag that requires one.
    fn value(&self, key: &str) -> Result<Option<&str>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(Some(v)) => Ok(Some(v.as_str())),
            Some(None) => bail!("--{key} needs a value"),
        }
    }

    fn str_opt(&self, key: &str) -> Result<Option<String>> {
        Ok(self.value(key)?.map(String::from))
    }

    fn str(&self, key: &str, default: &str) -> Result<String> {
        Ok(self.value(key)?.unwrap_or(default).to_string())
    }

    fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.value(key)? {
            Some(v) => Ok(Some(
                v.parse().with_context(|| format!("bad --{key} `{v}`"))?,
            )),
            None => Ok(None),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.opt_f64(key)?.unwrap_or(default))
    }

    fn opt_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.value(key)? {
            Some(v) => Ok(Some(
                v.parse().with_context(|| format!("bad --{key} `{v}`"))?,
            )),
            None => Ok(None),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.opt_u64(key)?.unwrap_or(default))
    }

    fn path(&self, key: &str) -> Result<Option<PathBuf>> {
        Ok(self.value(key)?.map(PathBuf::from))
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let opts = Opts::parse(&args[1..])?;
    if opts.bool("verbose")? {
        logging::set_level(logging::Level::Info);
    }
    match cmd.as_str() {
        "run" => cmd_run(&opts),
        "resume" => cmd_resume(&opts),
        "figure3" => cmd_figure3(&opts),
        "testbed" => cmd_testbed(&opts),
        "policies" => cmd_policies(&opts),
        "scenarios" => cmd_scenarios(&opts),
        "live" => cmd_live(&opts),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `nimrod help`)"),
    }
}

fn print_usage() {
    println!(
        "nimrod — Nimrod/G grid resource management and scheduling\n\n\
         usage:\n  nimrod run --plan FILE | --scenario NAME [--deadline-h H] [--budget G$]\n             [--policy NAME[?key=value]] [--seed S] [--scale X] [--user U]\n             [--journal FILE] [--csv DIR] [--threads N] [--scoped-spawn]\n  nimrod resume --journal FILE [--policy NAME] [--scale X] [--csv DIR]\n  nimrod figure3 [--csv DIR] [--seed S]\n  nimrod testbed [--seed S] [--scale X]\n  nimrod policies\n  nimrod scenarios\n  nimrod live [--workers N] [--jobs N] [--policy NAME] [--seed S] [--workdir DIR]\n\n\
         global flags: --help (per subcommand), --verbose\n\n\
         multi-tenant: `nimrod run --scenario contested-gusto` puts N competing\n\
         brokers on one shared grid and reports per-tenant + fairness metrics;\n\
         `nimrod run --scenario grace-auction` runs the GRACE tender/bid market\n\
         (paper §7) and reports agreements + clearing prices;\n\
         `nimrod run --scenario reserve-ahead` adds advance reservations\n\
         (probe → reserve → commit with shadow-schedule costing)"
    );
}

/// Apply the envelope/identity flags shared by experiment subcommands.
fn apply_common(mut b: ExperimentBuilder, opts: &Opts) -> Result<ExperimentBuilder> {
    if let Some(u) = opts.str_opt("user")? {
        b = b.user(&u);
    }
    if let Some(h) = opts.opt_f64("deadline-h")? {
        b = b.deadline_h(h);
    }
    if let Some(g) = opts.opt_f64("budget")? {
        b = b.budget(g);
    }
    if let Some(p) = opts.str_opt("policy")? {
        b = b.policy(&p);
    }
    if let Some(s) = opts.opt_u64("seed")? {
        b = b.seed(s);
    }
    if let Some(x) = opts.opt_f64("scale")? {
        b = b.testbed_scale(x);
    }
    Ok(b)
}

fn write_csvs(report: &nimrod_g::metrics::Report, dir: &Path, tag: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(
        dir.join(format!("{tag}_timeline.csv")),
        report.timeline_csv(300.0),
    )?;
    std::fs::write(
        dir.join(format!("{tag}_resources.csv")),
        report.per_resource_csv(),
    )?;
    println!("wrote {}/{{{tag}_timeline,{tag}_resources}}.csv", dir.display());
    Ok(())
}

fn cmd_run(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!(
            "nimrod run — simulate an experiment on the GUSTO-like testbed\n\n\
             usage: nimrod run --plan FILE | --scenario NAME [flags]\n\n\
             flags:\n  --plan FILE        plan-language experiment description\n  --scenario NAME    start from a preset (see `nimrod scenarios`)\n  --deadline-h H     deadline in virtual hours (default 15)\n  --budget G$        budget (default unlimited)\n  --policy SPEC      scheduling policy, e.g. cost or cost?safety=0.9\n  --seed S           master RNG seed\n  --scale X          testbed machine-count scale (1.0 = ~70 machines)\n  --user U           grid identity to run as\n  --journal FILE     journal state for crash recovery (single-tenant)\n  --csv DIR          write timeline/per-resource CSVs\n  --threads N        worker threads for the batched multi-tenant tick\n                     (default 1 = the sequential reference path; replay\n                     is bit-exact at every thread count)\n  --scoped-spawn     fan batches out via per-batch scoped threads instead\n                     of the persistent worker pool (multi-tenant only;\n                     barrier merge, same bit-exact trace)\n\n\
             multi-tenant scenarios (N brokers on one shared grid, per-tenant\n\
             report + fairness/price metrics):\n  nimrod run --scenario contested-gusto\n  nimrod run --scenario auction-rush\n\
             GRACE tender/bid market scenarios (agreements + clearing prices):\n  nimrod run --scenario grace-auction\n  nimrod run --scenario grace-rush\n\
             advance reservations (probe/reserve/commit, shadow schedules):\n  nimrod run --scenario reserve-ahead\n\
             candidate-index stress (10k machines, churn, 4 tenants):\n  nimrod run --scenario index-storm\n\
             tenant-population stress (256 brokers, batched parallel ticks):\n  nimrod run --scenario world-storm --threads 8\n\
             (--seed/--scale affect the whole world; --policy/--deadline-h/\n\
             --budget/--user retarget tenant 0 only)"
        );
        return Ok(());
    }
    opts.expect_known(&[
        "plan", "scenario", "deadline-h", "budget", "policy", "seed", "scale",
        "user", "journal", "csv", "threads", "scoped-spawn",
    ])?;
    let scenario = opts.str_opt("scenario")?;
    // The journal records only plan + seed + envelope, so `nimrod resume`
    // cannot reconstruct scenario-specific testbed tweaks, competition, or
    // policy — refuse the combination rather than resume onto a different
    // grid silently.
    if scenario.is_some() && opts.value("journal")?.is_some() {
        bail!("--journal cannot be combined with --scenario: resume cannot reconstruct scenario settings; journal a --plan run instead");
    }
    let mut b = match &scenario {
        Some(name) => Broker::scenario(name)?,
        None => Broker::experiment(),
    };
    // The journal needs the plan source so recovery can re-expand specs;
    // scenario presets all run the generated ionization study.
    let plan_src = match opts.path("plan")? {
        Some(plan_path) => {
            let src = std::fs::read_to_string(&plan_path)
                .with_context(|| format!("read plan {}", plan_path.display()))?;
            b = b.plan(src.clone());
            src
        }
        None => {
            if scenario.is_none() {
                bail!("`nimrod run` needs --plan FILE or --scenario NAME (try `nimrod run --help`)");
            }
            workload::ionization_plan(11, 5, 3)
        }
    };
    let mut b = apply_common(b, opts)?;
    if let Some(n) = opts.opt_u64("threads")? {
        b = b.threads(n as usize);
    }
    let cfg = b.config().clone();
    if let Some(name) = &scenario {
        // lint:allow(PANIC-BUDGET): apply_common already resolved this scenario name or bailed with a usage error
        let info = scenarios::describe(name).expect("scenario resolved above");
        println!("scenario {}: {}", info.name, info.summary);
    }
    // Multi-tenant scenarios (contested-gusto, auction-rush) run the whole
    // shared-grid world and report per tenant.
    if b.tenant_count() > 1 {
        if opts.value("journal")?.is_some() {
            bail!("--journal is single-tenant only (multi-tenant scenarios have one journal per tenant, unsupported from the CLI)");
        }
        // Per-tenant envelope flags only retarget the primary broker; say
        // so instead of letting the user believe all tenants changed.
        // (--seed reseeds the whole world; --scale rescales the shared
        // grid.)
        for flag in ["policy", "deadline-h", "budget", "user"] {
            if opts.value(flag)?.is_some() {
                println!(
                    "note: --{flag} applies to tenant 0 only; the other {} tenants keep their preset envelopes",
                    b.tenant_count() - 1
                );
            }
        }
        let mut world = b.world()?;
        if opts.bool("scoped-spawn")? {
            world.set_scoped_spawn(true);
        }
        println!(
            "world: {} tenants on {} resources / {} cpus across {} sites",
            world.tenant_count(),
            world.tb.resources.len(),
            world.tb.total_cpus(),
            world.tb.sites.len()
        );
        let wr = world.run_world();
        println!("{}", wr.summary());
        if let Some(dir) = opts.path("csv")? {
            std::fs::create_dir_all(&dir)?;
            std::fs::write(dir.join("run_tenants.csv"), wr.per_tenant_csv())?;
            std::fs::write(dir.join("run_prices.csv"), wr.price_csv())?;
            let mut wrote = "run_tenants,run_prices".to_string();
            if wr.has_market_data() {
                std::fs::write(dir.join("run_auction.csv"), wr.auction_csv())?;
                wrote.push_str(",run_auction");
            }
            println!("wrote {}/{{{wrote}}}.csv", dir.display());
        }
        return Ok(());
    }
    let mut sim = b.simulate()?;
    println!(
        "experiment: {} jobs, deadline {:.1} h, policy {}, budget {}",
        sim.exp().jobs.len(),
        cfg.deadline / HOUR,
        cfg.policy,
        cfg.budget
            .map(|b| format!("{b:.0} G$"))
            .unwrap_or_else(|| "unlimited".into()),
    );
    println!(
        "testbed: {} resources / {} cpus across {} sites",
        sim.tb().resources.len(),
        sim.tb().total_cpus(),
        sim.tb().sites.len()
    );
    if let Some(journal_path) = opts.path("journal")? {
        let journal =
            Journal::create(&journal_path, &plan_src, cfg.seed, sim.exp())?;
        sim = sim.with_journal(journal);
    }
    let report = sim.run();
    println!("{}", report.summary());
    if let Some(dir) = opts.path("csv")? {
        write_csvs(&report, &dir, "run")?;
    }
    Ok(())
}

fn cmd_resume(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!(
            "nimrod resume — restart a journaled experiment after a crash\n\n\
             usage: nimrod resume --journal FILE [--policy SPEC] [--scale X] [--csv DIR]"
        );
        return Ok(());
    }
    opts.expect_known(&["journal", "policy", "scale", "csv"])?;
    let journal_path = opts
        .path("journal")?
        .context("`nimrod resume` needs --journal FILE")?;
    let rec = recover(&journal_path)?;
    println!(
        "recovered: {}/{} jobs done, {} remaining",
        rec.experiment.completed(),
        rec.experiment.jobs.len(),
        rec.experiment.remaining()
    );
    let mut b = Broker::experiment()
        .seed(rec.seed)
        .deadline_s(rec.experiment.deadline)
        .policy(&opts.str("policy", "cost")?)
        .testbed_scale(opts.f64("scale", 1.0)?);
    if let Some(budget) = rec.experiment.budget {
        b = b.budget(budget);
    }
    let journal = Journal::append_to(&journal_path)?;
    let sim = b
        .resume(rec.experiment)
        .simulate()?
        .with_journal(journal);
    let report = sim.run();
    println!("{}", report.summary());
    if let Some(dir) = opts.path("csv")? {
        write_csvs(&report, &dir, "resume")?;
    }
    Ok(())
}

fn cmd_figure3(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!(
            "nimrod figure3 — reproduce the paper's Figure 3 deadline sweep\n\n\
             usage: nimrod figure3 [--csv DIR] [--seed S]"
        );
        return Ok(());
    }
    opts.expect_known(&["csv", "seed"])?;
    let seed = opts.u64("seed", 0xD15EA5E)?;
    let csv_dir = opts.path("csv")?;
    println!("Figure 3: GUSTO resource usage for 10 / 15 / 20 hour deadlines");
    println!("(165-job ionization chamber calibration, cost-optimizing DBC)\n");
    for deadline_h in [10.0, 15.0, 20.0] {
        let report = Broker::experiment()
            .deadline_h(deadline_h)
            .policy("cost")
            .seed(seed)
            .run()?;
        println!("deadline {deadline_h:>4.0} h: {}", report.summary());
        println!(
            "              avg {:.1} busy cpus over the run",
            report.busy_cpus.average(report.makespan_s.max(1.0))
        );
        if let Some(dir) = &csv_dir {
            write_csvs(&report, dir, &format!("figure3_{}h", deadline_h as u32))?;
        }
    }
    Ok(())
}

fn cmd_testbed(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!(
            "nimrod testbed — dump the generated GUSTO-like testbed as JSON\n\n\
             usage: nimrod testbed [--seed S] [--scale X]"
        );
        return Ok(());
    }
    opts.expect_known(&["seed", "scale"])?;
    let tb = nimrod_g::grid::Testbed::gusto(
        opts.u64("seed", 0xD15EA5E)?,
        opts.f64("scale", 1.0)?,
    );
    println!("{}", tb.to_json().to_string());
    Ok(())
}

fn cmd_policies(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!("nimrod policies — list registered scheduling policies");
        return Ok(());
    }
    opts.expect_known(&[])?;
    for name in PolicyRegistry::with_builtins().names() {
        println!("{name}");
    }
    println!("\n(parameterized specs accepted, e.g. cost?safety=0.9, fixed-rate?max-rate=2)");
    Ok(())
}

fn cmd_scenarios(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!("nimrod scenarios — list named experiment presets for `nimrod run --scenario`");
        return Ok(());
    }
    opts.expect_known(&[])?;
    for info in &scenarios::CATALOG {
        println!("{:<16} {}", info.name, info.summary);
    }
    Ok(())
}

fn cmd_live(opts: &Opts) -> Result<()> {
    if opts.bool("help")? {
        println!(
            "nimrod live — run real PJRT compute on worker threads\n\n\
             usage: nimrod live [--workers N] [--jobs N] [--policy SPEC] [--seed S] [--workdir DIR]\n\n\
             requires `make artifacts` to have produced the AOT chamber model"
        );
        return Ok(());
    }
    opts.expect_known(&["workers", "jobs", "policy", "seed", "workdir"])?;
    let workers = opts.u64("workers", 4)? as usize;
    let jobs = opts.u64("jobs", 24)? as usize;
    let nv = jobs.div_ceil(6).max(1);
    let src = workload::ionization_plan(nv, 3, 2);
    let workdir = opts
        .path("workdir")?
        .unwrap_or_else(|| std::env::temp_dir().join("nimrod-live"));
    let live = Broker::experiment()
        .plan(src)
        .deadline_s(3600.0) // wall-clock seconds in live mode
        .policy(&opts.str("policy", "time")?)
        .seed(opts.u64("seed", 7)?)
        .live(workers, &workdir)?;
    println!(
        "live: {} jobs on {} PJRT workers under {}",
        live.job_count(),
        workers,
        workdir.display()
    );
    let outcome = live.run()?;
    println!("{}", outcome.report.summary());
    for (jid, out) in outcome.outputs.iter().take(5) {
        println!("  {jid}: response={:.4} dose={:.3}", out.response, out.dose);
    }
    if outcome.outputs.len() > 5 {
        println!("  ... {} more", outcome.outputs.len() - 5);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Opts;

    fn parse(args: &[&str]) -> anyhow::Result<Opts> {
        Opts::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn key_value_pairs_parse() {
        let o = parse(&["--plan", "exp.pln", "--seed", "42"]).unwrap();
        assert_eq!(o.str("plan", "").unwrap(), "exp.pln");
        assert_eq!(o.u64("seed", 0).unwrap(), 42);
        assert_eq!(o.u64("missing", 7).unwrap(), 7);
    }

    #[test]
    fn boolean_flags_parse() {
        let o = parse(&["--verbose", "--plan", "x"]).unwrap();
        assert!(o.bool("verbose").unwrap());
        assert!(!o.bool("help").unwrap());
        // A flag at the end of the line is boolean too.
        let o = parse(&["--plan", "x", "--help"]).unwrap();
        assert!(o.bool("help").unwrap());
        // Explicit values still work.
        let o = parse(&["--verbose", "false"]).unwrap();
        assert!(!o.bool("verbose").unwrap());
    }

    #[test]
    fn key_equals_value_form() {
        let o = parse(&["--seed=9", "--policy=cost?safety=0.9"]).unwrap();
        assert_eq!(o.u64("seed", 0).unwrap(), 9);
        assert_eq!(o.str("policy", "").unwrap(), "cost?safety=0.9");
    }

    #[test]
    fn value_flags_reject_missing_values() {
        // `--plan --help` leaves plan valueless: accessors must error.
        let o = parse(&["--plan", "--help"]).unwrap();
        assert!(o.path("plan").is_err());
        assert!(o.bool("help").unwrap());
        let o = parse(&["--seed"]).unwrap();
        assert!(o.u64("seed", 1).is_err());
    }

    #[test]
    fn h_alias_and_errors() {
        let o = parse(&["-h"]).unwrap();
        assert!(o.bool("help").unwrap());
        assert!(parse(&["loose-word"]).is_err());
        let o = parse(&["--seed", "abc"]).unwrap();
        assert!(o.u64("seed", 0).is_err());
    }

    #[test]
    fn unknown_flags_detected() {
        let o = parse(&["--plan", "x", "--bogus", "1"]).unwrap();
        assert!(o.expect_known(&["plan"]).is_err());
        assert!(o.expect_known(&["plan", "bogus"]).is_ok());
        // help/verbose are always allowed.
        let o = parse(&["--verbose", "--help"]).unwrap();
        assert!(o.expect_known(&[]).is_ok());
    }
}
