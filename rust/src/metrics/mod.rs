//! Experiment metrics: the processors-in-use timeline (the y-axis of the
//! paper's Figure 3), cost/makespan summaries, CSV emission, and — for
//! multi-tenant worlds — the per-tenant breakdown with cross-tenant
//! fairness and price-trajectory figures ([`WorldReport`]).

use crate::types::{GridDollars, SimTime};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Step timeline of an integer quantity (busy processors). Records only
/// changes; queries interpolate as a step function.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    points: Vec<(SimTime, u32)>,
}

impl Timeline {
    /// Record the value at `t` (must be non-decreasing in `t`).
    pub fn record(&mut self, t: SimTime, value: u32) {
        if let Some(&(last_t, last_v)) = self.points.last() {
            debug_assert!(t >= last_t, "timeline time went backwards");
            if last_v == value {
                return;
            }
            if last_t == t {
                self.points.pop();
            }
        }
        self.points.push((t, value));
    }

    /// Value at time `t` (0 before the first record).
    pub fn at(&self, t: SimTime) -> u32 {
        match self.points.binary_search_by(|(pt, _)| pt.total_cmp(&t)) {
            Ok(i) => self.points[i].1,
            Err(0) => 0,
            Err(i) => self.points[i - 1].1,
        }
    }

    /// Peak value.
    pub fn peak(&self) -> u32 {
        self.points.iter().map(|&(_, v)| v).max().unwrap_or(0)
    }

    /// Time-weighted average over `[0, horizon]`.
    pub fn average(&self, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut prev_t = 0.0;
        let mut prev_v = 0u32;
        for &(t, v) in &self.points {
            if t >= horizon {
                break;
            }
            acc += (t - prev_t) * prev_v as f64;
            prev_t = t;
            prev_v = v;
        }
        acc += (horizon - prev_t).max(0.0) * prev_v as f64;
        acc / horizon
    }

    /// Resample onto a regular grid (for CSV/plotting): `(t, value)` rows
    /// every `dt` from 0 to `horizon` inclusive.
    pub fn sample(&self, dt: SimTime, horizon: SimTime) -> Vec<(SimTime, u32)> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while t <= horizon + 1e-9 {
            out.push((t, self.at(t)));
            t += dt;
        }
        out
    }

    pub fn points(&self) -> &[(SimTime, u32)] {
        &self.points
    }
}

/// Per-resource usage rollup.
#[derive(Debug, Clone, Default)]
pub struct ResourceUsage {
    pub jobs_completed: u32,
    pub jobs_failed: u32,
    pub cpu_seconds: f64,
    pub cost: GridDollars,
}

/// Final report for one experiment run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Virtual time the last job finished (0 if none ran).
    pub makespan_s: SimTime,
    pub deadline_s: SimTime,
    pub deadline_met: bool,
    pub jobs_total: u32,
    pub jobs_completed: u32,
    pub jobs_failed: u32,
    pub total_cost: GridDollars,
    /// Busy grid CPUs over time (Figure 3's y-axis).
    pub busy_cpus: Timeline,
    /// Distinct resources that ran at least one job.
    pub resources_used: u32,
    pub per_resource: BTreeMap<String, ResourceUsage>,
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// Simulator events processed.
    pub events: u64,
    /// Resource-view entries rebuilt across the run — the incremental
    /// tick pipeline's work counter (a full-rebuild driver pays
    /// `ticks × resources` here; the event-driven table pays O(changed)).
    pub view_refreshes: u64,
    /// Wall nanoseconds spent in the allocation phase (policy selection +
    /// dispatcher reconciliation) across all ticks. A host-clock figure
    /// for the perf benches — it never feeds back into the simulation, so
    /// traces stay deterministic; exclude it from bit-exact comparisons.
    pub alloc_ns: u64,
    /// Wall nanoseconds of the three-phase batched tick (snapshot /
    /// parallel per-tenant work / merge barrier) — world-level totals,
    /// populated on the [`WorldReport::into_single`] return path so the
    /// single-report API surfaces them too. Host-clock telemetry like
    /// `alloc_ns`: never fed back into the simulation, excluded from
    /// bit-exact comparisons.
    pub snapshot_ns: u64,
    pub parallel_ns: u64,
    pub merge_ns: u64,
}

impl Report {
    /// Total CPU-seconds consumed across resources (completed jobs).
    pub fn cpu_seconds(&self) -> f64 {
        self.per_resource.values().map(|u| u.cpu_seconds).sum()
    }

    /// One-line summary (CLI output).
    pub fn summary(&self) -> String {
        format!(
            "jobs {}/{} done ({} failed), makespan {:.2} h (deadline {:.1} h, {}), cost {:.0} G$, peak {} cpus on {} resources",
            self.jobs_completed,
            self.jobs_total,
            self.jobs_failed,
            self.makespan_s / 3600.0,
            self.deadline_s / 3600.0,
            if self.deadline_met { "met" } else { "MISSED" },
            self.total_cost,
            self.busy_cpus.peak(),
            self.resources_used,
        )
    }

    /// CSV of the busy-processor timeline: `hours,busy_cpus` rows.
    pub fn timeline_csv(&self, dt: SimTime) -> String {
        let horizon = self.makespan_s.max(self.deadline_s);
        let mut out = String::from("hours,busy_cpus\n");
        for (t, v) in self.busy_cpus.sample(dt, horizon) {
            let _ = writeln!(out, "{:.3},{v}", t / 3600.0);
        }
        out
    }

    /// CSV of per-resource usage.
    pub fn per_resource_csv(&self) -> String {
        let mut out =
            String::from("resource,jobs_completed,jobs_failed,cpu_hours,cost_gd\n");
        for (name, u) in &self.per_resource {
            let _ = writeln!(
                out,
                "{name},{},{},{:.3},{:.2}",
                u.jobs_completed,
                u.jobs_failed,
                u.cpu_seconds / 3600.0,
                u.cost
            );
        }
        out
    }
}

/// One tenant's outcome inside a multi-tenant world run.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    /// Grid identity the tenant ran as.
    pub user: String,
    /// Policy spec the tenant scheduled with (e.g. `cost?safety=0.9`).
    pub policy: String,
    /// GRACE market: price agreements this tenant won across the run
    /// (0 in posted-price worlds).
    pub agreements_won: u32,
    /// Total tender rounds this tenant's negotiations used, successful or
    /// not (the tenant's whole market effort).
    pub negotiation_rounds: u64,
    /// Tender rounds spent by *successful* negotiations only — the figure
    /// behind [`WorldReport::rounds_per_agreement`].
    pub deal_rounds: u64,
    /// Negotiations that ended without a feasible bid set.
    pub failed_negotiations: u32,
    /// Advance reservations: shadow-schedule probe quotes issued (0 when
    /// the subsystem is off).
    pub reservation_probes: u64,
    /// Holds hardened into binding commitments.
    pub reservations_committed: u32,
    /// Holds dropped before use — free cancellations plus expiries.
    pub reservations_cancelled: u32,
    /// Σ over held slots of seconds between entering and leaving a hold.
    pub held_slot_seconds: f64,
    /// Cancellation penalties billed through the ledger, G$.
    pub penalty_spend: GridDollars,
    pub report: Report,
}

/// Final report for a [`crate::sim::GridWorld`] run: every tenant's
/// [`Report`] plus the cross-tenant figures a shared grid produces —
/// fairness of the CPU split and the demand-driven price trajectory.
#[derive(Debug, Clone)]
pub struct WorldReport {
    pub tenants: Vec<TenantOutcome>,
    /// Simulator events processed across the whole world.
    pub events: u64,
    /// Mean posted effective G$/CPU-second across up machines (competition
    /// + demand premiums included), sampled at each directory refresh.
    pub price_index: Vec<(SimTime, GridDollars)>,
    /// Highest combined premium factor observed at any sample (1.0 = no
    /// repricing ever happened).
    pub peak_premium: f64,
    /// GRACE market: mean awarded G$/CPU-second per auction sweep that
    /// produced at least one agreement — the clearing-price trajectory.
    /// Empty in posted-price worlds.
    pub clearing_prices: Vec<(SimTime, GridDollars)>,
    /// Wall nanoseconds the batched tick pipeline spent building the
    /// shared-state snapshot (phase 1), summed over every coincident-tick
    /// batch. Host-clock telemetry like [`Report::alloc_ns`] — it never
    /// feeds back into the simulation; exclude it from bit-exact
    /// comparisons. Zero in worlds whose tenants never tick at the same
    /// instant (every batch is then a singleton on the legacy path).
    pub snapshot_ns: u64,
    /// Wall nanoseconds of phase 2 — the parallel per-tenant section
    /// (view refresh, index re-key, policy allocation), wall-clock across
    /// all workers, not summed per worker.
    pub parallel_ns: u64,
    /// Wall nanoseconds of phase 3 — the deterministic ordered merge that
    /// applies tenant deltas in ascending tenant order (streamed under
    /// phase 2 by default, drained behind a barrier under
    /// `set_barrier_merge`).
    pub merge_ns: u64,
    /// The slice of `merge_ns` that ran while phase-2 shards were still
    /// in flight — the merge wall-time the streaming commit queue hid
    /// under the parallel phase. Always 0 in barrier-merge, scoped-spawn
    /// and sequential worlds.
    pub merge_overlap_ns: u64,
    /// Lanes of the persistent phase-2 worker pool (spawned workers plus
    /// the participating caller). 0 when no pool was ever built: a
    /// sequential world, a `set_scoped_spawn` bench run, or a world whose
    /// ticks never coincided.
    pub pool_workers: u32,
    /// Coincident-tick batches fanned out through the persistent pool.
    pub pool_rounds: u64,
}

impl Default for WorldReport {
    /// Manual impl so `peak_premium` starts at its documented no-repricing
    /// value of 1.0 (a derived 0.0 would read as "below posted rates").
    fn default() -> Self {
        WorldReport {
            tenants: Vec::new(),
            events: 0,
            price_index: Vec::new(),
            peak_premium: 1.0,
            clearing_prices: Vec::new(),
            snapshot_ns: 0,
            parallel_ns: 0,
            merge_ns: 0,
            merge_overlap_ns: 0,
            pool_workers: 0,
            pool_rounds: 0,
        }
    }
}

impl WorldReport {
    /// Collapse a single-tenant world into its tenant's report (the
    /// [`crate::sim::GridSimulation`] return path).
    pub fn into_single(mut self) -> Report {
        assert_eq!(self.tenants.len(), 1, "into_single on a multi-tenant run");
        let mut report = self.tenants.remove(0).report;
        report.snapshot_ns = self.snapshot_ns;
        report.parallel_ns = self.parallel_ns;
        report.merge_ns = self.merge_ns;
        report
    }

    /// Jain's fairness index over the tenants' realized CPU-second shares:
    /// 1.0 when every tenant got the same grid share, → 1/N under total
    /// capture by one tenant. 1.0 for empty/idle worlds by convention.
    pub fn fairness_jain(&self) -> f64 {
        let xs: Vec<f64> = self
            .tenants
            .iter()
            .map(|t| t.report.cpu_seconds())
            .collect();
        let n = xs.len() as f64;
        let sum: f64 = xs.iter().sum();
        let sumsq: f64 = xs.iter().map(|x| x * x).sum();
        if n == 0.0 || sum <= 0.0 || sumsq <= 0.0 {
            return 1.0;
        }
        (sum * sum) / (n * sumsq)
    }

    /// Relative swing of the price index over the run: `max/min - 1`
    /// (0 when prices never moved, or with fewer than two samples).
    pub fn price_swing(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &(_, p) in &self.price_index {
            lo = lo.min(p);
            hi = hi.max(p);
        }
        if !lo.is_finite() || !hi.is_finite() || lo <= 0.0 {
            return 0.0;
        }
        hi / lo - 1.0
    }

    /// True when the world ran a GRACE market: any tender activity at all
    /// (won agreements, failed negotiations, or clearing-price samples).
    pub fn has_market_data(&self) -> bool {
        !self.clearing_prices.is_empty()
            || self.tenants.iter().any(|t| {
                t.agreements_won > 0
                    || t.failed_negotiations > 0
                    || t.negotiation_rounds > 0
            })
    }

    /// Agreements won across all tenants.
    pub fn agreements_won(&self) -> u32 {
        self.tenants.iter().map(|t| t.agreements_won).sum()
    }

    /// True when the world ran the advance-reservation subsystem: any
    /// probe, commitment or cancellation at all.
    pub fn has_reservation_data(&self) -> bool {
        self.tenants.iter().any(|t| {
            t.reservation_probes > 0
                || t.reservations_committed > 0
                || t.reservations_cancelled > 0
        })
    }

    /// Reservations committed across all tenants.
    pub fn reservations_committed(&self) -> u32 {
        self.tenants.iter().map(|t| t.reservations_committed).sum()
    }

    /// Cancellation-penalty spend across all tenants, G$.
    pub fn penalty_spend(&self) -> GridDollars {
        self.tenants.iter().map(|t| t.penalty_spend).sum()
    }

    /// Mean tender rounds behind each won agreement (0 when none), counting
    /// only the rounds of negotiations that actually produced a deal —
    /// failed negotiations' rounds live in
    /// [`TenantOutcome::negotiation_rounds`] instead. Can sit below 1: a
    /// single negotiation round may award a whole bid set.
    pub fn rounds_per_agreement(&self) -> f64 {
        let agreements = self.agreements_won();
        if agreements == 0 {
            return 0.0;
        }
        let rounds: u64 = self.tenants.iter().map(|t| t.deal_rounds).sum();
        rounds as f64 / agreements as f64
    }

    /// Each tenant's share of all agreements won, in tenant order (all
    /// zeros when no agreements were struck).
    pub fn award_share(&self) -> Vec<f64> {
        let total = self.agreements_won();
        self.tenants
            .iter()
            .map(|t| {
                if total == 0 {
                    0.0
                } else {
                    t.agreements_won as f64 / total as f64
                }
            })
            .collect()
    }

    /// Multi-line summary: one line per tenant plus the cross-tenant
    /// fairness/pricing figures (CLI output).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for t in &self.tenants {
            let _ = writeln!(
                out,
                "tenant {:<12} [{}] {}",
                t.user,
                t.policy,
                t.report.summary()
            );
        }
        let _ = write!(
            out,
            "world: {} tenants, {} events, fairness {:.3} (Jain), price swing {:+.1}%, peak premium {:.2}x",
            self.tenants.len(),
            self.events,
            self.fairness_jain(),
            self.price_swing() * 100.0,
            self.peak_premium,
        );
        if self.has_market_data() {
            let shares = self
                .award_share()
                .iter()
                .map(|s| format!("{:.0}%", s * 100.0))
                .collect::<Vec<_>>()
                .join("/");
            let failed: u32 =
                self.tenants.iter().map(|t| t.failed_negotiations).sum();
            let _ = write!(
                out,
                "\ngrace: {} agreements ({:.1} rounds/agreement), {} failed negotiations, award share {}",
                self.agreements_won(),
                self.rounds_per_agreement(),
                failed,
                shares,
            );
        }
        if self.has_reservation_data() {
            let probes: u64 =
                self.tenants.iter().map(|t| t.reservation_probes).sum();
            let cancelled: u32 =
                self.tenants.iter().map(|t| t.reservations_cancelled).sum();
            let held: f64 =
                self.tenants.iter().map(|t| t.held_slot_seconds).sum();
            let _ = write!(
                out,
                "\nreservations: {} committed ({} cancelled/expired), {} probes, {:.0} held slot-s, {:.2} G$ penalties",
                self.reservations_committed(),
                cancelled,
                probes,
                held,
                self.penalty_spend(),
            );
        }
        out
    }

    /// CSV of per-tenant outcomes (auction columns are zero in
    /// posted-price worlds).
    pub fn per_tenant_csv(&self) -> String {
        let mut out = String::from(
            "user,policy,jobs_total,jobs_completed,jobs_failed,makespan_h,deadline_h,deadline_met,cost_gd,cpu_hours,agreements_won,negotiation_rounds,deal_rounds,failed_negotiations,res_probes,res_committed,res_cancelled,held_slot_s,penalty_gd\n",
        );
        for t in &self.tenants {
            let r = &t.report;
            let _ = writeln!(
                out,
                "{},{},{},{},{},{:.3},{:.1},{},{:.2},{:.3},{},{},{},{},{},{},{},{:.1},{:.2}",
                t.user,
                t.policy,
                r.jobs_total,
                r.jobs_completed,
                r.jobs_failed,
                r.makespan_s / 3600.0,
                r.deadline_s / 3600.0,
                r.deadline_met,
                r.total_cost,
                r.cpu_seconds() / 3600.0,
                t.agreements_won,
                t.negotiation_rounds,
                t.deal_rounds,
                t.failed_negotiations,
                t.reservation_probes,
                t.reservations_committed,
                t.reservations_cancelled,
                t.held_slot_seconds,
                t.penalty_spend,
            );
        }
        out
    }

    /// CSV of the price trajectory: `hours,mean_rate_gd_per_cpu_s` rows.
    pub fn price_csv(&self) -> String {
        let mut out = String::from("hours,mean_rate_gd_per_cpu_s\n");
        for &(t, p) in &self.price_index {
            let _ = writeln!(out, "{:.3},{p:.6}", t / 3600.0);
        }
        out
    }

    /// CSV of the auction clearing-price trajectory:
    /// `hours,mean_clearing_rate_gd_per_cpu_s` rows (header only in
    /// posted-price worlds).
    pub fn auction_csv(&self) -> String {
        let mut out = String::from("hours,mean_clearing_rate_gd_per_cpu_s\n");
        for &(t, p) in &self.clearing_prices {
            let _ = writeln!(out, "{:.3},{p:.6}", t / 3600.0);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_step_semantics() {
        let mut tl = Timeline::default();
        tl.record(0.0, 0);
        tl.record(10.0, 3);
        tl.record(20.0, 1);
        assert_eq!(tl.at(-1.0), 0);
        assert_eq!(tl.at(5.0), 0);
        assert_eq!(tl.at(10.0), 3);
        assert_eq!(tl.at(15.0), 3);
        assert_eq!(tl.at(25.0), 1);
        assert_eq!(tl.peak(), 3);
    }

    #[test]
    fn duplicate_values_coalesce() {
        let mut tl = Timeline::default();
        tl.record(0.0, 2);
        tl.record(5.0, 2);
        tl.record(6.0, 2);
        assert_eq!(tl.points().len(), 1);
    }

    #[test]
    fn same_instant_overwrites() {
        let mut tl = Timeline::default();
        tl.record(1.0, 1);
        tl.record(1.0, 5);
        assert_eq!(tl.at(1.0), 5);
        assert_eq!(tl.points().len(), 1);
    }

    #[test]
    fn average_time_weighted() {
        let mut tl = Timeline::default();
        tl.record(0.0, 4);
        tl.record(5.0, 0);
        // 4 for half the horizon, 0 after.
        assert!((tl.average(10.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sampling_grid() {
        let mut tl = Timeline::default();
        tl.record(0.0, 1);
        tl.record(3.0, 2);
        let s = tl.sample(1.0, 4.0);
        assert_eq!(
            s,
            vec![(0.0, 1), (1.0, 1), (2.0, 1), (3.0, 2), (4.0, 2)]
        );
    }

    #[test]
    fn report_csv_shapes() {
        let mut r = Report {
            jobs_total: 2,
            jobs_completed: 2,
            makespan_s: 7200.0,
            deadline_s: 7200.0,
            deadline_met: true,
            ..Default::default()
        };
        r.busy_cpus.record(0.0, 1);
        r.per_resource.insert(
            "lemon0.anl.gov".into(),
            ResourceUsage {
                jobs_completed: 2,
                jobs_failed: 0,
                cpu_seconds: 3600.0,
                cost: 12.5,
            },
        );
        let csv = r.timeline_csv(3600.0);
        assert!(csv.starts_with("hours,busy_cpus\n"));
        assert_eq!(csv.lines().count(), 1 + 3); // header + 0,1,2 h
        let pr = r.per_resource_csv();
        assert!(pr.contains("lemon0.anl.gov,2,0,1.000,12.50"));
        assert!(r.summary().contains("met"));
    }

    fn tenant(user: &str, cpu_s: f64) -> TenantOutcome {
        let mut report = Report::default();
        report.per_resource.insert(
            "m".into(),
            ResourceUsage {
                jobs_completed: 1,
                jobs_failed: 0,
                cpu_seconds: cpu_s,
                cost: 1.0,
            },
        );
        TenantOutcome {
            user: user.into(),
            policy: "cost".into(),
            agreements_won: 0,
            negotiation_rounds: 0,
            deal_rounds: 0,
            failed_negotiations: 0,
            reservation_probes: 0,
            reservations_committed: 0,
            reservations_cancelled: 0,
            held_slot_seconds: 0.0,
            penalty_spend: 0.0,
            report,
        }
    }

    #[test]
    fn jain_fairness_bounds() {
        let even = WorldReport {
            tenants: vec![tenant("a", 100.0), tenant("b", 100.0)],
            ..Default::default()
        };
        assert!((even.fairness_jain() - 1.0).abs() < 1e-12);
        let skewed = WorldReport {
            tenants: vec![tenant("a", 1000.0), tenant("b", 0.0)],
            ..Default::default()
        };
        assert!((skewed.fairness_jain() - 0.5).abs() < 1e-12);
        // Empty world: 1.0 by convention, never NaN.
        assert_eq!(WorldReport::default().fairness_jain(), 1.0);
    }

    #[test]
    fn price_swing_and_csvs() {
        let wr = WorldReport {
            tenants: vec![tenant("a", 10.0)],
            events: 5,
            price_index: vec![(0.0, 1.0), (3600.0, 1.5), (7200.0, 1.2)],
            peak_premium: 1.5,
            ..Default::default()
        };
        assert!((wr.price_swing() - 0.5).abs() < 1e-12);
        assert!(wr.summary().contains("fairness"));
        assert!(wr.summary().contains("tenant a"));
        let csv = wr.per_tenant_csv();
        assert!(csv.starts_with("user,policy,"));
        assert_eq!(csv.lines().count(), 2);
        let pcsv = wr.price_csv();
        assert_eq!(pcsv.lines().count(), 4);
        assert!(pcsv.contains("1.000,1.500000"));
        // No samples ⇒ no swing, not NaN.
        assert_eq!(WorldReport::default().price_swing(), 0.0);
    }

    #[test]
    fn auction_figures_and_csv() {
        // Posted-price worlds carry no market data and say nothing about it.
        let posted = WorldReport {
            tenants: vec![tenant("a", 10.0)],
            ..Default::default()
        };
        assert!(!posted.has_market_data());
        assert!(!posted.summary().contains("grace:"));
        assert_eq!(posted.rounds_per_agreement(), 0.0);
        assert_eq!(posted.award_share(), vec![0.0]);
        assert_eq!(posted.auction_csv().lines().count(), 1); // header only

        // An auction world reports agreements, rounds and award shares.
        let mut a = tenant("a", 10.0);
        a.agreements_won = 6;
        a.deal_rounds = 9;
        a.negotiation_rounds = 9;
        let mut b = tenant("b", 10.0);
        b.agreements_won = 2;
        b.deal_rounds = 7;
        // Failed negotiations burn rounds too, but those must not inflate
        // the rounds-per-agreement figure.
        b.negotiation_rounds = 7 + 15;
        b.failed_negotiations = 3;
        let wr = WorldReport {
            tenants: vec![a, b],
            clearing_prices: vec![(3600.0, 0.8), (7200.0, 1.1)],
            ..Default::default()
        };
        assert!(wr.has_market_data());
        assert_eq!(wr.agreements_won(), 8);
        assert!((wr.rounds_per_agreement() - 2.0).abs() < 1e-12);
        let share = wr.award_share();
        assert!((share[0] - 0.75).abs() < 1e-12);
        assert!((share[1] - 0.25).abs() < 1e-12);
        let s = wr.summary();
        assert!(s.contains("grace: 8 agreements"), "{s}");
        assert!(s.contains("3 failed negotiations"), "{s}");
        let acsv = wr.auction_csv();
        assert_eq!(acsv.lines().count(), 3);
        assert!(acsv.contains("1.000,0.800000"));
        // Per-tenant CSV carries the auction columns, deal_rounds included
        // so rounds_per_agreement is reproducible from the export.
        let tcsv = wr.per_tenant_csv();
        assert!(tcsv.lines().next().unwrap().ends_with(
            "agreements_won,negotiation_rounds,deal_rounds,failed_negotiations,res_probes,res_committed,res_cancelled,held_slot_s,penalty_gd"
        ));
        assert!(tcsv.contains(",6,9,9,0,"), "{tcsv}");
        assert!(tcsv.contains(",2,22,7,3,"), "{tcsv}");
    }

    #[test]
    fn reservation_figures_and_csv() {
        // Worlds without the subsystem carry no reservation data and say
        // nothing about it.
        let off = WorldReport {
            tenants: vec![tenant("a", 10.0)],
            ..Default::default()
        };
        assert!(!off.has_reservation_data());
        assert!(!off.summary().contains("reservations:"));
        assert!(off.per_tenant_csv().contains(",0,0,0,0.0,0.00"));

        let mut a = tenant("a", 10.0);
        a.reservation_probes = 12;
        a.reservations_committed = 3;
        a.reservations_cancelled = 2;
        a.held_slot_seconds = 5400.0;
        a.penalty_spend = 42.5;
        let mut b = tenant("b", 10.0);
        b.reservation_probes = 4;
        b.reservations_committed = 1;
        let wr = WorldReport {
            tenants: vec![a, b],
            ..Default::default()
        };
        assert!(wr.has_reservation_data());
        assert_eq!(wr.reservations_committed(), 4);
        assert!((wr.penalty_spend() - 42.5).abs() < 1e-12);
        let s = wr.summary();
        assert!(s.contains("reservations: 4 committed"), "{s}");
        assert!(s.contains("16 probes"), "{s}");
        assert!(s.contains("42.50 G$ penalties"), "{s}");
        let tcsv = wr.per_tenant_csv();
        assert!(tcsv.contains(",12,3,2,5400.0,42.50"), "{tcsv}");
        assert!(tcsv.contains(",4,1,0,0.0,0.00"), "{tcsv}");
    }
}
