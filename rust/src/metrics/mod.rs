//! Experiment metrics: the processors-in-use timeline (the y-axis of the
//! paper's Figure 3), cost/makespan summaries, and CSV emission.

use crate::types::{GridDollars, SimTime};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Step timeline of an integer quantity (busy processors). Records only
/// changes; queries interpolate as a step function.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    points: Vec<(SimTime, u32)>,
}

impl Timeline {
    /// Record the value at `t` (must be non-decreasing in `t`).
    pub fn record(&mut self, t: SimTime, value: u32) {
        if let Some(&(last_t, last_v)) = self.points.last() {
            debug_assert!(t >= last_t, "timeline time went backwards");
            if last_v == value {
                return;
            }
            if last_t == t {
                self.points.pop();
            }
        }
        self.points.push((t, value));
    }

    /// Value at time `t` (0 before the first record).
    pub fn at(&self, t: SimTime) -> u32 {
        match self.points.binary_search_by(|(pt, _)| pt.total_cmp(&t)) {
            Ok(i) => self.points[i].1,
            Err(0) => 0,
            Err(i) => self.points[i - 1].1,
        }
    }

    /// Peak value.
    pub fn peak(&self) -> u32 {
        self.points.iter().map(|&(_, v)| v).max().unwrap_or(0)
    }

    /// Time-weighted average over `[0, horizon]`.
    pub fn average(&self, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut prev_t = 0.0;
        let mut prev_v = 0u32;
        for &(t, v) in &self.points {
            if t >= horizon {
                break;
            }
            acc += (t - prev_t) * prev_v as f64;
            prev_t = t;
            prev_v = v;
        }
        acc += (horizon - prev_t).max(0.0) * prev_v as f64;
        acc / horizon
    }

    /// Resample onto a regular grid (for CSV/plotting): `(t, value)` rows
    /// every `dt` from 0 to `horizon` inclusive.
    pub fn sample(&self, dt: SimTime, horizon: SimTime) -> Vec<(SimTime, u32)> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while t <= horizon + 1e-9 {
            out.push((t, self.at(t)));
            t += dt;
        }
        out
    }

    pub fn points(&self) -> &[(SimTime, u32)] {
        &self.points
    }
}

/// Per-resource usage rollup.
#[derive(Debug, Clone, Default)]
pub struct ResourceUsage {
    pub jobs_completed: u32,
    pub jobs_failed: u32,
    pub cpu_seconds: f64,
    pub cost: GridDollars,
}

/// Final report for one experiment run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Virtual time the last job finished (0 if none ran).
    pub makespan_s: SimTime,
    pub deadline_s: SimTime,
    pub deadline_met: bool,
    pub jobs_total: u32,
    pub jobs_completed: u32,
    pub jobs_failed: u32,
    pub total_cost: GridDollars,
    /// Busy grid CPUs over time (Figure 3's y-axis).
    pub busy_cpus: Timeline,
    /// Distinct resources that ran at least one job.
    pub resources_used: u32,
    pub per_resource: BTreeMap<String, ResourceUsage>,
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// Simulator events processed.
    pub events: u64,
    /// Resource-view entries rebuilt across the run — the incremental
    /// tick pipeline's work counter (a full-rebuild driver pays
    /// `ticks × resources` here; the event-driven table pays O(changed)).
    pub view_refreshes: u64,
}

impl Report {
    /// One-line summary (CLI output).
    pub fn summary(&self) -> String {
        format!(
            "jobs {}/{} done ({} failed), makespan {:.2} h (deadline {:.1} h, {}), cost {:.0} G$, peak {} cpus on {} resources",
            self.jobs_completed,
            self.jobs_total,
            self.jobs_failed,
            self.makespan_s / 3600.0,
            self.deadline_s / 3600.0,
            if self.deadline_met { "met" } else { "MISSED" },
            self.total_cost,
            self.busy_cpus.peak(),
            self.resources_used,
        )
    }

    /// CSV of the busy-processor timeline: `hours,busy_cpus` rows.
    pub fn timeline_csv(&self, dt: SimTime) -> String {
        let horizon = self.makespan_s.max(self.deadline_s);
        let mut out = String::from("hours,busy_cpus\n");
        for (t, v) in self.busy_cpus.sample(dt, horizon) {
            let _ = writeln!(out, "{:.3},{v}", t / 3600.0);
        }
        out
    }

    /// CSV of per-resource usage.
    pub fn per_resource_csv(&self) -> String {
        let mut out =
            String::from("resource,jobs_completed,jobs_failed,cpu_hours,cost_gd\n");
        for (name, u) in &self.per_resource {
            let _ = writeln!(
                out,
                "{name},{},{},{:.3},{:.2}",
                u.jobs_completed,
                u.jobs_failed,
                u.cpu_seconds / 3600.0,
                u.cost
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_step_semantics() {
        let mut tl = Timeline::default();
        tl.record(0.0, 0);
        tl.record(10.0, 3);
        tl.record(20.0, 1);
        assert_eq!(tl.at(-1.0), 0);
        assert_eq!(tl.at(5.0), 0);
        assert_eq!(tl.at(10.0), 3);
        assert_eq!(tl.at(15.0), 3);
        assert_eq!(tl.at(25.0), 1);
        assert_eq!(tl.peak(), 3);
    }

    #[test]
    fn duplicate_values_coalesce() {
        let mut tl = Timeline::default();
        tl.record(0.0, 2);
        tl.record(5.0, 2);
        tl.record(6.0, 2);
        assert_eq!(tl.points().len(), 1);
    }

    #[test]
    fn same_instant_overwrites() {
        let mut tl = Timeline::default();
        tl.record(1.0, 1);
        tl.record(1.0, 5);
        assert_eq!(tl.at(1.0), 5);
        assert_eq!(tl.points().len(), 1);
    }

    #[test]
    fn average_time_weighted() {
        let mut tl = Timeline::default();
        tl.record(0.0, 4);
        tl.record(5.0, 0);
        // 4 for half the horizon, 0 after.
        assert!((tl.average(10.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sampling_grid() {
        let mut tl = Timeline::default();
        tl.record(0.0, 1);
        tl.record(3.0, 2);
        let s = tl.sample(1.0, 4.0);
        assert_eq!(
            s,
            vec![(0.0, 1), (1.0, 1), (2.0, 1), (3.0, 2), (4.0, 2)]
        );
    }

    #[test]
    fn report_csv_shapes() {
        let mut r = Report {
            jobs_total: 2,
            jobs_completed: 2,
            makespan_s: 7200.0,
            deadline_s: 7200.0,
            deadline_met: true,
            ..Default::default()
        };
        r.busy_cpus.record(0.0, 1);
        r.per_resource.insert(
            "lemon0.anl.gov".into(),
            ResourceUsage {
                jobs_completed: 2,
                jobs_failed: 0,
                cpu_seconds: 3600.0,
                cost: 12.5,
            },
        );
        let csv = r.timeline_csv(3600.0);
        assert!(csv.starts_with("hours,busy_cpus\n"));
        assert_eq!(csv.lines().count(), 1 + 3); // header + 0,1,2 h
        let pr = r.per_resource_csv();
        assert!(pr.contains("lemon0.anl.gov,2,0,1.000,12.50"));
        assert!(r.summary().contains("met"));
    }
}
