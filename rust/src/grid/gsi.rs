//! GSI analogue: grid security — mutual authentication and authorization.
//!
//! Models what the scheduler/dispatcher need from the Globus Security
//! Infrastructure: users hold proxy credentials derived from an identity;
//! resources map credentials to local accounts through their gridmap
//! ([`crate::grid::testbed::AuthPolicy`]); every GRAM/GASS interaction is
//! performed under a validated credential. Cryptography is out of scope —
//! tokens are opaque capability strings with expiry, which preserves the
//! control-flow the paper depends on (authorization failures prune the
//! discovered resource list).

use crate::grid::testbed::ResourceSpec;
use crate::types::SimTime;

/// Default proxy credential lifetime (12 h, the Globus default).
pub const PROXY_LIFETIME_S: f64 = 12.0 * 3600.0;

/// A user's proxy credential.
#[derive(Debug, Clone, PartialEq)]
pub struct ProxyCredential {
    /// Grid identity (maps to per-resource accounts via the gridmap).
    pub subject: String,
    /// Opaque capability token.
    pub token: u64,
    pub expires_at: SimTime,
}

/// Credential authority: issues and validates proxies.
#[derive(Debug, Default)]
pub struct Gsi {
    issued: Vec<ProxyCredential>,
    next_token: u64,
}

/// Authorization failure reasons (what the dispatcher reports upstream).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuthError {
    Expired,
    Unknown,
    NotAuthorized,
}

impl std::fmt::Display for AuthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AuthError::Expired => "credential expired",
            AuthError::Unknown => "credential unknown",
            AuthError::NotAuthorized => "user not in resource gridmap",
        })
    }
}

impl std::error::Error for AuthError {}

impl Gsi {
    /// grid-proxy-init: issue a proxy for `subject`.
    pub fn issue(&mut self, subject: &str, now: SimTime) -> ProxyCredential {
        self.next_token += 1;
        let cred = ProxyCredential {
            subject: subject.to_string(),
            token: self.next_token,
            expires_at: now + PROXY_LIFETIME_S,
        };
        self.issued.push(cred.clone());
        cred
    }

    /// Validate a credential (mutual auth step of every remote call).
    pub fn validate(
        &self,
        cred: &ProxyCredential,
        now: SimTime,
    ) -> Result<(), AuthError> {
        let known = self
            .issued
            .iter()
            .any(|c| c.token == cred.token && c.subject == cred.subject);
        if !known {
            return Err(AuthError::Unknown);
        }
        if now >= cred.expires_at {
            return Err(AuthError::Expired);
        }
        Ok(())
    }

    /// Full check for an operation on `resource`: authentication plus
    /// gridmap authorization.
    pub fn authorize(
        &self,
        cred: &ProxyCredential,
        resource: &ResourceSpec,
        now: SimTime,
    ) -> Result<(), AuthError> {
        self.validate(cred, now)?;
        if !resource.auth.allows(&cred.subject) {
            return Err(AuthError::NotAuthorized);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::economy::price::PriceModel;
    use crate::grid::testbed::{AuthPolicy, QueueKind};
    use crate::types::{Arch, Os, ResourceId, SiteId};

    fn restricted_spec() -> ResourceSpec {
        ResourceSpec {
            id: ResourceId(0),
            name: "t".into(),
            site: SiteId(0),
            arch: Arch::Intel,
            os: Os::Linux,
            cpus: 1,
            speed: 1.0,
            mem_mb: 128,
            queue: QueueKind::Interactive,
            auth: AuthPolicy::Users(vec!["rajkumar".into()]),
            price: PriceModel::flat(1.0),
            mtbf_s: 1e9,
            mttr_s: 1.0,
            bg_load_mean: 0.0,
            bg_load_vol: 0.0,
            private_cluster: false,
        }
    }

    #[test]
    fn issue_validate_expire() {
        let mut gsi = Gsi::default();
        let cred = gsi.issue("rajkumar", 0.0);
        assert!(gsi.validate(&cred, 100.0).is_ok());
        assert_eq!(
            gsi.validate(&cred, PROXY_LIFETIME_S + 1.0),
            Err(AuthError::Expired)
        );
    }

    #[test]
    fn forged_credentials_rejected() {
        let mut gsi = Gsi::default();
        let real = gsi.issue("rajkumar", 0.0);
        let forged = ProxyCredential {
            subject: "rajkumar".into(),
            token: real.token + 999,
            expires_at: 1e9,
        };
        assert_eq!(gsi.validate(&forged, 0.0), Err(AuthError::Unknown));
        // Stolen token under a different subject also fails.
        let stolen = ProxyCredential {
            subject: "mallory".into(),
            ..real
        };
        assert_eq!(gsi.validate(&stolen, 0.0), Err(AuthError::Unknown));
    }

    #[test]
    fn gridmap_authorization() {
        let mut gsi = Gsi::default();
        let spec = restricted_spec();
        let ok = gsi.issue("rajkumar", 0.0);
        let nope = gsi.issue("stranger", 0.0);
        assert!(gsi.authorize(&ok, &spec, 1.0).is_ok());
        assert_eq!(
            gsi.authorize(&nope, &spec, 1.0),
            Err(AuthError::NotAuthorized)
        );
    }
}
