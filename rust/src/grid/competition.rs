//! Competing experiments (paper §3).
//!
//! "This system tries to find sufficient resources to meet the user's
//! deadline, and adapts the list of machines it is using depending on
//! competition for them. However, the cost changes as other competing
//! experiments are put on the grid."
//!
//! Modelled as a population of background task farms arriving as a Poisson
//! process. Each claims a bundle of CPUs on a random subset of resources
//! for an exponential holding time. Effects on the foreground experiment:
//!
//! * **capacity**: claimed CPUs are unavailable to GRAM (slots shrink);
//! * **price**: owners charge a *demand premium* that rises with the
//!   fraction of their machine already claimed — the mechanism that makes
//!   "the cost changes as other competing experiments are put on the grid"
//!   true in this testbed.

use crate::grid::testbed::Testbed;
use crate::types::{ResourceId, SimTime};
use crate::util::rng::Rng;

/// Demand premium slope: a fully-contended machine costs this factor more.
pub const DEMAND_PREMIUM_MAX: f64 = 1.5;

/// One background experiment occupying grid capacity.
#[derive(Debug, Clone)]
pub struct CompetingLoad {
    /// CPUs claimed per resource.
    pub claims: Vec<(ResourceId, u32)>,
    pub departs_at: SimTime,
}

/// Configuration of the competition process.
#[derive(Debug, Clone)]
pub struct CompetitionModel {
    /// Mean seconds between competing-experiment arrivals (Poisson).
    pub mean_interarrival_s: f64,
    /// Mean holding time of a competing experiment, seconds.
    pub mean_duration_s: f64,
    /// Mean CPUs a competing experiment claims in total.
    pub mean_cpus: f64,
}

impl Default for CompetitionModel {
    fn default() -> Self {
        CompetitionModel {
            mean_interarrival_s: 2.0 * 3600.0,
            mean_duration_s: 4.0 * 3600.0,
            mean_cpus: 30.0,
        }
    }
}

/// Runtime state: how many CPUs each resource has lost to competitors.
#[derive(Debug, Clone)]
pub struct Competition {
    pub model: CompetitionModel,
    claimed: Vec<u32>,
    active: Vec<CompetingLoad>,
    rng: Rng,
}

impl Competition {
    pub fn new(tb: &Testbed, model: CompetitionModel, rng: Rng) -> Competition {
        Competition {
            model,
            claimed: vec![0; tb.resources.len()],
            active: Vec::new(),
            rng,
        }
    }

    /// Seconds until the next competing experiment arrives.
    pub fn draw_interarrival(&mut self) -> SimTime {
        self.rng.exponential(self.model.mean_interarrival_s)
    }

    /// A new competing experiment lands: claim CPUs across random
    /// resources. Returns its departure time and the resources it claimed
    /// (whose premium/slots just changed — the views an incremental driver
    /// must dirty).
    ///
    /// `occupied` is the per-resource count of CPUs already held by real
    /// tenants (all experiments' in-flight jobs, indexed by `ResourceId`;
    /// missing entries read as 0). Competitors only claim genuinely free
    /// CPUs, so `Σ tenants' in-flight + claims ≤ CPUs` is a per-resource
    /// invariant, not a hope — previously arrivals ignored the foreground
    /// experiment and could oversubscribe a machine.
    pub fn arrive(
        &mut self,
        tb: &Testbed,
        now: SimTime,
        occupied: &[u32],
    ) -> (SimTime, Vec<ResourceId>) {
        let mut remaining =
            self.rng.exponential(self.model.mean_cpus).round().max(1.0) as u32;
        let mut claims = Vec::new();
        let mut guard = 0;
        while remaining > 0 && guard < 4 * tb.resources.len() {
            guard += 1;
            let idx = self.rng.below(tb.resources.len());
            let spec = &tb.resources[idx];
            let busy = occupied.get(idx).copied().unwrap_or(0);
            let free = spec
                .cpus
                .saturating_sub(self.claimed[idx])
                .saturating_sub(busy);
            if free == 0 {
                continue;
            }
            let take = remaining.min(free).min(1 + self.rng.below(8) as u32);
            self.claimed[idx] += take;
            claims.push((spec.id, take));
            remaining -= take;
        }
        let departs_at = now + self.rng.exponential(self.model.mean_duration_s);
        let claimed_rids = claims.iter().map(|&(rid, _)| rid).collect();
        self.active.push(CompetingLoad { claims, departs_at });
        (departs_at, claimed_rids)
    }

    /// Release every competing experiment whose departure time has passed.
    /// Returns the resources whose claims changed (possibly with
    /// duplicates), so an incremental driver can dirty just those views.
    pub fn depart_until(&mut self, now: SimTime) -> Vec<ResourceId> {
        let mut released = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].departs_at <= now {
                let load = self.active.swap_remove(i);
                for (rid, n) in load.claims {
                    let c = &mut self.claimed[rid.0 as usize];
                    *c = c.saturating_sub(n);
                    released.push(rid);
                }
            } else {
                i += 1;
            }
        }
        released
    }

    /// CPUs currently claimed by competitors on `rid`.
    pub fn claimed(&self, rid: ResourceId) -> u32 {
        self.claimed[rid.0 as usize]
    }

    /// Competing experiments currently on the grid.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Slots left for one experiment on a resource, accounting for every
    /// occupancy source in one place — synthetic competition claims, the
    /// other tenants' real in-flight jobs (`foreign_in_flight`) and the
    /// other tenants' advance-reservation holds (`foreign_reserved`) — so
    /// no driver can double-count or miss one of them. Single-tenant
    /// drivers pass zeros and get the legacy behaviour.
    pub fn free_slots(
        &self,
        tb: &Testbed,
        rid: ResourceId,
        base_slots: u32,
        foreign_in_flight: u32,
        foreign_reserved: u32,
    ) -> u32 {
        visible_slots(
            base_slots,
            tb.spec(rid).cpus,
            self.claimed(rid),
            foreign_in_flight,
            foreign_reserved,
        )
    }

    /// Demand premium multiplier on the owner's quoted rate: 1.0 when idle,
    /// up to [`DEMAND_PREMIUM_MAX`] when fully claimed.
    pub fn demand_premium(&self, tb: &Testbed, rid: ResourceId) -> f64 {
        let spec = tb.spec(rid);
        if spec.cpus == 0 {
            return 1.0;
        }
        let frac = self.claimed(rid) as f64 / spec.cpus as f64;
        1.0 + (DEMAND_PREMIUM_MAX - 1.0) * frac.min(1.0)
    }
}

/// The one formula for "how many GRAM slots can this experiment still
/// see": the queue's admit limit, capped by CPUs not claimed by
/// competitors, minus CPUs held by other tenants' in-flight jobs, minus
/// CPUs other tenants have locked with advance-reservation holds (a
/// tenant still sees its *own* holds — that is what lets it dispatch into
/// them). Shared by [`Competition::free_slots`] and the no-competition
/// path in [`crate::sim::GridWorld`] so both agree by construction.
pub fn visible_slots(
    base_slots: u32,
    cpus: u32,
    competition_claimed: u32,
    foreign_in_flight: u32,
    foreign_reserved: u32,
) -> u32 {
    base_slots
        .min(cpus.saturating_sub(competition_claimed))
        .saturating_sub(foreign_in_flight)
        .saturating_sub(foreign_reserved)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Testbed, Competition) {
        let tb = Testbed::gusto(3, 0.5);
        let comp =
            Competition::new(&tb, CompetitionModel::default(), Rng::new(9));
        (tb, comp)
    }

    #[test]
    fn arrivals_claim_and_departures_release() {
        let (tb, mut comp) = setup();
        let total_before: u32 =
            (0..tb.resources.len()).map(|i| comp.claimed[i]).sum();
        assert_eq!(total_before, 0);
        let (departs, claimed) = comp.arrive(&tb, 0.0, &[]);
        assert!(comp.active_count() == 1);
        assert!(!claimed.is_empty(), "arrival must report claimed rids");
        for rid in &claimed {
            assert!(comp.claimed(*rid) >= 1);
        }
        let total: u32 = (0..tb.resources.len()).map(|i| comp.claimed[i]).sum();
        assert!(total >= 1);
        let released = comp.depart_until(departs + 1.0);
        assert!(!released.is_empty(), "departure must report touched rids");
        assert_eq!(comp.active_count(), 0);
        let total_after: u32 =
            (0..tb.resources.len()).map(|i| comp.claimed[i]).sum();
        assert_eq!(total_after, 0);
    }

    #[test]
    fn claims_never_exceed_cpus() {
        let (tb, mut comp) = setup();
        for k in 0..50 {
            comp.arrive(&tb, k as f64, &[]);
        }
        for spec in &tb.resources {
            assert!(
                comp.claimed(spec.id) <= spec.cpus,
                "{}: {} > {}",
                spec.name,
                comp.claimed(spec.id),
                spec.cpus
            );
        }
    }

    #[test]
    fn premium_rises_with_contention() {
        let (tb, mut comp) = setup();
        let rid = tb.resources[0].id;
        assert_eq!(comp.demand_premium(&tb, rid), 1.0);
        // Saturate the grid with competitors.
        for k in 0..100 {
            comp.arrive(&tb, k as f64, &[]);
        }
        let contended = tb
            .resources
            .iter()
            .find(|s| comp.claimed(s.id) > 0)
            .expect("some contention");
        let premium = comp.demand_premium(&tb, contended.id);
        assert!(premium > 1.0 && premium <= DEMAND_PREMIUM_MAX);
        // Slots shrink accordingly.
        let slots = comp.free_slots(&tb, contended.id, contended.cpus, 0, 0);
        assert!(slots < contended.cpus);
    }

    #[test]
    fn arrivals_respect_tenant_occupancy() {
        // With every CPU already held by tenants, competitors can claim
        // nothing: the global slot-conservation invariant has no synthetic
        // loophole.
        let (tb, mut comp) = setup();
        let full: Vec<u32> = tb.resources.iter().map(|s| s.cpus).collect();
        for k in 0..20 {
            let (_, claimed) = comp.arrive(&tb, k as f64, &full);
            assert!(claimed.is_empty(), "claimed through full occupancy");
        }
        let total: u32 = (0..tb.resources.len()).map(|i| comp.claimed[i]).sum();
        assert_eq!(total, 0);
        // Partial occupancy: claims + occupancy never exceed CPUs.
        let half: Vec<u32> = tb.resources.iter().map(|s| s.cpus / 2).collect();
        for k in 0..50 {
            comp.arrive(&tb, k as f64, &half);
        }
        for spec in &tb.resources {
            let i = spec.id.0 as usize;
            assert!(
                comp.claimed(spec.id) + half[i] <= spec.cpus,
                "{}: {} + {} > {}",
                spec.name,
                comp.claimed(spec.id),
                half[i],
                spec.cpus
            );
        }
    }

    #[test]
    fn free_slots_subtracts_foreign_tenants() {
        let (tb, comp) = setup();
        let spec = &tb.resources[0];
        let base = spec.cpus;
        assert_eq!(comp.free_slots(&tb, spec.id, base, 0, 0), base);
        assert_eq!(
            comp.free_slots(&tb, spec.id, base, 3, 0),
            base.saturating_sub(3)
        );
        // Foreign occupancy can zero a machine out, never underflow.
        assert_eq!(comp.free_slots(&tb, spec.id, base, base + 5, 0), 0);
        // The shared formula is the same one the no-competition path uses.
        assert_eq!(visible_slots(8, 10, 4, 2, 0), 4);
        assert_eq!(visible_slots(8, 10, 0, 2, 0), 6);
        assert_eq!(visible_slots(8, 10, 10, 0, 0), 0);
        // Foreign reservation holds subtract exactly like foreign
        // in-flight jobs, and cannot underflow either.
        assert_eq!(visible_slots(8, 10, 0, 2, 3), 3);
        assert_eq!(visible_slots(8, 10, 4, 2, 3), 1);
        assert_eq!(comp.free_slots(&tb, spec.id, base, 1, base), 0);
    }

    #[test]
    fn interarrival_scale() {
        let (_tb, mut comp) = setup();
        let n = 2000;
        let mean: f64 =
            (0..n).map(|_| comp.draw_interarrival()).sum::<f64>() / n as f64;
        assert!((mean / comp.model.mean_interarrival_s - 1.0).abs() < 0.1);
    }
}
