//! Cluster master-node proxy (paper §4).
//!
//! "In many dedicated clusters ... only the master node is able to
//! communicate to the external world. ... we have developed a proxy server
//! in order to integrate closed cluster nodes as part of computational
//! grids. The proxy deployed on the cluster master node acts as a mediator
//! between external Nimrod components and cluster private-nodes for
//! accessing storage."
//!
//! Modelled effects for a private cluster:
//!
//! * every stage-in/out for a job on a private node is **two hops**:
//!   root ↔ master (WAN, via GASS) then master ↔ node (fast private LAN);
//! * all of the cluster's staging shares the single master uplink, so a
//!   wide sweep on a big private cluster self-throttles — exactly the
//!   behaviour that makes private clusters cheap-but-slower-to-feed in the
//!   economy benches.

use crate::grid::gass::Gass;
use crate::grid::testbed::{NetLink, ResourceSpec, Testbed};
use crate::types::SimTime;
use std::collections::BTreeMap;

/// Private intra-cluster LAN (fixed: fast switched Ethernet).
pub const CLUSTER_LAN: NetLink = NetLink {
    bandwidth_mbps: 100.0,
    latency_ms: 0.5,
};

/// Per-cluster proxy state: concurrent relays through the master uplink.
#[derive(Debug, Clone, Default)]
pub struct ClusterProxy {
    relays: BTreeMap<u32, u32>, // resource id → active relays
    pub relayed_bytes: f64,
}

impl ClusterProxy {
    /// Stage `bytes` to/from a node of `spec`. For public resources this is
    /// a plain GASS transfer; for private clusters it is the two-hop relay.
    /// Returns the transfer duration. Pair with [`ClusterProxy::end`].
    pub fn begin(
        &mut self,
        gass: &mut Gass,
        tb: &Testbed,
        spec: &ResourceSpec,
        bytes: f64,
    ) -> SimTime {
        let wan = gass.begin_transfer(tb, spec.site, bytes);
        if !spec.private_cluster {
            return wan;
        }
        let n = self.relays.entry(spec.id.0).or_insert(0);
        *n += 1;
        let contention = (*n).max(1) as f64;
        // Master uplink is the same WAN link; the LAN hop adds its own time,
        // serialized through the master relay.
        let lan = NetLink {
            bandwidth_mbps: CLUSTER_LAN.bandwidth_mbps / contention,
            latency_ms: CLUSTER_LAN.latency_ms,
        };
        self.relayed_bytes += bytes;
        wan + lan.transfer_seconds(bytes)
    }

    /// Finish a staging operation for `spec`.
    pub fn end(&mut self, gass: &mut Gass, spec: &ResourceSpec) {
        gass.end_transfer(spec.site);
        if spec.private_cluster {
            if let Some(n) = self.relays.get_mut(&spec.id.0) {
                *n = n.saturating_sub(1);
            }
        }
    }

    pub fn active_relays(&self, spec: &ResourceSpec) -> u32 {
        self.relays.get(&spec.id.0).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn testbed_with_private() -> (Testbed, usize, usize) {
        // Find one private and one public resource in the generated testbed.
        for seed in 0..20 {
            let tb = Testbed::gusto(seed, 1.0);
            let private = tb.resources.iter().position(|r| r.private_cluster);
            let public = tb.resources.iter().position(|r| !r.private_cluster);
            if let (Some(a), Some(b)) = (private, public) {
                return (tb, a, b);
            }
        }
        panic!("no seed produced both private and public resources");
    }

    #[test]
    fn private_staging_slower_than_public_same_site() {
        let (tb, prv, _) = testbed_with_private();
        let spec = tb.resources[prv].clone();
        let mut public_spec = spec.clone();
        public_spec.private_cluster = false;

        let mut gass = Gass::new(&tb);
        let mut proxy = ClusterProxy::default();
        let t_private = proxy.begin(&mut gass, &tb, &spec, 1e7);
        proxy.end(&mut gass, &spec);
        let t_public = proxy.begin(&mut gass, &tb, &public_spec, 1e7);
        proxy.end(&mut gass, &public_spec);
        assert!(t_private > t_public, "{t_private} vs {t_public}");
    }

    #[test]
    fn relay_contention_counts() {
        let (tb, prv, _) = testbed_with_private();
        let spec = tb.resources[prv].clone();
        let mut gass = Gass::new(&tb);
        let mut proxy = ClusterProxy::default();
        let t1 = proxy.begin(&mut gass, &tb, &spec, 1e7);
        let t2 = proxy.begin(&mut gass, &tb, &spec, 1e7);
        assert_eq!(proxy.active_relays(&spec), 2);
        assert!(t2 > t1, "second concurrent relay must be slower");
        proxy.end(&mut gass, &spec);
        proxy.end(&mut gass, &spec);
        assert_eq!(proxy.active_relays(&spec), 0);
    }
}
