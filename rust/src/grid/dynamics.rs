//! Per-resource dynamic behaviour: background load and availability churn.
//!
//! These processes are what make the grid "dynamic" in the paper's sense —
//! the scheduler must adapt its resource set because machine effective
//! speeds drift (local owners use their machines) and machines leave/join
//! the testbed (failures, maintenance).
//!
//! * **Background load** follows a mean-reverting AR(1) process clamped to
//!   `[0, 0.95]`: `x' = ρ·x + (1-ρ)·μ + σ·ε`. A grid job on the machine
//!   runs at `speed · (1 - x)`.
//! * **Availability** alternates exponentially-distributed up/down periods
//!   (means `mtbf_s` / `mttr_s`). A failure kills the resource's running
//!   grid jobs (the engine re-queues them).

use crate::grid::testbed::ResourceSpec;
use crate::types::SimTime;
use crate::util::rng::Rng;

/// AR(1) persistence per update step.
const LOAD_RHO: f64 = 0.9;
/// Seconds between background-load updates.
pub const LOAD_UPDATE_PERIOD_S: f64 = 300.0;
/// Hard ceiling on background load. Every load sample is clamped into
/// `[0, MAX_BG_LOAD]` — an unclamped AR(1) excursion past 1.0 would make
/// `planning_speed` negative and silently drop an overloaded-but-alive
/// machine from selection, and the small `1 - MAX_BG_LOAD` floor keeps a
/// saturated machine barely (but positively) fast.
pub const MAX_BG_LOAD: f64 = 0.95;

/// Dynamic state of one resource.
#[derive(Debug, Clone)]
pub struct ResourceDyn {
    pub up: bool,
    /// Fraction of CPU consumed by local (non-grid) work, 0..0.95.
    pub bg_load: f64,
    /// Private RNG stream for this resource's processes.
    rng: Rng,
}

impl ResourceDyn {
    pub fn new(spec: &ResourceSpec, parent_rng: &mut Rng) -> ResourceDyn {
        let mut rng = parent_rng.fork(spec.id.0 as u64);
        let bg_load = (spec.bg_load_mean + rng.normal(0.0, spec.bg_load_vol))
            .clamp(0.0, MAX_BG_LOAD);
        ResourceDyn {
            up: true,
            bg_load,
            rng,
        }
    }

    /// Advance the AR(1) load process one step.
    pub fn step_load(&mut self, spec: &ResourceSpec) {
        let eps = self.rng.normal(0.0, spec.bg_load_vol);
        self.bg_load = (LOAD_RHO * self.bg_load
            + (1.0 - LOAD_RHO) * spec.bg_load_mean
            + eps)
            .clamp(0.0, MAX_BG_LOAD);
    }

    /// Effective speed for a grid job right now.
    pub fn effective_speed(&self, spec: &ResourceSpec) -> f64 {
        if !self.up {
            0.0
        } else {
            spec.speed * (1.0 - self.bg_load)
        }
    }

    /// Draw the time until this (currently up) resource next fails.
    pub fn draw_uptime(&mut self, spec: &ResourceSpec) -> SimTime {
        self.rng.exponential(spec.mtbf_s)
    }

    /// Draw the outage duration once failed.
    pub fn draw_downtime(&mut self, spec: &ResourceSpec) -> SimTime {
        self.rng.exponential(spec.mttr_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::economy::price::PriceModel;
    use crate::grid::testbed::{AuthPolicy, QueueKind};
    use crate::types::{Arch, Os, ResourceId, SiteId};

    fn spec(mean: f64, vol: f64) -> ResourceSpec {
        ResourceSpec {
            id: ResourceId(0),
            name: "test0".into(),
            site: SiteId(0),
            arch: Arch::Intel,
            os: Os::Linux,
            cpus: 4,
            speed: 1.5,
            mem_mb: 512,
            queue: QueueKind::Interactive,
            auth: AuthPolicy::AllUsers,
            price: PriceModel::flat(1.0),
            mtbf_s: 100_000.0,
            mttr_s: 3600.0,
            bg_load_mean: mean,
            bg_load_vol: vol,
            private_cluster: false,
        }
    }

    #[test]
    fn load_stays_in_bounds() {
        let s = spec(0.4, 0.3);
        let mut rng = Rng::new(5);
        let mut d = ResourceDyn::new(&s, &mut rng);
        for _ in 0..10_000 {
            d.step_load(&s);
            assert!(
                (0.0..=MAX_BG_LOAD).contains(&d.bg_load),
                "load={}",
                d.bg_load
            );
        }
    }

    #[test]
    fn extreme_parameters_never_yield_negative_speed() {
        // A pathological spec (mean load past saturation, huge volatility):
        // the clamp must keep effective speed non-negative — and strictly
        // positive while the machine is up, so it stays selectable.
        let s = spec(5.0, 3.0);
        let mut rng = Rng::new(17);
        let mut d = ResourceDyn::new(&s, &mut rng);
        for _ in 0..2_000 {
            d.step_load(&s);
            assert!(d.bg_load <= MAX_BG_LOAD, "load={}", d.bg_load);
            assert!(
                d.effective_speed(&s) > 0.0,
                "up machine lost its speed: load={}",
                d.bg_load
            );
        }
    }

    #[test]
    fn load_mean_reverts() {
        let s = spec(0.3, 0.05);
        let mut rng = Rng::new(6);
        let mut d = ResourceDyn::new(&s, &mut rng);
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            d.step_load(&s);
            sum += d.bg_load;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.3).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn effective_speed_reflects_load_and_outage() {
        let s = spec(0.5, 0.0);
        let mut rng = Rng::new(7);
        let mut d = ResourceDyn::new(&s, &mut rng);
        d.bg_load = 0.5;
        assert!((d.effective_speed(&s) - 0.75).abs() < 1e-12);
        d.up = false;
        assert_eq!(d.effective_speed(&s), 0.0);
    }

    #[test]
    fn uptime_draws_have_right_scale() {
        let s = spec(0.1, 0.01);
        let mut rng = Rng::new(8);
        let mut d = ResourceDyn::new(&s, &mut rng);
        let n = 5000;
        let mean: f64 =
            (0..n).map(|_| d.draw_uptime(&s)).sum::<f64>() / n as f64;
        assert!((mean / s.mtbf_s - 1.0).abs() < 0.1, "mean={mean}");
    }
}
