//! Resource and site descriptions plus the GUSTO-like testbed generator.
//!
//! The paper's Figure-3 trial ran on "about 70 machines" of the GUSTO
//! testbed during April/May 1999 — heterogeneous workstations, SMPs and
//! clusters across administrative domains in the US, Europe, Japan and
//! Australia. [`Testbed::gusto`] synthesizes a testbed of that shape:
//! 8 sites in 5 time zones, ~70 machines with mixed architectures, queue
//! disciplines, owner pricing policies, and network links whose quality
//! falls with distance from the experiment's root site.

use crate::economy::price::PriceModel;
use crate::types::{Arch, Os, ResourceId, SiteId};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// An administrative site: one owner domain, one GASS server, one timezone.
#[derive(Debug, Clone)]
pub struct Site {
    pub id: SiteId,
    pub name: String,
    /// Hours relative to UTC (experiment clock is UTC).
    pub tz_offset_hours: f64,
    /// Wide-area link from the experiment root to this site.
    pub link: NetLink,
}

/// Network link quality used by the GASS staging model.
#[derive(Debug, Clone, Copy)]
pub struct NetLink {
    pub bandwidth_mbps: f64,
    pub latency_ms: f64,
}

impl NetLink {
    /// Seconds to move `bytes` over this link, one transfer, no contention.
    pub fn transfer_seconds(&self, bytes: f64) -> f64 {
        self.latency_ms / 1000.0 + bytes * 8.0 / (self.bandwidth_mbps * 1e6)
    }
}

/// Queue discipline the resource's local management system enforces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QueueKind {
    /// Fork-style immediate execution (GRAM fork jobmanager).
    Interactive,
    /// Space-shared batch queue (PBS/LSF-like): bounded running slots and a
    /// scheduling cycle the job waits for even on an idle machine.
    Batch {
        /// Concurrent grid jobs the queue admits.
        slots: u32,
        /// Seconds between queue scheduling cycles.
        cycle_s: f64,
    },
}

/// Who may run jobs on a resource (the GSI gridmap analogue).
#[derive(Debug, Clone)]
pub enum AuthPolicy {
    /// Any authenticated grid user.
    AllUsers,
    /// Only the listed accounts.
    Users(Vec<String>),
}

impl AuthPolicy {
    pub fn allows(&self, user: &str) -> bool {
        match self {
            AuthPolicy::AllUsers => true,
            AuthPolicy::Users(us) => us.iter().any(|u| u == user),
        }
    }
}

/// Static description of one grid resource (machine/cluster head).
#[derive(Debug, Clone)]
pub struct ResourceSpec {
    pub id: ResourceId,
    pub name: String,
    pub site: SiteId,
    pub arch: Arch,
    pub os: Os,
    /// CPUs this resource exposes to grid users.
    pub cpus: u32,
    /// Relative CPU speed (reference machine = 1.0).
    pub speed: f64,
    pub mem_mb: u32,
    pub queue: QueueKind,
    pub auth: AuthPolicy,
    /// Owner-set pricing (the computational economy input).
    pub price: PriceModel,
    /// Mean time between failures, seconds (availability churn).
    pub mtbf_s: f64,
    /// Mean time to recover, seconds.
    pub mttr_s: f64,
    /// Background (owner/local) load process parameters: long-run mean
    /// fraction of CPU consumed locally, and its volatility.
    pub bg_load_mean: f64,
    pub bg_load_vol: f64,
    /// True if this is a closed cluster reachable only via the master-node
    /// proxy (paper §4).
    pub private_cluster: bool,
}

/// Convenience pairing used throughout the scheduler and simulator.
#[derive(Debug, Clone)]
pub struct Resource {
    pub spec: ResourceSpec,
}

/// A complete testbed: sites plus resources.
#[derive(Debug, Clone, Default)]
pub struct Testbed {
    pub sites: Vec<Site>,
    pub resources: Vec<ResourceSpec>,
}

impl Testbed {
    /// Total CPUs across all resources.
    pub fn total_cpus(&self) -> u32 {
        self.resources.iter().map(|r| r.cpus).sum()
    }

    pub fn site(&self, id: SiteId) -> &Site {
        &self.sites[id.0 as usize]
    }

    pub fn spec(&self, id: ResourceId) -> &ResourceSpec {
        &self.resources[id.0 as usize]
    }

    /// Synthesize the GUSTO-like testbed (DESIGN.md §2). `scale` multiplies
    /// the machine count at every site (1.0 ⇒ ~70 machines / ~330 CPUs);
    /// `seed` fixes all sampled attributes.
    pub fn gusto(seed: u64, scale: f64) -> Testbed {
        let mut rng = Rng::new(seed);
        // (name, tz, wan bandwidth Mbps, latency ms, machines at scale 1)
        let site_defs: [(&str, f64, f64, f64, usize); 8] = [
            ("anl.gov", -6.0, 40.0, 30.0, 12),       // Argonne (root-adjacent)
            ("isi.edu", -8.0, 30.0, 60.0, 9),        // USC ISI
            ("ncsa.uiuc.edu", -6.0, 45.0, 35.0, 11), // NCSA
            ("sdsc.edu", -8.0, 30.0, 65.0, 8),       // San Diego
            ("ctc.cornell.edu", -5.0, 25.0, 45.0, 7),
            ("monash.edu.au", 10.0, 8.0, 220.0, 10), // experiment home site
            ("unile.it", 1.0, 6.0, 160.0, 6),        // Lecce, Italy
            ("etl.go.jp", 9.0, 10.0, 180.0, 7),      // ETL, Japan
        ];
        let archs = [
            (Arch::Intel, Os::Linux, 1.0),
            (Arch::Sparc, Os::Solaris, 0.8),
            (Arch::Mips, Os::Irix, 1.3),
            (Arch::Alpha, Os::Tru64, 1.5),
            (Arch::PowerPc, Os::Aix, 1.1),
        ];
        let mut tb = Testbed::default();
        let mut rid = 0u32;
        for (sidx, (sname, tz, bw, lat, count)) in site_defs.iter().enumerate() {
            let site_id = SiteId(sidx as u32);
            tb.sites.push(Site {
                id: site_id,
                name: sname.to_string(),
                tz_offset_hours: *tz,
                link: NetLink {
                    bandwidth_mbps: *bw * rng.uniform(0.8, 1.2),
                    latency_ms: *lat * rng.uniform(0.9, 1.1),
                },
            });
            let n_machines = ((*count as f64) * scale).round().max(1.0) as usize;
            for m in 0..n_machines {
                let (arch, os, speed_base) = *rng.choose(&archs);
                // A few big SMPs / clusters; mostly workstations.
                let cpus = match rng.below(10) {
                    0 => rng.range(16, 64) as u32, // cluster or big SMP
                    1..=2 => rng.range(4, 8) as u32,
                    _ => rng.range(1, 2) as u32,
                };
                let speed = speed_base * rng.uniform(0.7, 1.4);
                let batch = cpus >= 8 || rng.chance(0.25);
                let queue = if batch {
                    QueueKind::Batch {
                        slots: (cpus as f64 * rng.uniform(0.5, 1.0)).ceil() as u32,
                        cycle_s: rng.uniform(15.0, 120.0),
                    }
                } else {
                    QueueKind::Interactive
                };
                // Owner pricing: faster machines charge more; each owner adds
                // its own margin and peak policy (paper §3: owner-controlled,
                // time-varying cost).
                let price = PriceModel::owner_policy(
                    speed,
                    rng.uniform(0.6, 1.8),
                    rng.uniform(1.2, 3.0),
                    rng.chance(0.7),
                );
                let private_cluster = cpus >= 16 && rng.chance(0.5);
                tb.resources.push(ResourceSpec {
                    id: ResourceId(rid),
                    name: format!("{}{}.{}", host_stem(&mut rng), m, sname),
                    site: site_id,
                    arch,
                    os,
                    cpus,
                    speed,
                    mem_mb: 128 * cpus.max(2) * rng.range(1, 4) as u32,
                    queue,
                    auth: if rng.chance(0.85) {
                        AuthPolicy::AllUsers
                    } else {
                        AuthPolicy::Users(vec!["rajkumar".into(), "davida".into()])
                    },
                    price,
                    mtbf_s: rng.uniform(20.0, 200.0) * 3600.0,
                    mttr_s: rng.uniform(0.25, 2.0) * 3600.0,
                    bg_load_mean: rng.uniform(0.05, 0.5),
                    bg_load_vol: rng.uniform(0.02, 0.15),
                    private_cluster,
                });
                rid += 1;
            }
        }
        tb
    }

    /// Synthesize an arbitrarily large testbed: `sites` administrative
    /// domains of `resources_per_site` machines each, with the same
    /// heterogeneity axes as [`Testbed::gusto`] (architectures, batch vs
    /// interactive queues, owner pricing, churn and load parameters) but a
    /// regular shape that scales to tens of thousands of machines — the
    /// grids the incremental tick pipeline and the `mega-grid` scenario
    /// exercise. Every machine is open to all users so the whole grid is
    /// schedulable; resource ids are dense and ordered, as the directory
    /// service requires. Deterministic in `seed`.
    pub fn synthetic(
        sites: usize,
        resources_per_site: usize,
        seed: u64,
    ) -> Testbed {
        let mut rng = Rng::new(seed ^ 0x5CA1_AB1E);
        let archs = [
            (Arch::Intel, Os::Linux, 1.0),
            (Arch::Sparc, Os::Solaris, 0.8),
            (Arch::Mips, Os::Irix, 1.3),
            (Arch::Alpha, Os::Tru64, 1.5),
            (Arch::PowerPc, Os::Aix, 1.1),
        ];
        let mut tb = Testbed::default();
        let mut rid = 0u32;
        for s in 0..sites {
            let site_id = SiteId(s as u32);
            // Spread sites over the 24 timezones; link quality varies.
            tb.sites.push(Site {
                id: site_id,
                name: format!("site{s}.grid"),
                tz_offset_hours: (s % 24) as f64 - 11.0,
                link: NetLink {
                    bandwidth_mbps: rng.uniform(5.0, 45.0),
                    latency_ms: rng.uniform(20.0, 250.0),
                },
            });
            for m in 0..resources_per_site {
                let (arch, os, speed_base) = *rng.choose(&archs);
                let cpus = match rng.below(12) {
                    0 => rng.range(16, 64) as u32, // cluster or big SMP
                    1..=3 => rng.range(4, 8) as u32,
                    _ => rng.range(1, 2) as u32,
                };
                let speed = speed_base * rng.uniform(0.7, 1.4);
                let queue = if cpus >= 8 {
                    QueueKind::Batch {
                        slots: (cpus as f64 * rng.uniform(0.5, 1.0)).ceil()
                            as u32,
                        cycle_s: rng.uniform(15.0, 120.0),
                    }
                } else {
                    QueueKind::Interactive
                };
                let price = PriceModel::owner_policy(
                    speed,
                    rng.uniform(0.6, 1.8),
                    rng.uniform(1.2, 3.0),
                    rng.chance(0.5),
                );
                tb.resources.push(ResourceSpec {
                    id: ResourceId(rid),
                    name: format!("n{m}.site{s}.grid"),
                    site: site_id,
                    arch,
                    os,
                    cpus,
                    speed,
                    mem_mb: 256 * cpus.max(1),
                    queue,
                    auth: AuthPolicy::AllUsers,
                    price,
                    mtbf_s: rng.uniform(50.0, 500.0) * 3600.0,
                    mttr_s: rng.uniform(0.25, 2.0) * 3600.0,
                    bg_load_mean: rng.uniform(0.05, 0.4),
                    bg_load_vol: rng.uniform(0.02, 0.1),
                    private_cluster: false,
                });
                rid += 1;
            }
        }
        tb
    }

    // -- JSON config round-trip ---------------------------------------------

    /// Serialize to the JSON config format (`nimrod testbed --dump`).
    pub fn to_json(&self) -> Json {
        let sites = self
            .sites
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(&s.name)),
                    ("tz", Json::num(s.tz_offset_hours)),
                    ("bw_mbps", Json::num(s.link.bandwidth_mbps)),
                    ("lat_ms", Json::num(s.link.latency_ms)),
                ])
            })
            .collect();
        let resources = self
            .resources
            .iter()
            .map(|r| {
                let (kind, slots, cycle) = match r.queue {
                    QueueKind::Interactive => ("interactive", 0.0, 0.0),
                    QueueKind::Batch { slots, cycle_s } => {
                        ("batch", slots as f64, cycle_s)
                    }
                };
                let users = match &r.auth {
                    AuthPolicy::AllUsers => Json::Null,
                    AuthPolicy::Users(us) => {
                        Json::arr(us.iter().map(Json::str).collect())
                    }
                };
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("site", Json::num(r.site.0 as f64)),
                    ("arch", Json::str(r.arch.to_string())),
                    ("os", Json::str(r.os.to_string())),
                    ("cpus", Json::num(r.cpus as f64)),
                    ("speed", Json::num(r.speed)),
                    ("mem_mb", Json::num(r.mem_mb as f64)),
                    ("queue", Json::str(kind)),
                    ("slots", Json::num(slots)),
                    ("cycle_s", Json::num(cycle)),
                    ("users", users),
                    ("price", r.price.to_json()),
                    ("mtbf_s", Json::num(r.mtbf_s)),
                    ("mttr_s", Json::num(r.mttr_s)),
                    ("bg_load_mean", Json::num(r.bg_load_mean)),
                    ("bg_load_vol", Json::num(r.bg_load_vol)),
                    ("private", Json::Bool(r.private_cluster)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("sites", Json::arr(sites)),
            ("resources", Json::arr(resources)),
        ])
    }

    /// Load from the JSON config format.
    pub fn from_json(v: &Json) -> anyhow::Result<Testbed> {
        let mut tb = Testbed::default();
        for (i, s) in v.req_arr("sites")?.iter().enumerate() {
            tb.sites.push(Site {
                id: SiteId(i as u32),
                name: s.req_str("name")?.to_string(),
                tz_offset_hours: s.req_f64("tz")?,
                link: NetLink {
                    bandwidth_mbps: s.req_f64("bw_mbps")?,
                    latency_ms: s.req_f64("lat_ms")?,
                },
            });
        }
        for (i, r) in v.req_arr("resources")?.iter().enumerate() {
            let queue = match r.req_str("queue")? {
                "interactive" => QueueKind::Interactive,
                "batch" => QueueKind::Batch {
                    slots: r.req_f64("slots")? as u32,
                    cycle_s: r.req_f64("cycle_s")?,
                },
                other => anyhow::bail!("unknown queue kind `{other}`"),
            };
            let auth = match r.get("users") {
                Json::Null => AuthPolicy::AllUsers,
                Json::Arr(us) => AuthPolicy::Users(
                    us.iter()
                        .filter_map(|u| u.as_str().map(String::from))
                        .collect(),
                ),
                _ => anyhow::bail!("bad `users` field"),
            };
            tb.resources.push(ResourceSpec {
                id: ResourceId(i as u32),
                name: r.req_str("name")?.to_string(),
                site: SiteId(r.req_f64("site")? as u32),
                arch: parse_arch(r.req_str("arch")?)?,
                os: parse_os(r.req_str("os")?)?,
                cpus: r.req_f64("cpus")? as u32,
                speed: r.req_f64("speed")?,
                mem_mb: r.req_f64("mem_mb")? as u32,
                queue,
                auth,
                price: PriceModel::from_json(r.get("price"))?,
                mtbf_s: r.req_f64("mtbf_s")?,
                mttr_s: r.req_f64("mttr_s")?,
                bg_load_mean: r.req_f64("bg_load_mean")?,
                bg_load_vol: r.req_f64("bg_load_vol")?,
                private_cluster: r.get("private").as_bool().unwrap_or(false),
            });
        }
        Ok(tb)
    }
}

fn parse_arch(s: &str) -> anyhow::Result<Arch> {
    Ok(match s {
        "intel" => Arch::Intel,
        "sparc" => Arch::Sparc,
        "alpha" => Arch::Alpha,
        "mips" => Arch::Mips,
        "powerpc" => Arch::PowerPc,
        other => anyhow::bail!("unknown arch `{other}`"),
    })
}

fn parse_os(s: &str) -> anyhow::Result<Os> {
    Ok(match s {
        "linux" => Os::Linux,
        "solaris" => Os::Solaris,
        "irix" => Os::Irix,
        "tru64" => Os::Tru64,
        "aix" => Os::Aix,
        other => anyhow::bail!("unknown os `{other}`"),
    })
}

fn host_stem(rng: &mut Rng) -> &'static str {
    const STEMS: [&str; 12] = [
        "lemon", "pitcairn", "tuva", "bolas", "denali", "huxley", "vidar",
        "osprey", "jupiter", "modi", "lindner", "dirac",
    ];
    STEMS[rng.below(STEMS.len())]
}

/// Local wall-clock hour at a site when the UTC experiment clock reads
/// `utc_hours` hours (fractional).
pub fn local_hour(utc_hours: f64, tz_offset_hours: f64) -> f64 {
    ((utc_hours + tz_offset_hours) % 24.0 + 24.0) % 24.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gusto_shape() {
        let tb = Testbed::gusto(1, 1.0);
        assert_eq!(tb.sites.len(), 8);
        let n = tb.resources.len();
        assert!((55..=90).contains(&n), "expected ~70 machines, got {n}");
        assert!(tb.total_cpus() >= 100, "cpus={}", tb.total_cpus());
        // Heterogeneity: more than one arch, some batch queues, some
        // restricted-auth machines, some private clusters at scale 1.
        let archs: std::collections::BTreeSet<_> =
            tb.resources.iter().map(|r| r.arch).collect();
        assert!(archs.len() >= 3);
        assert!(tb
            .resources
            .iter()
            .any(|r| matches!(r.queue, QueueKind::Batch { .. })));
        assert!(tb
            .resources
            .iter()
            .any(|r| matches!(r.auth, AuthPolicy::Users(_))));
    }

    #[test]
    fn gusto_deterministic() {
        let a = Testbed::gusto(7, 1.0);
        let b = Testbed::gusto(7, 1.0);
        assert_eq!(a.resources.len(), b.resources.len());
        for (x, y) in a.resources.iter().zip(&b.resources) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.speed, y.speed);
            assert_eq!(x.cpus, y.cpus);
        }
    }

    #[test]
    fn gusto_scales() {
        let small = Testbed::gusto(1, 0.5);
        let big = Testbed::gusto(1, 4.0);
        assert!(big.resources.len() > 3 * small.resources.len());
    }

    #[test]
    fn synthetic_shape_and_determinism() {
        let tb = Testbed::synthetic(12, 25, 4);
        assert_eq!(tb.sites.len(), 12);
        assert_eq!(tb.resources.len(), 300);
        // Dense, ordered ids (the directory service indexes by id).
        for (i, r) in tb.resources.iter().enumerate() {
            assert_eq!(r.id.0 as usize, i);
            assert!(r.auth.allows("anyone"), "synthetic grids are open");
            assert!(r.speed > 0.0 && r.cpus >= 1);
        }
        // Heterogeneous enough to give schedulers something to choose on.
        let archs: std::collections::BTreeSet<_> =
            tb.resources.iter().map(|r| r.arch).collect();
        assert!(archs.len() >= 3);
        let b = Testbed::synthetic(12, 25, 4);
        for (x, y) in tb.resources.iter().zip(&b.resources) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.speed, y.speed);
        }
        let c = Testbed::synthetic(12, 25, 5);
        assert!(
            tb.resources.iter().zip(&c.resources).any(|(x, y)| x.speed != y.speed),
            "different seeds should vary the sampled attributes"
        );
    }

    #[test]
    fn json_roundtrip() {
        let tb = Testbed::gusto(3, 0.3);
        let j = tb.to_json();
        let back = Testbed::from_json(&crate::util::json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(tb.resources.len(), back.resources.len());
        assert_eq!(tb.sites.len(), back.sites.len());
        for (a, b) in tb.resources.iter().zip(&back.resources) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.cpus, b.cpus);
            assert!((a.speed - b.speed).abs() < 1e-9);
            assert_eq!(
                matches!(a.queue, QueueKind::Interactive),
                matches!(b.queue, QueueKind::Interactive)
            );
        }
    }

    #[test]
    fn transfer_time_model() {
        let link = NetLink {
            bandwidth_mbps: 8.0,
            latency_ms: 100.0,
        };
        // 1 MB over 8 Mbps = 1 s, plus 0.1 s latency.
        let t = link.transfer_seconds(1e6);
        assert!((t - 1.1).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn local_hour_wraps() {
        assert_eq!(local_hour(0.0, 10.0), 10.0);
        assert_eq!(local_hour(20.0, 10.0), 6.0);
        assert_eq!(local_hour(3.0, -6.0), 21.0);
    }

    #[test]
    fn auth_policy() {
        let all = AuthPolicy::AllUsers;
        assert!(all.allows("anyone"));
        let some = AuthPolicy::Users(vec!["rajkumar".into()]);
        assert!(some.allows("rajkumar"));
        assert!(!some.allows("stranger"));
    }
}
