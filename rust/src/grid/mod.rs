//! The simulated grid substrate (the paper's Globus/GUSTO environment).
//!
//! Everything Nimrod/G ran *on* is unavailable (GUSTO testbed, Globus 1.1),
//! so this module provides behaviour-preserving analogues (DESIGN.md §2):
//!
//! * [`testbed`] — resource/site descriptions and the ~70-machine
//!   GUSTO-like testbed generator;
//! * [`dynamics`] — per-resource background load (AR(1)) and availability
//!   churn processes, the source of the "dynamic resources" the paper
//!   schedules against;
//! * [`mds`] — the directory service (Globus MDS analogue) with refresh
//!   staleness;
//! * [`gram`] — the per-resource job manager (GRAM analogue): submit /
//!   queue / run / poll / cancel with interactive- and batch-queue
//!   semantics;
//! * [`gass`] — storage servers and the staging time model (GASS analogue);
//! * [`gsi`] — token-based mutual authentication and per-resource
//!   authorization (GSI analogue);
//! * [`proxy`] — the cluster master-node proxy of paper §4, which mediates
//!   storage access for private (non-routable) cluster nodes.

pub mod competition;
pub mod dynamics;
pub mod gass;
pub mod gram;
pub mod gsi;
pub mod mds;
pub mod proxy;
pub mod testbed;

pub use gram::{GramStatus, JobManager};
pub use testbed::{
    AuthPolicy, NetLink, QueueKind, Resource, ResourceSpec, Site, Testbed,
};
