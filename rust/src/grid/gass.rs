//! GASS analogue: storage servers and the file-staging time model.
//!
//! Nimrod/G's job-wrapper stages inputs to the node and results back via
//! Globus GASS. Here each site runs a storage server; a transfer's duration
//! is latency + size/bandwidth over the root↔site WAN link, degraded by the
//! number of concurrent transfers sharing that link (the root side is the
//! choke point for a parameter sweep, which is why staging matters to the
//! scheduler at tight deadlines).

use crate::grid::testbed::{NetLink, Testbed};
use crate::types::{SimTime, SiteId};
use std::collections::BTreeMap;

/// A named file in experiment root storage or on a node.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct FileRef {
    pub name: String,
}

/// Per-site GASS server bookkeeping.
#[derive(Debug, Clone, Default)]
struct SiteServer {
    active_transfers: u32,
}

/// The staging subsystem: tracks concurrent transfers per site link and
/// computes transfer durations.
#[derive(Debug, Clone, Default)]
pub struct Gass {
    servers: BTreeMap<SiteId, SiteServer>,
    /// Total bytes moved (metrics).
    pub bytes_moved: f64,
    /// Total transfers performed.
    pub transfers: u64,
}

impl Gass {
    pub fn new(tb: &Testbed) -> Gass {
        Gass {
            servers: tb
                .sites
                .iter()
                .map(|s| (s.id, SiteServer::default()))
                .collect(),
            bytes_moved: 0.0,
            transfers: 0,
        }
    }

    /// Begin a transfer of `bytes` between root storage and `site`; returns
    /// its duration. Concurrency on the same link divides bandwidth.
    /// The caller must pair this with [`Gass::end_transfer`].
    pub fn begin_transfer(
        &mut self,
        tb: &Testbed,
        site: SiteId,
        bytes: f64,
    ) -> SimTime {
        let server = self.servers.entry(site).or_default();
        server.active_transfers += 1;
        let contention = server.active_transfers.max(1) as f64;
        let link = tb.site(site).link;
        let effective = NetLink {
            bandwidth_mbps: link.bandwidth_mbps / contention,
            latency_ms: link.latency_ms,
        };
        self.bytes_moved += bytes;
        self.transfers += 1;
        effective.transfer_seconds(bytes)
    }

    /// Mark a transfer finished (frees its bandwidth share).
    pub fn end_transfer(&mut self, site: SiteId) {
        if let Some(s) = self.servers.get_mut(&site) {
            s.active_transfers = s.active_transfers.saturating_sub(1);
        }
    }

    /// Transfers in flight to a site (tests/metrics).
    pub fn active(&self, site: SiteId) -> u32 {
        self.servers.get(&site).map(|s| s.active_transfers).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::testbed::Testbed;

    #[test]
    fn transfer_duration_scales_with_size() {
        let tb = Testbed::gusto(1, 0.3);
        let mut gass = Gass::new(&tb);
        let site = tb.sites[0].id;
        let t_small = gass.begin_transfer(&tb, site, 1e5);
        gass.end_transfer(site);
        let t_big = gass.begin_transfer(&tb, site, 1e7);
        gass.end_transfer(site);
        assert!(t_big > t_small * 10.0);
    }

    #[test]
    fn contention_slows_concurrent_transfers() {
        let tb = Testbed::gusto(1, 0.3);
        let mut gass = Gass::new(&tb);
        let site = tb.sites[0].id;
        let alone = gass.begin_transfer(&tb, site, 1e7);
        // Second concurrent transfer sees half the bandwidth.
        let contended = gass.begin_transfer(&tb, site, 1e7);
        assert!(contended > alone * 1.5);
        assert_eq!(gass.active(site), 2);
        gass.end_transfer(site);
        gass.end_transfer(site);
        assert_eq!(gass.active(site), 0);
    }

    #[test]
    fn accounting() {
        let tb = Testbed::gusto(1, 0.3);
        let mut gass = Gass::new(&tb);
        gass.begin_transfer(&tb, tb.sites[0].id, 100.0);
        gass.begin_transfer(&tb, tb.sites[1].id, 200.0);
        assert_eq!(gass.transfers, 2);
        assert_eq!(gass.bytes_moved, 300.0);
    }
}
