//! GRAM analogue: the per-resource job manager.
//!
//! The dispatcher talks to resources exclusively through this interface
//! (submit / poll / cancel), as Nimrod/G's dispatcher talks to the Globus
//! GRAM. The job manager is a pure state machine over queue slots; the
//! simulation driver (or the live runtime) supplies timing.
//!
//! Queue semantics:
//! * **Interactive** (fork jobmanager) — a job starts as soon as a CPU is
//!   free; all CPUs are usable as slots.
//! * **Batch** — at most `slots` grid jobs run concurrently and a job only
//!   starts at the queue's next scheduling cycle, even on an idle machine.

use crate::grid::testbed::{QueueKind, ResourceSpec};
use crate::types::{JobId, SimTime};
use std::collections::{BTreeMap, VecDeque};

/// Externally visible job status (GRAM job states).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GramStatus {
    /// Queued, not yet running.
    Pending,
    /// Executing.
    Active,
    /// Finished successfully.
    Done,
    /// Failed (machine went down, cancelled, ...).
    Failed,
}

/// One resource's job manager.
#[derive(Debug, Clone)]
pub struct JobManager {
    /// Max concurrently running grid jobs.
    slots: u32,
    /// Batch scheduling cycle (0 for interactive).
    cycle_s: SimTime,
    queue: VecDeque<JobId>,
    running: BTreeMap<JobId, SimTime>, // job → start time
    status: BTreeMap<JobId, GramStatus>,
}

impl JobManager {
    pub fn new(spec: &ResourceSpec) -> JobManager {
        let (slots, cycle_s) = match spec.queue {
            QueueKind::Interactive => (spec.cpus, 0.0),
            QueueKind::Batch { slots, cycle_s } => (slots.min(spec.cpus), cycle_s),
        };
        JobManager {
            slots,
            cycle_s,
            queue: VecDeque::new(),
            running: BTreeMap::new(),
            status: BTreeMap::new(),
        }
    }

    pub fn slots(&self) -> u32 {
        self.slots
    }

    /// Jobs currently executing.
    pub fn active_count(&self) -> u32 {
        self.running.len() as u32
    }

    /// Jobs queued but not yet started.
    pub fn pending_count(&self) -> u32 {
        self.queue.len() as u32
    }

    /// Total jobs this manager is responsible for (in-flight).
    pub fn in_flight(&self) -> u32 {
        self.active_count() + self.pending_count()
    }

    /// GRAM submit: enqueue the job.
    pub fn submit(&mut self, job: JobId) {
        debug_assert!(
            !self.status.contains_key(&job)
                || matches!(
                    self.status[&job],
                    GramStatus::Done | GramStatus::Failed
                ),
            "resubmitting in-flight job {job}"
        );
        self.queue.push_back(job);
        self.status.insert(job, GramStatus::Pending);
    }

    /// GRAM poll.
    pub fn poll(&self, job: JobId) -> Option<GramStatus> {
        self.status.get(&job).copied()
    }

    /// Pop jobs that may start now (free slots × queue head), marking them
    /// Active. Returns `(job, queue_delay)` pairs: the extra delay before
    /// execution actually begins (batch scheduling cycle).
    pub fn start_eligible(&mut self, now: SimTime) -> Vec<(JobId, SimTime)> {
        let mut started = Vec::new();
        while (self.running.len() as u32) < self.slots {
            let Some(job) = self.queue.pop_front() else {
                break;
            };
            // Mid-cycle arrivals wait for the next scheduling cycle.
            let delay = if self.cycle_s > 0.0 {
                self.cycle_s / 2.0
            } else {
                0.0
            };
            self.running.insert(job, now + delay);
            self.status.insert(job, GramStatus::Active);
            started.push((job, delay));
        }
        started
    }

    /// Mark a running job complete.
    pub fn complete(&mut self, job: JobId) {
        let was = self.running.remove(&job);
        debug_assert!(was.is_some(), "completing job {job} that is not running");
        self.status.insert(job, GramStatus::Done);
    }

    /// GRAM cancel: remove a job wherever it is. Returns true if the job was
    /// in flight here.
    pub fn cancel(&mut self, job: JobId) -> bool {
        if self.running.remove(&job).is_some() {
            self.status.insert(job, GramStatus::Failed);
            return true;
        }
        if let Some(pos) = self.queue.iter().position(|&j| j == job) {
            self.queue.remove(pos);
            self.status.insert(job, GramStatus::Failed);
            return true;
        }
        false
    }

    /// Resource failure: everything in flight fails. Returns the jobs that
    /// were running or queued (for the engine to re-queue elsewhere) paired
    /// with their start time if they were running.
    pub fn fail_all(&mut self) -> Vec<(JobId, Option<SimTime>)> {
        let mut out: Vec<(JobId, Option<SimTime>)> = Vec::new();
        for (job, started) in std::mem::take(&mut self.running) {
            self.status.insert(job, GramStatus::Failed);
            out.push((job, Some(started)));
        }
        for job in std::mem::take(&mut self.queue) {
            self.status.insert(job, GramStatus::Failed);
            out.push((job, None));
        }
        out
    }

    /// Running jobs and their start times (metering partial cost on failure).
    pub fn running_jobs(&self) -> impl Iterator<Item = (&JobId, &SimTime)> {
        self.running.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::economy::price::PriceModel;
    use crate::grid::testbed::AuthPolicy;
    use crate::types::{Arch, Os, ResourceId, SiteId};

    fn spec(queue: QueueKind, cpus: u32) -> ResourceSpec {
        ResourceSpec {
            id: ResourceId(0),
            name: "t".into(),
            site: SiteId(0),
            arch: Arch::Intel,
            os: Os::Linux,
            cpus,
            speed: 1.0,
            mem_mb: 256,
            queue,
            auth: AuthPolicy::AllUsers,
            price: PriceModel::flat(1.0),
            mtbf_s: 1e9,
            mttr_s: 1.0,
            bg_load_mean: 0.0,
            bg_load_vol: 0.0,
            private_cluster: false,
        }
    }

    #[test]
    fn interactive_starts_up_to_cpus() {
        let mut jm = JobManager::new(&spec(QueueKind::Interactive, 2));
        jm.submit(JobId(0));
        jm.submit(JobId(1));
        jm.submit(JobId(2));
        let started = jm.start_eligible(0.0);
        assert_eq!(started.len(), 2);
        assert_eq!(started[0].1, 0.0); // no queue-cycle delay
        assert_eq!(jm.poll(JobId(0)), Some(GramStatus::Active));
        assert_eq!(jm.poll(JobId(2)), Some(GramStatus::Pending));
        // Completing one admits the next.
        jm.complete(JobId(0));
        let started = jm.start_eligible(10.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].0, JobId(2));
        assert_eq!(jm.poll(JobId(0)), Some(GramStatus::Done));
    }

    #[test]
    fn batch_respects_slots_and_cycle() {
        let mut jm = JobManager::new(&spec(
            QueueKind::Batch {
                slots: 1,
                cycle_s: 60.0,
            },
            8,
        ));
        jm.submit(JobId(0));
        jm.submit(JobId(1));
        let started = jm.start_eligible(0.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].1, 30.0); // half a cycle on average
        assert_eq!(jm.in_flight(), 2);
    }

    #[test]
    fn batch_slots_capped_by_cpus() {
        let jm = JobManager::new(&spec(
            QueueKind::Batch {
                slots: 64,
                cycle_s: 30.0,
            },
            4,
        ));
        assert_eq!(jm.slots(), 4);
    }

    #[test]
    fn cancel_queued_and_running() {
        let mut jm = JobManager::new(&spec(QueueKind::Interactive, 1));
        jm.submit(JobId(0));
        jm.submit(JobId(1));
        jm.start_eligible(0.0);
        assert!(jm.cancel(JobId(0))); // running
        assert!(jm.cancel(JobId(1))); // queued
        assert!(!jm.cancel(JobId(2))); // unknown
        assert_eq!(jm.poll(JobId(0)), Some(GramStatus::Failed));
        assert_eq!(jm.in_flight(), 0);
    }

    #[test]
    fn fail_all_reports_roles() {
        let mut jm = JobManager::new(&spec(QueueKind::Interactive, 1));
        jm.submit(JobId(0));
        jm.submit(JobId(1));
        jm.start_eligible(5.0);
        let failed = jm.fail_all();
        assert_eq!(failed.len(), 2);
        let running: Vec<_> = failed.iter().filter(|(_, s)| s.is_some()).collect();
        assert_eq!(running.len(), 1);
        assert_eq!(running[0].0, JobId(0));
        assert_eq!(jm.in_flight(), 0);
    }

    #[test]
    fn resubmit_after_failure_allowed() {
        let mut jm = JobManager::new(&spec(QueueKind::Interactive, 1));
        jm.submit(JobId(0));
        jm.start_eligible(0.0);
        jm.fail_all();
        jm.submit(JobId(0)); // re-dispatch after failure is legal
        assert_eq!(jm.poll(JobId(0)), Some(GramStatus::Pending));
    }
}
