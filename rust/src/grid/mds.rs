//! MDS analogue: the grid information/directory service.
//!
//! The scheduler's resource-discovery step queries this directory — as
//! Nimrod/G queries the Globus MDS — for the machines a user is authorized
//! on, with capability and status attributes. Directory data is a *stale
//! snapshot*: records refresh on a period, so the scheduler sees load and
//! availability as they were at the last refresh, not ground truth. This
//! staleness is part of the paper's problem setting (resource state decays;
//! the scheduler must adapt).

use crate::grid::dynamics::{ResourceDyn, MAX_BG_LOAD};
use crate::grid::testbed::{QueueKind, ResourceSpec, Testbed};
use crate::types::{ResourceId, SimTime, SiteId};

/// Seconds between directory refreshes (GRIS cache TTL).
pub const MDS_REFRESH_PERIOD_S: f64 = 120.0;

/// One directory record (what discovery returns).
#[derive(Debug, Clone)]
pub struct MdsRecord {
    pub id: ResourceId,
    pub name: String,
    pub site: SiteId,
    pub cpus: u32,
    pub speed: f64,
    /// Load as of the last refresh.
    pub bg_load: f64,
    /// Up/down as of the last refresh.
    pub up: bool,
    pub batch_queue: bool,
    /// Timestamp of the record's last refresh.
    pub as_of: SimTime,
}

impl MdsRecord {
    /// Effective speed the scheduler plans with (stale view). Load is
    /// clamped into `[0, MAX_BG_LOAD]` so an overloaded-but-alive machine
    /// still advertises a small positive speed — a negative speed would
    /// silently drop it from every policy's candidate list.
    pub fn planning_speed(&self) -> f64 {
        if self.up {
            let ps = self.speed * (1.0 - self.bg_load.clamp(0.0, MAX_BG_LOAD));
            debug_assert!(
                ps >= 0.0,
                "negative planning speed on {} (load {})",
                self.name,
                self.bg_load
            );
            ps
        } else {
            0.0
        }
    }
}

/// The directory service: hierarchical in the Globus sense (site GRIS →
/// root GIIS), flattened here to a root index refreshed per site.
#[derive(Debug, Clone)]
pub struct Mds {
    records: Vec<MdsRecord>,
    last_refresh: SimTime,
}

impl Mds {
    /// Build the initial directory from the testbed (t = 0 snapshot).
    pub fn new(tb: &Testbed, dyns: &[ResourceDyn]) -> Mds {
        let records = tb
            .resources
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                debug_assert_eq!(
                    spec.id.0 as usize, i,
                    "testbed resource ids must be dense and ordered"
                );
                let d = &dyns[i];
                MdsRecord {
                    id: spec.id,
                    name: spec.name.clone(),
                    site: spec.site,
                    cpus: spec.cpus,
                    speed: spec.speed,
                    bg_load: d.bg_load,
                    up: d.up,
                    batch_queue: matches!(spec.queue, QueueKind::Batch { .. }),
                    as_of: 0.0,
                }
            })
            .collect();
        Mds {
            records,
            last_refresh: 0.0,
        }
    }

    /// Re-scan ground truth (the simulation driver calls this on the
    /// refresh period; a live deployment would poll site GRIS daemons).
    /// Records are updated in place — no per-refresh allocation — and the
    /// ids whose scheduler-visible state (up/load) actually changed are
    /// returned, so an incremental driver dirties only those resources'
    /// views instead of rebuilding all of them.
    pub fn refresh(
        &mut self,
        tb: &Testbed,
        dyns: &[ResourceDyn],
        now: SimTime,
    ) -> Vec<ResourceId> {
        debug_assert_eq!(self.records.len(), tb.resources.len());
        let mut changed = Vec::new();
        for rec in &mut self.records {
            let d = &dyns[rec.id.0 as usize];
            rec.as_of = now;
            if rec.up != d.up || rec.bg_load != d.bg_load {
                rec.up = d.up;
                rec.bg_load = d.bg_load;
                changed.push(rec.id);
            }
        }
        self.last_refresh = now;
        changed
    }

    pub fn last_refresh(&self) -> SimTime {
        self.last_refresh
    }

    /// Discovery: records for machines `user` is authorized on that were up
    /// at the last refresh. This is the paper's "resource discovery
    /// algorithm interacts with a grid-information service directory,
    /// identifies the list of authorized machines".
    pub fn discover<'a>(
        &'a self,
        tb: &'a Testbed,
        user: &'a str,
    ) -> impl Iterator<Item = &'a MdsRecord> + 'a {
        self.records
            .iter()
            .filter(move |r| r.up && tb.spec(r.id).auth.allows(user))
    }

    /// All records (monitoring clients).
    pub fn records(&self) -> &[MdsRecord] {
        &self.records
    }

    /// Look up one record. O(1): records are stored dense in id order.
    pub fn record(&self, id: ResourceId) -> Option<&MdsRecord> {
        self.records.get(id.0 as usize)
    }
}

/// Convenience: specs of discovered resources (tests, GRACE directory).
pub fn discover_specs<'a>(
    mds: &'a Mds,
    tb: &'a Testbed,
    user: &'a str,
) -> Vec<&'a ResourceSpec> {
    mds.discover(tb, user).map(|r| tb.spec(r.id)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (Testbed, Vec<ResourceDyn>) {
        let tb = Testbed::gusto(11, 0.5);
        let mut rng = Rng::new(12);
        let dyns = tb
            .resources
            .iter()
            .map(|s| ResourceDyn::new(s, &mut rng))
            .collect();
        (tb, dyns)
    }

    #[test]
    fn discovery_filters_authorization() {
        let (tb, dyns) = setup();
        let mds = Mds::new(&tb, &dyns);
        let all_up = mds.records().iter().filter(|r| r.up).count();
        let authorized = mds.discover(&tb, "rajkumar").count();
        let stranger = mds.discover(&tb, "stranger").count();
        // rajkumar is on every ACL; stranger only sees AllUsers machines.
        assert_eq!(authorized, all_up);
        assert!(stranger <= authorized);
        let has_restricted = tb
            .resources
            .iter()
            .any(|r| !r.auth.allows("stranger"));
        if has_restricted {
            assert!(stranger < authorized);
        }
    }

    #[test]
    fn staleness_until_refresh() {
        let (tb, mut dyns) = setup();
        let mut mds = Mds::new(&tb, &dyns);
        let victim = tb.resources[0].id;
        // Ground truth changes...
        dyns[victim.0 as usize].up = false;
        // ...but the directory still reports the old state.
        assert!(mds.record(victim).unwrap().up);
        // After refresh the outage is visible.
        mds.refresh(&tb, &dyns, 120.0);
        assert!(!mds.record(victim).unwrap().up);
        assert_eq!(mds.record(victim).unwrap().as_of, 120.0);
        assert!(mds.discover(&tb, "rajkumar").all(|r| r.id != victim));
    }

    #[test]
    fn refresh_reports_only_changed_records() {
        let (tb, mut dyns) = setup();
        let mut mds = Mds::new(&tb, &dyns);
        // Nothing moved since the snapshot: no ids reported.
        assert!(mds.refresh(&tb, &dyns, 60.0).is_empty());
        dyns[3].up = false;
        dyns[5].bg_load = 0.77;
        let changed = mds.refresh(&tb, &dyns, 120.0);
        assert_eq!(changed, vec![tb.resources[3].id, tb.resources[5].id]);
        // Both visible, and a second refresh is quiet again.
        assert!(!mds.record(tb.resources[3].id).unwrap().up);
        assert_eq!(mds.record(tb.resources[5].id).unwrap().bg_load, 0.77);
        assert!(mds.refresh(&tb, &dyns, 180.0).is_empty());
    }

    #[test]
    fn planning_speed_never_negative_under_extreme_load() {
        let (tb, mut dyns) = setup();
        dyns[0].bg_load = 0.95;
        let mut mds = Mds::new(&tb, &dyns);
        mds.refresh(&tb, &dyns, 0.0);
        let rec = mds.record(tb.resources[0].id).unwrap();
        // Overloaded-but-alive machines stay selectable (small positive).
        assert!(rec.planning_speed() > 0.0);
        // Even a corrupt out-of-range load must not flip the sign.
        let mut corrupt = rec.clone();
        corrupt.bg_load = 1.7;
        assert!(corrupt.planning_speed() >= 0.0);
    }

    #[test]
    fn planning_speed_discounts_load() {
        let (tb, mut dyns) = setup();
        dyns[0].bg_load = 0.5;
        let mds = {
            let mut m = Mds::new(&tb, &dyns);
            m.refresh(&tb, &dyns, 0.0);
            m
        };
        let rec = mds.record(tb.resources[0].id).unwrap();
        assert!(
            (rec.planning_speed() - tb.resources[0].speed * 0.5).abs() < 1e-12
        );
    }
}
