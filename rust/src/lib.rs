//! Nimrod/G — resource management and scheduling for a computational grid
//! with a computational economy.
//!
//! Reproduction of Buyya, Abramson, Giddy, *"Nimrod/G: An Architecture for a
//! Resource Management and Scheduling System in a Global Computational
//! Grid"* (HPC Asia 2000), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system: parametric engine, scheduler
//!   with deadline/budget (computational-economy) algorithms, dispatcher,
//!   job-wrapper, the Clustor-style TCP protocol, and a simulated GUSTO
//!   testbed (MDS/GRAM/GASS/GSI analogues) it schedules over.
//! * **L2/L1 (python/, build time)** — the ionization-chamber calibration
//!   workload as a JAX model with a Pallas spectral-transform kernel, lowered
//!   AOT to HLO text.
//! * **runtime** — PJRT CPU client that loads the HLO artifacts so the Rust
//!   job-wrapper executes real compute on the request path (Python never).
//!
//! Start with [`sim::GridSimulation`] (virtual-time experiments, the paper's
//! Figure 3) or `examples/ionization_study.rs` (real execution end to end).

pub mod client;
pub mod config;
pub mod dispatcher;
pub mod economy;
pub mod engine;
pub mod grid;
pub mod metrics;
pub mod plan;
pub mod protocol;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod simtime;
pub mod types;
pub mod util;
pub mod workload;
