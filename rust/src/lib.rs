//! Nimrod/G — resource management and scheduling for a computational grid
//! with a computational economy.
//!
//! Reproduction of Buyya, Abramson, Giddy, *"Nimrod/G: An Architecture for a
//! Resource Management and Scheduling System in a Global Computational
//! Grid"* (HPC Asia 2000), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system: parametric engine, scheduler
//!   with deadline/budget (computational-economy) algorithms, dispatcher,
//!   job-wrapper, the Clustor-style TCP protocol, and a simulated GUSTO
//!   testbed (MDS/GRAM/GASS/GSI analogues) it schedules over.
//! * **L2/L1 (python/, build time)** — the ionization-chamber calibration
//!   workload as a JAX model with a Pallas spectral-transform kernel, lowered
//!   AOT to HLO text.
//! * **runtime** — PJRT CPU client that loads the HLO artifacts so the Rust
//!   job-wrapper executes real compute on the request path (Python never).
//!
//! # Entry point: the broker
//!
//! Experiments are composed and launched through [`broker::Broker`] — the
//! paper's resource-broker facade over the whole component stack:
//!
//! ```no_run
//! use nimrod_g::broker::Broker;
//!
//! // The paper's Figure-3 trial, tuned and reseeded:
//! let report = Broker::experiment()
//!     .deadline_h(20.0)
//!     .budget(2.0e6)
//!     .policy("cost?safety=0.9") // parameterized policy spec
//!     .seed(42)
//!     .run()
//!     .unwrap();
//! println!("{}", report.summary());
//!
//! // Or start from a named scenario preset:
//! let report = Broker::scenario("flash-crowd").unwrap().seed(7).run().unwrap();
//! println!("{}", report.summary());
//! ```
//!
//! [`broker::ExperimentBuilder::simulate`] yields the virtual-time driver
//! ([`sim::GridSimulation`], replaying a 20-hour trial in milliseconds);
//! [`broker::ExperimentBuilder::live`] yields real PJRT execution
//! ([`sim::live::LiveRunner`]). Both drivers delegate their per-tick
//! discovery → selection → assignment pipeline to the shared
//! [`broker::ScheduleAdvisor`]; scheduling policies are constructed through
//! the open, parameterized [`broker::PolicyRegistry`] and allocate off the
//! persistent [`scheduler::CandidateIndex`] — ranked candidate orderings
//! re-keyed incrementally from the same dirty-view deltas that drive
//! discovery, so selection stays sub-linear on 10k-machine grids.
//!
//! Multi-tenant brokering — the paper's *many users competing under a
//! computational economy* — composes through
//! [`broker::ExperimentBuilder::tenant`]: N full experiments (own deadline,
//! budget, policy, journal) share one [`sim::GridWorld`] where tenant
//! occupancy shrinks everyone's visible slots and demand-priced owners
//! reprice with utilization. Try
//! `Broker::scenario("contested-gusto")?.run_world()?`.
//!
//! The economy's market layer is pluggable ([`economy::market`]): posted
//! prices by default, or the paper's §7 GRACE trading layer via
//! [`broker::ExperimentBuilder::grace_market`] — periodic tender/bid
//! auctions whose awards become time-limited price agreements the
//! scheduler and billing both honour. Try
//! `Broker::scenario("grace-auction")?.run_world()?`.
//!
//! Everything above depends on **bit-exact seeded replay**. The coding
//! discipline behind it (ordered containers in tick paths, no wall-clock
//! reads in sim code, total float comparisons, dirty-marks paired with
//! index re-keys, a justified panic budget) is enforced statically by
//! `tools/nimrod-lint` — run `cargo run -p nimrod-lint`, or just
//! `cargo test`: `rust/tests/lint_clean.rs` runs the same pass in-process.
//!
//! See `examples/quickstart.rs` for the plan-language path and
//! `examples/ionization_study.rs` for live execution end to end.

pub mod broker;
pub mod client;
pub mod config;
pub mod dispatcher;
pub mod economy;
pub mod engine;
pub mod grid;
pub mod metrics;
pub mod plan;
pub mod protocol;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod simtime;
pub mod types;
pub mod util;
pub mod workload;
