//! Advance-reservation acceptance: the subsystem must be a strict opt-in.
//! Worlds without a [`ReservationConfig`] must replay the PR-5 pipeline
//! bit-exactly (no RNG drawn, no f64 moved — the composed full-rebuild +
//! full-sort baselines are the equivalence surface), and worlds with one
//! must hold the *extended* slot-conservation invariant — Σ in-flight +
//! competition claims + reserved slots ≤ CPUs — at every step of a churny,
//! contested run while replaying bit-exactly against the same baselines.

use nimrod_g::broker::Broker;
use nimrod_g::economy::reservation::ReservationConfig;
use nimrod_g::grid::competition::CompetitionModel;
use nimrod_g::metrics::WorldReport;
use nimrod_g::sim::GridWorld;
use nimrod_g::types::HOUR;

const SMALL_PLAN: &str = "parameter i integer range from 1 to 30\n\
                          task main\nexecute icc $i\nendtask";

/// Reserve ahead from 5 % of the deadline, so the probe → reserve → commit
/// ladder runs while plenty of work is still undispatched.
fn eager() -> ReservationConfig {
    ReservationConfig {
        trigger_frac: 0.05,
        ..ReservationConfig::default()
    }
}

/// A contested two-tenant world on the churny 0.4-scale GUSTO grid:
/// availability churn, demand repricing and background claims all dirty
/// views mid-run. `rsv` switches the reservation subsystem on.
fn contested_world(seed: u64, rsv: Option<ReservationConfig>) -> GridWorld {
    let mut b = Broker::experiment()
        .plan(SMALL_PLAN)
        .deadline_h(20.0)
        .policy("cost")
        .budget(2.0e6)
        .seed(seed)
        .testbed_scale(0.4)
        .demand_pricing(0.8)
        .competition(CompetitionModel {
            mean_interarrival_s: 1200.0,
            mean_duration_s: 2.0 * 3600.0,
            mean_cpus: 20.0,
        })
        .tweak_testbed(|tb| {
            for spec in &mut tb.resources {
                spec.mtbf_s = 2.0 * 3600.0;
                spec.mttr_s = 0.4 * 3600.0;
            }
        })
        .tenant(
            Broker::experiment()
                .plan(SMALL_PLAN)
                .deadline_h(12.0)
                .policy("time")
                .user("davida")
                .budget(2.0e6),
        );
    if let Some(cfg) = rsv {
        b = b.reservations(cfg);
    }
    b.world().unwrap()
}

/// Assert two world runs replayed the identical trace, bit for bit.
fn assert_same_trace(a: &WorldReport, b: &WorldReport, tag: &str) {
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{tag}");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let who = format!("{tag}/{} ({})", x.user, x.policy);
        assert_eq!(x.report.ticks, y.report.ticks, "{who}: ticks");
        assert_eq!(
            x.report.jobs_completed, y.report.jobs_completed,
            "{who}: completions"
        );
        assert_eq!(
            x.report.makespan_s.to_bits(),
            y.report.makespan_s.to_bits(),
            "{who}: makespan"
        );
        assert_eq!(
            x.report.total_cost.to_bits(),
            y.report.total_cost.to_bits(),
            "{who}: spend"
        );
        assert_eq!(
            x.report.busy_cpus.points(),
            y.report.busy_cpus.points(),
            "{who}: busy-cpu timeline"
        );
        assert_eq!(
            x.reservations_committed, y.reservations_committed,
            "{who}: commits"
        );
        assert_eq!(
            x.penalty_spend.to_bits(),
            y.penalty_spend.to_bits(),
            "{who}: penalties"
        );
    }
}

/// Run `build()` twice — incremental versus both forced baselines — and
/// demand identical traces (the PR-5 equivalence surface).
fn check_against_baselines(build: impl Fn() -> GridWorld, tag: &str) {
    let incremental = build().run_world();
    let mut forced = build();
    forced.set_full_view_rebuild(true);
    forced.set_full_allocation_sort(true);
    let baseline = forced.run_world();
    assert_same_trace(&incremental, &baseline, tag);
}

#[test]
fn disabled_worlds_replay_the_pre_reservation_pipeline_bit_exactly() {
    // No ReservationConfig ⇒ the subsystem must be inert: the whole
    // reservation machinery (occupancy terms, expiry sweeps, rate
    // overrides) must leave the trace exactly where the PR-5 pipeline
    // left it, across seeds, against the composed baselines.
    for seed in [3u64, 11] {
        check_against_baselines(
            || contested_world(seed, None),
            &format!("disabled/seed{seed}"),
        );
    }
    // And such worlds carry no reservation data at all.
    let wr = contested_world(3, None).run_world();
    assert!(!wr.has_reservation_data());
    for t in &wr.tenants {
        assert_eq!(t.reservation_probes, 0, "{}", t.user);
        assert_eq!(t.reservations_committed, 0, "{}", t.user);
        assert_eq!(t.reservations_cancelled, 0, "{}", t.user);
        assert_eq!(t.held_slot_seconds.to_bits(), 0.0f64.to_bits());
        assert_eq!(t.penalty_spend.to_bits(), 0.0f64.to_bits());
    }
}

#[test]
fn enabled_worlds_match_the_baselines_bit_exactly() {
    // With the subsystem on, every hold transition must dirty views and
    // index entries exactly like any other occupancy event: the composed
    // rebuild-everything baselines must replay the identical trace. The
    // short-hold variant forces commit timeouts and binding-hold expiries
    // (with their penalties) into the compared traces.
    let quick_lapse = ReservationConfig {
        trigger_frac: 0.05,
        hold_s: 1800.0,
        ..ReservationConfig::default()
    };
    for (cfg, tag) in [(eager(), "default"), (quick_lapse, "quick-lapse")] {
        check_against_baselines(
            || contested_world(7, Some(cfg.clone())),
            &format!("enabled/{tag}"),
        );
    }
}

#[test]
fn extended_slot_conservation_holds_under_churn_and_reservations() {
    // The property the subsystem must never break: at every 0.25 h step of
    // a run with machine churn, background claims and live holds,
    // Σ in-flight + claims + reserved ≤ CPUs on every machine, and no
    // tenant's exposure exceeds its budget (penalty envelopes included).
    for seed in [3u64, 7, 21] {
        let mut world = contested_world(seed, Some(eager()));
        let mut t = 0.0;
        while !world.finished() && t < 60.0 * HOUR {
            t += 0.25 * HOUR;
            world.run_until(t);
            assert!(
                world.slot_conservation_ok(),
                "seed {seed}: slot conservation violated at t={t}"
            );
            for tid in 0..world.tenant_count() {
                let ledger = world.ledger(tid);
                if let Some(budget) = ledger.budget() {
                    assert!(
                        ledger.exposure() <= budget + 1e-6,
                        "seed {seed} tenant {tid}: exposure {} over budget \
                         {budget} at t={t}",
                        ledger.exposure()
                    );
                }
            }
        }
        assert!(world.finished(), "seed {seed}: world should finish in 60h");
        // The run actually exercised the machinery it claims to test.
        let holds_seen: u32 = (0..world.tenant_count())
            .map(|tid| world.reservations_of(tid).reserves)
            .sum();
        assert!(holds_seen > 0, "seed {seed}: no hold was ever taken");
    }
}

#[test]
fn reserve_ahead_commits_the_cheapest_probed_set() {
    // The acceptance experiment: a DBC tenant past its trigger probes
    // several candidate sets and commits the cheapest feasible one —
    // visible as probes from ≥ 2 sets, at least one commitment, and
    // held-slot time actually accrued.
    let wr = contested_world(13, Some(eager())).run_world();
    for t in &wr.tenants {
        assert_eq!(
            t.report.jobs_completed + t.report.jobs_failed,
            t.report.jobs_total,
            "{}: {}",
            t.user,
            t.report.summary()
        );
    }
    assert!(wr.has_reservation_data());
    let probes: u64 = wr.tenants.iter().map(|t| t.reservation_probes).sum();
    assert!(probes >= 2, "must probe ≥ 2 candidate sets, saw {probes}");
    assert!(
        wr.reservations_committed() > 0,
        "a tenant must commit a hold: {}",
        wr.summary()
    );
    let held: f64 = wr.tenants.iter().map(|t| t.held_slot_seconds).sum();
    assert!(held > 0.0, "commitments must accrue held slot-seconds");
}
