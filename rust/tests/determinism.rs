//! Determinism regression: the same seeded world, built twice and run
//! twice, must replay the identical trace — event counts, per-tenant
//! outcomes (f64s compared bit-for-bit), busy-CPU timelines and the price
//! trajectories. This is the runtime backstop behind the `nimrod-lint`
//! determinism rules (ND-HASH/ND-CLOCK/ND-FLOAT): if unordered iteration,
//! a wall-clock read or a NaN-partial comparator ever leaks into the tick
//! path, these traces diverge.
//!
//! The world builder and the bit-exact comparator live in
//! `tests/common/mod.rs`, shared with `parallel_equivalence.rs` (which
//! replays the same worlds across thread counts).

mod common;

use common::{assert_identical, contested_world};
use nimrod_g::broker::Broker;

#[test]
fn contested_world_replays_bit_exactly_across_seeds() {
    for seed in [7u64, 23] {
        let a = contested_world(seed).run_world();
        let b = contested_world(seed).run_world();
        assert_identical(&a, &b, &format!("contested/seed{seed}"));
    }
}

#[test]
fn grace_auction_scenario_replays_bit_exactly() {
    // The market layer adds tender/bid rounds, agreements and clearing
    // prices on top of the tick pipeline — all of it must replay too.
    let run = || {
        Broker::scenario("grace-auction")
            .expect("known scenario")
            .seed(11)
            .run_world()
            .expect("scenario runs")
    };
    let a = run();
    let b = run();
    assert_identical(&a, &b, "grace-auction/seed11");
}
