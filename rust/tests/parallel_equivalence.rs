//! Parallel-tick equivalence: the three-phase batched tenant tick must
//! replay bit-for-bit at every thread count. `threads(1)` is the reference
//! path — it runs the identical snapshot → per-tenant → merge pipeline,
//! just on the calling thread — so any divergence at 2, 4 or 8 workers
//! means shared state leaked into the parallel phase (the PAR-SHARED
//! lint's runtime backstop, the way `determinism.rs` backstops ND-*).
//! Multi-threaded runs go through the persistent `WorkerPool`, so this
//! suite is also the pool's end-to-end determinism proof — and since the
//! pool path now defaults to the streaming ordered merge (commits applied
//! in tenant order while later shards still run), it proves the commit
//! queue too. `set_barrier_merge(true)` variants pin the PR-9 drain-after-
//! barrier path to the same trace.
//!
//! Worlds and the bit-exact comparator come from `tests/common/mod.rs`.

mod common;

use common::{assert_identical, contested_builder};
use nimrod_g::broker::Broker;
use nimrod_g::metrics::WorldReport;

/// Thread counts the suite proves equivalent. 4 and 8 exceed the 3
/// tenants in the small worlds here, so they also exercise the builder's
/// clamp path and pool rounds narrower than the lane count.
const THREADS: [usize; 3] = [2, 4, 8];

fn contested(seed: u64, threads: usize) -> WorldReport {
    contested_builder(seed)
        .threads(threads)
        .world()
        .expect("world builds")
        .run_world()
}

/// The contested world forced back onto the pre-pipelining barrier merge
/// (phase 3 drains only after every shard has finished).
fn contested_barrier(seed: u64, threads: usize) -> WorldReport {
    let mut world = contested_builder(seed)
        .threads(threads)
        .world()
        .expect("world builds");
    world.set_barrier_merge(true);
    world.run_world()
}

fn scenario(name: &str, seed: u64, threads: usize) -> WorldReport {
    Broker::scenario(name)
        .expect("known scenario")
        .seed(seed)
        .threads(threads)
        .run_world()
        .expect("scenario runs")
}

/// A scenario preset run under the barrier merge instead of the default
/// streaming ordered merge.
fn scenario_barrier(name: &str, seed: u64, threads: usize) -> WorldReport {
    let mut world = Broker::scenario(name)
        .expect("known scenario")
        .seed(seed)
        .threads(threads)
        .world()
        .expect("world builds");
    world.set_barrier_merge(true);
    world.run_world()
}

#[test]
fn contested_world_is_bit_exact_across_thread_counts() {
    for seed in [7u64, 23] {
        let sequential = contested(seed, 1);
        // The worlds here tick every tenant on the same period from t=0,
        // so multi-member batches must actually have formed — otherwise
        // this suite would pass vacuously without ever running the
        // parallel phase.
        assert!(
            sequential.parallel_ns > 0,
            "contested/seed{seed}: no tick batch ever coalesced"
        );
        for threads in THREADS {
            let parallel = contested(seed, threads);
            assert_identical(
                &sequential,
                &parallel,
                &format!("contested/seed{seed}/threads{threads}"),
            );
        }
    }
}

#[test]
fn contested_world_barrier_merge_matches_streaming_at_every_lane_count() {
    // The streaming ordered merge (commits applied in tenant order while
    // higher shards still run) and the PR-9 barrier merge (commits drained
    // only after the whole batch lands) are the same trace by
    // construction — prove it at every lane count, against the sequential
    // reference and against each other.
    let sequential = contested(7, 1);
    for threads in [1, 2, 4, 8] {
        let streaming = contested(7, threads);
        let barrier = contested_barrier(7, threads);
        assert_identical(
            &sequential,
            &streaming,
            &format!("contested/streaming/threads{threads}"),
        );
        assert_identical(
            &sequential,
            &barrier,
            &format!("contested/barrier/threads{threads}"),
        );
        // Overlap telemetry is the observable difference between the
        // modes: a barrier drain can never overlap the lanes.
        assert_eq!(
            barrier.merge_overlap_ns, 0,
            "barrier merge reported overlapped commit time at {threads} lanes"
        );
    }
}

#[test]
fn grace_auction_world_is_bit_exact_across_thread_counts() {
    // Tender/bid negotiation, agreements and clearing prices all ride on
    // the tick pipeline; the streaming commit queue must not reorder any
    // of it, and neither may the barrier fallback.
    let sequential = scenario("grace-auction", 11, 1);
    for threads in THREADS {
        let parallel = scenario("grace-auction", 11, threads);
        assert_identical(
            &sequential,
            &parallel,
            &format!("grace-auction/threads{threads}"),
        );
    }
    let barrier = scenario_barrier("grace-auction", 11, 4);
    assert_identical(&sequential, &barrier, "grace-auction/barrier/threads4");
}

#[test]
fn reserve_ahead_world_is_bit_exact_across_thread_counts() {
    // Reservations mutate shared slot accounting (holds, ledgers,
    // total_reserved) — all of it stays in the sequential snapshot phase,
    // and this proves the parallel phase observes it identically.
    let sequential = scenario("reserve-ahead", 5, 1);
    for threads in THREADS {
        let parallel = scenario("reserve-ahead", 5, threads);
        assert_identical(
            &sequential,
            &parallel,
            &format!("reserve-ahead/threads{threads}"),
        );
    }
    // The committed-hold fast path in the merge capacity guard must agree
    // across merge modes too.
    let barrier = scenario_barrier("reserve-ahead", 5, 4);
    assert_identical(&sequential, &barrier, "reserve-ahead/barrier/threads4");
}

#[test]
fn world_storm_replays_bit_exactly_at_every_lane_count_and_merge_mode() {
    // The 256-tenant population-stress preset: every tenant ticks on the
    // same period, so each tick is one 256-member batch fanned across the
    // pool — the widest scatter anything in-tree produces, and far more
    // shards than lanes, so the claim counter (and the sticky per-lane
    // affinity ranges under it) is exercised hard. The streaming commit
    // queue sees its deepest reorder window here: lane counts far below
    // the shard count keep the commit frontier trailing the fan-out.
    let sequential = scenario("world-storm", 7, 1);
    assert!(
        sequential.parallel_ns > 0,
        "world-storm: no tick batch ever coalesced"
    );
    for threads in THREADS {
        let pooled = scenario("world-storm", 7, threads);
        assert_identical(
            &sequential,
            &pooled,
            &format!("world-storm/threads{threads}"),
        );
    }
    let barrier = scenario_barrier("world-storm", 7, 8);
    assert_identical(&sequential, &barrier, "world-storm/barrier/threads8");
}

#[test]
fn pooled_runs_populate_pool_and_phase_telemetry() {
    // A multi-threaded run must actually have gone through the persistent
    // pool (not silently fallen back to some other path), and the
    // three-phase timers must all be wired: a zero would mean a phase's
    // instrumentation was dropped in a refactor.
    let pooled = contested(7, 4);
    assert!(
        pooled.pool_workers > 1,
        "pooled run reports {} pool workers",
        pooled.pool_workers
    );
    assert!(
        pooled.pool_rounds > 0,
        "pooled run never scattered a batch through the pool"
    );
    assert!(pooled.snapshot_ns > 0, "snapshot phase timer not populated");
    assert!(pooled.parallel_ns > 0, "parallel phase timer not populated");
    assert!(pooled.merge_ns > 0, "merge phase timer not populated");
    // The reference path never builds a pool.
    let sequential = contested(7, 1);
    assert_eq!(sequential.pool_workers, 0, "threads(1) must stay pool-free");
    assert_eq!(sequential.pool_rounds, 0, "threads(1) must stay pool-free");
}
