//! Parallel-tick equivalence: the three-phase batched tenant tick must
//! replay bit-for-bit at every thread count. `threads(1)` is the reference
//! path — it runs the identical snapshot → per-tenant → merge pipeline,
//! just on the calling thread — so any divergence at 2 or 4 workers means
//! shared state leaked into the parallel phase (the PAR-SHARED lint's
//! runtime backstop, the way `determinism.rs` backstops ND-*).
//!
//! Worlds and the bit-exact comparator come from `tests/common/mod.rs`.

mod common;

use common::{assert_identical, contested_builder};
use nimrod_g::broker::Broker;
use nimrod_g::metrics::WorldReport;

/// Thread counts the suite proves equivalent. 4 exceeds the 3 tenants in
/// every world here, so it also exercises the builder's clamp path.
const THREADS: [usize; 2] = [2, 4];

fn contested(seed: u64, threads: usize) -> WorldReport {
    contested_builder(seed)
        .threads(threads)
        .world()
        .expect("world builds")
        .run_world()
}

fn scenario(name: &str, seed: u64, threads: usize) -> WorldReport {
    Broker::scenario(name)
        .expect("known scenario")
        .seed(seed)
        .threads(threads)
        .run_world()
        .expect("scenario runs")
}

#[test]
fn contested_world_is_bit_exact_across_thread_counts() {
    for seed in [7u64, 23] {
        let sequential = contested(seed, 1);
        // The worlds here tick every tenant on the same period from t=0,
        // so multi-member batches must actually have formed — otherwise
        // this suite would pass vacuously without ever running the
        // parallel phase.
        assert!(
            sequential.parallel_ns > 0,
            "contested/seed{seed}: no tick batch ever coalesced"
        );
        for threads in THREADS {
            let parallel = contested(seed, threads);
            assert_identical(
                &sequential,
                &parallel,
                &format!("contested/seed{seed}/threads{threads}"),
            );
        }
    }
}

#[test]
fn grace_auction_world_is_bit_exact_across_thread_counts() {
    // Tender/bid negotiation, agreements and clearing prices all ride on
    // the tick pipeline; the merge barrier must not reorder any of it.
    let sequential = scenario("grace-auction", 11, 1);
    for threads in THREADS {
        let parallel = scenario("grace-auction", 11, threads);
        assert_identical(
            &sequential,
            &parallel,
            &format!("grace-auction/threads{threads}"),
        );
    }
}

#[test]
fn reserve_ahead_world_is_bit_exact_across_thread_counts() {
    // Reservations mutate shared slot accounting (holds, ledgers,
    // total_reserved) — all of it stays in the sequential snapshot phase,
    // and this proves the parallel phase observes it identically.
    let sequential = scenario("reserve-ahead", 5, 1);
    for threads in THREADS {
        let parallel = scenario("reserve-ahead", 5, threads);
        assert_identical(
            &sequential,
            &parallel,
            &format!("reserve-ahead/threads{threads}"),
        );
    }
}
