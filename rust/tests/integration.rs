//! Integration tests: whole-system behaviour over the simulated grid.

use nimrod_g::config::ExperimentConfig;
use nimrod_g::engine::journal::{recover, Journal};
use nimrod_g::grid::Testbed;
use nimrod_g::plan::{expand, Plan};
use nimrod_g::sim::GridSimulation;
use nimrod_g::types::HOUR;
use nimrod_g::workload::{ionization_jobs, ionization_plan};

fn cfg(policy: &str, deadline_h: f64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        policy: policy.into(),
        deadline: deadline_h * HOUR,
        seed,
        ..Default::default()
    }
}

#[test]
fn figure3_shape_tight_deadline_uses_more_and_costs_more() {
    let tight = GridSimulation::gusto_ionization(cfg("cost", 10.0, 0xF1)).run();
    let mid = GridSimulation::gusto_ionization(cfg("cost", 15.0, 0xF1)).run();
    let loose = GridSimulation::gusto_ionization(cfg("cost", 20.0, 0xF1)).run();
    for r in [&tight, &mid, &loose] {
        assert_eq!(r.jobs_completed, 165, "{}", r.summary());
        assert!(r.deadline_met, "{}", r.summary());
    }
    let avg = |r: &nimrod_g::metrics::Report| r.busy_cpus.average(r.makespan_s);
    assert!(
        avg(&tight) > avg(&mid) && avg(&mid) > avg(&loose),
        "processors-in-use must decrease with relaxed deadline: {:.1} / {:.1} / {:.1}",
        avg(&tight),
        avg(&mid),
        avg(&loose)
    );
    assert!(
        tight.total_cost > loose.total_cost,
        "tight deadline must cost more: {} vs {}",
        tight.total_cost,
        loose.total_cost
    );
}

#[test]
fn economy_beats_performance_only_on_cost() {
    let cost = GridSimulation::gusto_ionization(cfg("cost", 15.0, 0xB2)).run();
    let perf = GridSimulation::gusto_ionization(cfg("perf", 15.0, 0xB2)).run();
    assert!(cost.deadline_met, "{}", cost.summary());
    assert!(
        cost.total_cost < perf.total_cost,
        "economy-aware scheduling must be cheaper at an equal (met) deadline: {} vs {}",
        cost.total_cost,
        perf.total_cost
    );
}

#[test]
fn failure_churn_is_survived_by_retries() {
    // A flaky testbed: every machine fails every ~2 simulated hours.
    let mut tb = Testbed::gusto(5, 0.5);
    for spec in &mut tb.resources {
        spec.mtbf_s = 2.0 * 3600.0;
        spec.mttr_s = 0.5 * 3600.0;
    }
    let specs = ionization_jobs(5);
    let mut c = cfg("time", 40.0, 5);
    c.max_attempts = 8;
    let r = GridSimulation::new(tb, specs, c).run();
    assert!(
        r.jobs_completed >= 160,
        "retries should carry most jobs through churn: {}",
        r.summary()
    );
    // Failures actually happened (the testbed really was flaky).
    let failures: u32 = r.per_resource.values().map(|u| u.jobs_failed).sum();
    assert!(failures > 0, "expected some failures under churn");
}

#[test]
fn journal_restart_roundtrip_at_scale() {
    let dir = std::env::temp_dir().join(format!("nimrod-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.journal");
    let c = cfg("cost", 15.0, 0x7E57);
    let plan_src = ionization_plan(11, 5, 3);
    let specs = ionization_jobs(c.seed);
    let tb = Testbed::gusto(c.seed ^ 0x6057, 1.0);

    let mut sim = GridSimulation::new(tb.clone(), specs, c.clone());
    let journal = Journal::create(&path, &plan_src, c.seed, sim.exp()).unwrap();
    sim = sim.with_journal(journal);
    sim.run_until(4.0 * HOUR);
    let done_at_crash = sim.exp().completed();
    assert!(done_at_crash > 5, "some progress before the crash");
    assert!(!sim.exp().finished());
    drop(sim);

    let rec = recover(&path).unwrap();
    assert_eq!(rec.experiment.completed(), done_at_crash);
    let journal = Journal::append_to(&path).unwrap();
    let r = GridSimulation::new(tb, Vec::new(), c)
        .with_experiment(rec.experiment)
        .with_journal(journal)
        .run();
    assert_eq!(r.jobs_completed + r.jobs_failed, 165);
    assert!(r.jobs_completed >= 160, "{}", r.summary());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn budget_sweep_monotone_spend() {
    let mut spends = Vec::new();
    for budget in [3e6, 1e6, 3e5] {
        let mut c = cfg("cost", 15.0, 0xB4D);
        c.budget = Some(budget);
        let r = GridSimulation::gusto_ionization(c).run();
        assert!(
            r.total_cost <= budget + 1e-6,
            "budget {} exceeded: {}",
            budget,
            r.total_cost
        );
        spends.push(r.total_cost);
    }
    assert!(
        spends[0] >= spends[1] && spends[1] >= spends[2],
        "tighter budget cannot increase spend: {spends:?}"
    );
}

#[test]
fn restricted_user_never_runs_on_unauthorized_machines() {
    // "stranger" is outside every restrictive gridmap; discovery must prune
    // those machines, so no job may ever land on one.
    let mut c = cfg("time", 30.0, 0xACE);
    c.user = "stranger".into();
    let seed = c.seed;
    let restricted = GridSimulation::gusto_ionization(c).run();
    assert!(restricted.jobs_completed >= 160, "{}", restricted.summary());

    let tb = Testbed::gusto(seed ^ 0x6057, 1.0);
    let forbidden: Vec<&str> = tb
        .resources
        .iter()
        .filter(|r| !r.auth.allows("stranger"))
        .map(|r| r.name.as_str())
        .collect();
    assert!(!forbidden.is_empty(), "testbed should have some ACLed machines");
    for name in restricted.per_resource.keys() {
        assert!(
            !forbidden.contains(&name.as_str()),
            "job ran on unauthorized machine {name}"
        );
    }
}

#[test]
fn plan_file_through_cli_surface() {
    // The same plan text a user would pass to `nimrod run --plan`.
    let src = r#"
parameter voltage float range from 100 to 1000 step 300
parameter energy float select anyof 5 15
task main
    copy chamber.cfg node:chamber.cfg
    execute ./icc_sim -v $voltage -e $energy -o out.dat
    copy node:out.dat results.$jobname.dat
endtask
"#;
    let plan = Plan::parse(src).unwrap();
    let specs = expand(&plan, 1).unwrap();
    assert_eq!(specs.len(), 8);
    let tb = Testbed::gusto(1, 0.3);
    let r = GridSimulation::new(tb, specs, cfg("cost", 20.0, 1)).run();
    assert_eq!(r.jobs_completed, 8, "{}", r.summary());
}

#[test]
fn competition_raises_cost_and_shifts_resources() {
    // Paper §3: "the cost changes as other competing experiments are put on
    // the grid" — with background task farms claiming CPUs and triggering
    // demand premiums, the same experiment must cost more.
    let quiet = GridSimulation::gusto_ionization(cfg("cost", 20.0, 0xC0)).run();
    let mut c = cfg("cost", 20.0, 0xC0);
    c.competition = Some(nimrod_g::grid::competition::CompetitionModel {
        mean_interarrival_s: 1800.0, // busy grid: a new competitor every 30 min
        mean_duration_s: 4.0 * 3600.0,
        mean_cpus: 60.0,
    });
    let busy = GridSimulation::gusto_ionization(c).run();
    assert!(busy.jobs_completed >= 160, "{}", busy.summary());
    assert!(
        busy.total_cost > quiet.total_cost,
        "competition must raise cost: {} vs {}",
        busy.total_cost,
        quiet.total_cost
    );
}

#[test]
fn deterministic_replay_full_stack() {
    let a = GridSimulation::gusto_ionization(cfg("cost", 15.0, 0xD0)).run();
    let b = GridSimulation::gusto_ionization(cfg("cost", 15.0, 0xD0)).run();
    assert_eq!(a.events, b.events);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.total_cost, b.total_cost);
    assert_eq!(a.busy_cpus.points(), b.busy_cpus.points());
}
