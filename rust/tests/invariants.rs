//! Property-based invariant tests (seeded random cases; see
//! `nimrod_g::util::prop` — failures report the case seed).

use nimrod_g::broker::{Broker, PolicyRegistry};
use nimrod_g::economy::Ledger;
use nimrod_g::engine::Experiment;
use nimrod_g::grid::gram::JobManager;
use nimrod_g::grid::testbed::{AuthPolicy, QueueKind, ResourceSpec, Testbed};
use nimrod_g::plan::{expand, Plan};
use nimrod_g::prop_assert;
use nimrod_g::scheduler::{CandidateIndex, ResourceView, SchedCtx, ALL_POLICIES};
use nimrod_g::simtime::EventQueue;
use nimrod_g::types::{Arch, JobId, Os, ResourceId, SiteId, HOUR};
use nimrod_g::util::prop::prop_check;
use nimrod_g::util::rng::Rng;

#[test]
fn prop_plan_expansion_cardinality_is_domain_product() {
    prop_check(128, |rng| {
        let n_params = rng.below(4) + 1;
        let mut src = String::new();
        let mut expected = 1usize;
        for p in 0..n_params {
            match rng.below(3) {
                0 => {
                    let n = rng.below(6) + 1;
                    src.push_str(&format!(
                        "parameter p{p} integer range from 1 to {n}\n"
                    ));
                    expected *= n;
                }
                1 => {
                    let n = rng.below(5) + 1;
                    src.push_str(&format!(
                        "parameter p{p} float random from 0 to 1 count {n}\n"
                    ));
                    expected *= n;
                }
                _ => {
                    let n = rng.below(4) + 1;
                    let vals: Vec<String> =
                        (0..n).map(|i| format!("{}", i as f64 + 0.5)).collect();
                    src.push_str(&format!(
                        "parameter p{p} float select anyof {}\n",
                        vals.join(" ")
                    ));
                    expected *= n;
                }
            }
        }
        src.push_str("task main\nexecute run");
        for p in 0..n_params {
            src.push_str(&format!(" $p{p}"));
        }
        src.push_str("\nendtask\n");
        let plan = Plan::parse(&src).map_err(|e| e.to_string())?;
        let jobs = expand(&plan, rng.next_u64()).map_err(|e| e.to_string())?;
        prop_assert!(
            jobs.len() == expected,
            "expected {expected} jobs, got {} for plan:\n{src}",
            jobs.len()
        );
        // No job carries an unsubstituted reference.
        for job in &jobs {
            for op in &job.script {
                if let nimrod_g::plan::TaskOp::Execute { command } = op {
                    prop_assert!(
                        !command.contains('$'),
                        "unsubstituted var in `{command}`"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_event_queue_pops_in_nondecreasing_time_order() {
    prop_check(256, |rng| {
        let mut q = EventQueue::new();
        let n = rng.below(200) + 1;
        for i in 0..n {
            q.schedule_at(rng.uniform(0.0, 1000.0), i);
        }
        let mut last = f64::NEG_INFINITY;
        let mut popped = 0;
        while let Some((t, _)) = q.pop() {
            prop_assert!(t >= last, "time went backwards: {t} < {last}");
            last = t;
            popped += 1;
        }
        prop_assert!(popped == n, "lost events: {popped} != {n}");
        Ok(())
    });
}

#[test]
fn prop_ledger_never_exceeds_budget_and_conserves() {
    prop_check(256, |rng| {
        let budget = rng.uniform(10.0, 1000.0);
        let mut ledger = Ledger::new(Some(budget));
        let mut in_flight: Vec<(JobId, f64)> = Vec::new();
        let mut next = 0u32;
        // Ledger guarantee: exposure stays within budget *up to the
        // cumulative overshoot of actual over estimate* (and of partial
        // billing on release) — commit-time enforcement cannot see the
        // future. Track that slack exactly.
        let mut slack = 0.0f64;
        for _ in 0..rng.below(300) {
            match rng.below(3) {
                0 => {
                    let est = rng.uniform(0.0, 80.0);
                    if ledger.commit(JobId(next), est) {
                        in_flight.push((JobId(next), est));
                    }
                    next += 1;
                }
                1 if !in_flight.is_empty() => {
                    let (j, est) =
                        in_flight.swap_remove(rng.below(in_flight.len()));
                    // Actual cost may overshoot the estimate.
                    let actual = rng.uniform(0.0, 90.0);
                    slack += (actual - est).max(0.0);
                    ledger.settle(j, actual, "r");
                }
                _ if !in_flight.is_empty() => {
                    let (j, _) =
                        in_flight.swap_remove(rng.below(in_flight.len()));
                    let partial = rng.uniform(0.0, 5.0);
                    slack += partial;
                    ledger.release(j, partial, "r");
                }
                _ => {}
            }
            prop_assert!(
                ledger.exposure() <= budget + slack + 1e-9,
                "exposure {} past budget {} + slack {}",
                ledger.exposure(),
                budget,
                slack
            );
            prop_assert!(ledger.check_conservation(), "per-resource sums diverged");
        }
        // Commit-time enforcement: with everything settled, spend can only
        // exceed the budget by accumulated (actual - estimate) overshoot,
        // never by new commitments.
        for (j, _) in in_flight.drain(..) {
            ledger.release(j, 0.0, "r");
        }
        prop_assert!(ledger.committed() == 0.0, "commitments leak");
        Ok(())
    });
}

#[test]
fn prop_settled_plus_committed_never_exceeds_budget_under_churn() {
    // The ledger's core guarantee, stated directly: as long as every
    // settlement/partial bill stays within its job's committed estimate,
    // `settled + committed` (exposure) can never pass the budget — across
    // arbitrary interleavings of dispatch, settle, fail and cancel — and
    // the clamped headroom never goes negative.
    prop_check(192, |rng| {
        let budget = rng.uniform(50.0, 2000.0);
        let mut ledger = Ledger::new(Some(budget));
        let mut in_flight: Vec<(JobId, f64)> = Vec::new();
        let mut next = 0u32;
        for _ in 0..rng.below(400) {
            match rng.below(4) {
                0 => {
                    // Dispatch: commit the cost estimate.
                    let est = rng.uniform(0.0, 120.0);
                    if ledger.commit(JobId(next), est) {
                        in_flight.push((JobId(next), est));
                    }
                    next += 1;
                }
                1 if !in_flight.is_empty() => {
                    // Complete: settle at or below the estimate.
                    let (j, est) =
                        in_flight.swap_remove(rng.below(in_flight.len()));
                    ledger.settle(j, rng.uniform(0.0, est), "r");
                }
                2 if !in_flight.is_empty() => {
                    // Fail: bill partial use, within the estimate.
                    let (j, est) =
                        in_flight.swap_remove(rng.below(in_flight.len()));
                    ledger.release(j, rng.uniform(0.0, est), "r");
                }
                3 if !in_flight.is_empty() => {
                    // Cancel: clean release, nothing billed.
                    let (j, _) =
                        in_flight.swap_remove(rng.below(in_flight.len()));
                    ledger.release(j, 0.0, "r");
                }
                _ => {}
            }
            prop_assert!(
                ledger.exposure() <= budget + 1e-9,
                "settled {} + committed {} exceeds budget {}",
                ledger.settled(),
                ledger.committed(),
                budget
            );
            let headroom = ledger.headroom().expect("budgeted ledger");
            prop_assert!(headroom >= 0.0, "headroom went negative: {headroom}");
            prop_assert!(
                ledger.check_conservation(),
                "per-resource sums diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_job_state_machine_counts_consistent() {
    prop_check(128, |rng| {
        let n = rng.below(30) + 2;
        let src = format!(
            "parameter i integer range from 1 to {n}\ntask main\nexecute r $i\nendtask"
        );
        let specs = expand(&Plan::parse(&src).unwrap(), 0).unwrap();
        let mut exp = Experiment::new(specs, 3600.0, None, "u", 3);
        for _ in 0..rng.below(400) {
            let id = JobId(rng.below(n) as u32);
            match rng.below(5) {
                0 => {
                    let _ = exp.dispatch(id, ResourceId(rng.below(8) as u32), 0.0);
                }
                1 => {
                    let _ = exp.start(id, 1.0);
                }
                2 => {
                    let _ = exp.complete(id, 2.0, 10.0, 1.0);
                }
                3 => {
                    let _ = exp.fail_attempt(id);
                }
                _ => {
                    let _ = exp.release(id);
                }
            }
            let done = exp.completed();
            let failed = exp.failed();
            let remaining = exp.remaining();
            prop_assert!(
                done + failed + remaining == n as u32,
                "counts diverged: {done}+{failed}+{remaining} != {n}"
            );
            // The engine's incremental rollups (terminal counters, Ready
            // set, per-resource in-flight/queued tables) must agree with a
            // full job-table scan after every transition.
            prop_assert!(
                exp.counts_consistent(),
                "incremental rollups drifted from the job table"
            );
            for rid in 0..8u32 {
                let scan = exp
                    .jobs
                    .iter()
                    .filter(|j| j.state.resource() == Some(ResourceId(rid)))
                    .count() as u32;
                prop_assert!(
                    exp.in_flight_on(ResourceId(rid)) == scan,
                    "in-flight counter drifted on r{rid}"
                );
            }
            // Attempts never exceed max.
            for job in &exp.jobs {
                prop_assert!(
                    job.attempts <= 3,
                    "job {} has {} attempts",
                    job.spec.id,
                    job.attempts
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gram_never_runs_more_than_slots() {
    prop_check(128, |rng| {
        let cpus = rng.below(8) as u32 + 1;
        let queue = if rng.chance(0.5) {
            QueueKind::Interactive
        } else {
            QueueKind::Batch {
                slots: rng.below(6) as u32 + 1,
                cycle_s: 30.0,
            }
        };
        let spec = ResourceSpec {
            id: ResourceId(0),
            name: "t".into(),
            site: SiteId(0),
            arch: Arch::Intel,
            os: Os::Linux,
            cpus,
            speed: 1.0,
            mem_mb: 128,
            queue,
            auth: AuthPolicy::AllUsers,
            price: nimrod_g::economy::PriceModel::flat(1.0),
            mtbf_s: 1e9,
            mttr_s: 1.0,
            bg_load_mean: 0.0,
            bg_load_vol: 0.0,
            private_cluster: false,
        };
        let mut jm = JobManager::new(&spec);
        let mut next = 0u32;
        let mut running: Vec<JobId> = Vec::new();
        for _ in 0..rng.below(200) {
            match rng.below(4) {
                0 => {
                    jm.submit(JobId(next));
                    next += 1;
                }
                1 => {
                    for (j, _) in jm.start_eligible(0.0) {
                        running.push(j);
                    }
                }
                2 if !running.is_empty() => {
                    let j = running.swap_remove(rng.below(running.len()));
                    jm.complete(j);
                }
                _ => {
                    if rng.chance(0.1) {
                        jm.fail_all();
                        running.clear();
                    }
                }
            }
            prop_assert!(
                jm.active_count() <= jm.slots(),
                "running {} > slots {}",
                jm.active_count(),
                jm.slots()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_policies_respect_slots_and_skip_down_resources() {
    prop_check(96, |rng| {
        let n = rng.below(40) + 1;
        let views: Vec<ResourceView> = (0..n)
            .map(|i| ResourceView {
                id: ResourceId(i as u32),
                slots: rng.below(16) as u32 + 1,
                planning_speed: if rng.chance(0.15) {
                    0.0 // down at last MDS refresh
                } else {
                    rng.uniform(0.2, 2.0)
                },
                rate: rng.uniform(0.01, 5.0),
                in_flight: 0,
                measured_jphps: if rng.chance(0.3) {
                    Some(rng.uniform(0.05, 3.0))
                } else {
                    None
                },
                batch_queue: rng.chance(0.5),
            })
            .collect();
        let remaining = rng.below(300) as u32 + 1;
        let registry = PolicyRegistry::with_builtins();
        let index = CandidateIndex::from_views(&views);
        for name in ALL_POLICIES {
            let mut policy = registry.resolve(name).unwrap();
            let mut prng = Rng::new(rng.next_u64());
            let alloc = {
                let mut ctx = SchedCtx {
                    now: rng.uniform(0.0, 10.0 * HOUR),
                    deadline: 15.0 * HOUR,
                    budget_headroom: if rng.chance(0.5) {
                        Some(rng.uniform(100.0, 1e7))
                    } else {
                        None
                    },
                    remaining_jobs: remaining,
                    job_work_ref_h: rng.uniform(0.2, 4.0),
                    resources: &views,
                    candidates: &index,
                    rng: &mut prng,
                };
                policy.allocate(&mut ctx)
            };
            let mut total = 0u32;
            for (rid, target) in &alloc {
                let v = &views[rid.0 as usize];
                prop_assert!(
                    *target <= v.slots,
                    "{name}: target {} > slots {} on {rid}",
                    target,
                    v.slots
                );
                prop_assert!(
                    v.planning_speed > 0.0,
                    "{name}: allocated down resource {rid}"
                );
                total += target;
            }
            prop_assert!(
                total <= remaining.max(1) * 2,
                "{name}: grossly over-allocated {total} for {remaining} jobs"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_multi_tenant_worlds_conserve_slots_and_budgets_under_churn() {
    // The GridWorld invariants, checked mid-flight at every step of a
    // contended run with availability churn, background competition AND
    // demand pricing:
    //  * per resource: Σ tenants' in-flight + competition claims ≤ CPUs;
    //  * per tenant: settled + committed (ledger exposure) ≤ budget.
    prop_check(6, |rng| {
        let seed = rng.next_u64();
        let n_tenants = rng.below(3) + 2; // 2..4
        let policies = ["cost", "time", "deadline-only", "conservative-time"];
        let mut b = Broker::experiment()
            .plan(
                "parameter i integer range from 1 to 30\n\
                 task main\nexecute icc $i\nendtask",
            )
            .deadline_h(12.0)
            .policy(policies[0])
            .budget(2.0e5)
            .seed(seed)
            .testbed_scale(0.4)
            .demand_pricing(0.7)
            .competition(nimrod_g::grid::competition::CompetitionModel {
                mean_interarrival_s: 1500.0,
                mean_duration_s: 2.0 * HOUR,
                mean_cpus: 30.0,
            })
            .tweak_testbed(|tb| {
                for spec in &mut tb.resources {
                    spec.mtbf_s = 4.0 * 3600.0; // flaky: real churn mid-run
                    spec.mttr_s = 0.5 * 3600.0;
                }
            });
        for k in 1..n_tenants {
            b = b.tenant(
                Broker::experiment()
                    .plan(
                        "parameter i integer range from 1 to 30\n\
                         task main\nexecute icc $i\nendtask",
                    )
                    .deadline_h(8.0 + 3.0 * k as f64)
                    .policy(policies[k % policies.len()])
                    .budget(2.0e5)
                    .user(&format!("user{k}")),
            );
        }
        let mut world = b.world().map_err(|e| format!("{e:#}"))?;
        let mut t = 0.0;
        while !world.finished() && t < 60.0 * HOUR {
            t += 20.0 * 60.0; // 20-minute steps
            world.run_until(t);
            prop_assert!(
                world.slot_conservation_ok(),
                "slot conservation violated at t={t}"
            );
            for tid in 0..world.tenant_count() {
                let ledger = world.ledger(tid);
                prop_assert!(
                    ledger.exposure() <= 2.0e5 + 1e-6,
                    "tenant {tid}: exposure {} past budget at t={t}",
                    ledger.exposure()
                );
                prop_assert!(
                    ledger.check_conservation(),
                    "tenant {tid}: per-resource spend rollup diverged"
                );
            }
        }
        // Whatever terminal state the budget allowed, spend never exceeds
        // the envelope and the engine rollups stay consistent.
        for tid in 0..world.tenant_count() {
            prop_assert!(
                world.ledger(tid).settled() <= 2.0e5 + 1e-6,
                "tenant {tid} overspent: {}",
                world.ledger(tid).settled()
            );
            prop_assert!(
                world.exp(tid).counts_consistent(),
                "tenant {tid} engine rollups drifted"
            );
        }
        Ok(())
    });
}

#[test]
fn contested_gusto_conserves_slots_every_tick() {
    // The acceptance experiment: step the contested-gusto preset through
    // its whole run, checking global slot conservation at a fine grain
    // (every tick also re-checks it via debug_assert inside the world).
    let mut world = Broker::scenario("contested-gusto")
        .unwrap()
        .seed(0xC0117)
        .world()
        .unwrap();
    let mut t = 0.0;
    while !world.finished() && t < 40.0 * HOUR {
        t += 10.0 * 60.0; // 10-minute steps
        world.run_until(t);
        assert!(
            world.slot_conservation_ok(),
            "slot conservation violated at t={t}"
        );
    }
    assert!(world.finished(), "contested-gusto should finish inside 40h");
    let wr = world.finalize_world();
    for tenant in &wr.tenants {
        assert_eq!(
            tenant.report.jobs_completed + tenant.report.jobs_failed,
            tenant.report.jobs_total,
            "{}: {}",
            tenant.user,
            tenant.report.summary()
        );
    }
}

#[test]
fn prop_small_simulations_terminate_consistently() {
    prop_check(24, |rng| {
        let seed = rng.next_u64();
        let policy = *rng.choose(&["cost", "time", "round-robin", "perf"]);
        let nv = rng.below(4) + 2;
        let src = format!(
            "parameter voltage float range from 100 to 1000 step {}\nparameter energy float select anyof 5 15\ntask main\nexecute icc -v $voltage -e $energy\nendtask",
            900.0 / (nv - 1) as f64
        );
        let specs = expand(&Plan::parse(&src).unwrap(), seed).unwrap();
        let total = specs.len() as u32;
        let tb = Testbed::gusto(seed, 0.4);
        let cfg = nimrod_g::config::ExperimentConfig {
            policy: policy.to_string(),
            deadline: 30.0 * HOUR,
            seed,
            ..Default::default()
        };
        let r = nimrod_g::sim::GridSimulation::new(tb, specs, cfg).run();
        prop_assert!(
            r.jobs_completed + r.jobs_failed == total,
            "{policy}: jobs unaccounted for: {}",
            r.summary()
        );
        // Spend bookkeeping agrees between ledger and per-resource rollup.
        let rollup: f64 = r.per_resource.values().map(|u| u.cost).sum();
        prop_assert!(
            (rollup - r.total_cost).abs() <= 1e-6 * r.total_cost.max(1.0),
            "{policy}: cost rollup {rollup} != total {}",
            r.total_cost
        );
        // All processors released at the end.
        let final_busy = r.busy_cpus.at(r.makespan_s + 1.0);
        prop_assert!(
            final_busy == 0,
            "{policy}: {final_busy} cpus still busy after completion"
        );
        Ok(())
    });
}
