//! Integration tests for the `broker` public API: the policy registry
//! (registration + parameter round-trips), the experiment builder
//! (defaulting, validation, determinism against the legacy construction
//! path), and one smoke test per scenario preset.

use nimrod_g::broker::{Broker, PolicyRegistry, ScheduleAdvisor, TickCtx};
use nimrod_g::config::ExperimentConfig;
use nimrod_g::metrics::Report;
use nimrod_g::scheduler::{
    Allocation, CandidateIndex, Policy, ResourceView, SchedCtx,
};
use nimrod_g::sim::GridSimulation;
use nimrod_g::types::{JobId, ResourceId, HOUR};
use nimrod_g::util::rng::Rng;

// -- policy registry ---------------------------------------------------------

/// An out-of-crate policy: allocates one slot on every `stride`-th
/// resource. Exists to prove the registry seam is open.
struct EveryNth {
    stride: usize,
}

impl Policy for EveryNth {
    fn name(&self) -> &'static str {
        "every-nth"
    }

    fn allocate(&mut self, ctx: &mut SchedCtx<'_>) -> Allocation {
        let mut alloc = Allocation::new();
        let mut total = 0u32;
        for r in ctx.resources.iter().step_by(self.stride) {
            if total >= ctx.remaining_jobs {
                break;
            }
            if r.planning_speed > 0.0 && r.slots > 0 {
                alloc.insert(r.id, 1);
                total += 1;
            }
        }
        alloc
    }
}

fn registry_with_every_nth() -> PolicyRegistry {
    let mut reg = PolicyRegistry::with_builtins();
    reg.register("every-nth", |params| {
        let stride = params.take_f64("stride")?.unwrap_or(2.0);
        if stride < 1.0 {
            anyhow::bail!("stride must be >= 1, got {stride}");
        }
        Ok(Box::new(EveryNth {
            stride: stride as usize,
        }))
    });
    reg
}

#[test]
fn out_of_crate_policy_registers_and_resolves_with_params() {
    let reg = registry_with_every_nth();
    let p = reg.resolve("every-nth?stride=3").unwrap();
    assert_eq!(p.name(), "every-nth");
    // Unknown keys are rejected even on custom policies.
    assert!(reg.resolve("every-nth?pace=3").is_err());
    assert!(reg.resolve("every-nth?stride=0").is_err());
    // Builtins are still present alongside.
    assert!(reg.resolve("cost?safety=0.9").is_ok());
}

#[test]
fn custom_policy_drives_a_full_experiment() {
    let report = Broker::experiment()
        .registry(registry_with_every_nth())
        .policy("every-nth?stride=2")
        .deadline_h(40.0)
        .seed(11)
        .run()
        .unwrap();
    assert_eq!(report.jobs_total, 165);
    assert_eq!(
        report.jobs_completed + report.jobs_failed,
        report.jobs_total,
        "{}",
        report.summary()
    );
    assert!(report.resources_used > 1);
}

#[test]
fn cost_safety_parameter_changes_planning() {
    // Lower safety shrinks the planning window, so the cost optimizer must
    // hold more capacity for the same deadline.
    let views: Vec<ResourceView> = (0..3)
        .map(|i| ResourceView {
            id: ResourceId(i),
            slots: 8,
            planning_speed: 1.0,
            rate: 1.0 + i as f64,
            in_flight: 0,
            measured_jphps: None,
            batch_queue: false,
        })
        .collect();
    let reg = PolicyRegistry::with_builtins();
    let index = CandidateIndex::from_views(&views);
    let slots_with = |spec: &str| -> u32 {
        let mut policy = reg.resolve(spec).unwrap();
        let mut rng = Rng::new(1);
        let mut ctx = SchedCtx {
            now: 0.0,
            deadline: 8.0 * HOUR,
            budget_headroom: None,
            remaining_jobs: 40,
            job_work_ref_h: 1.0,
            resources: &views,
            candidates: &index,
            rng: &mut rng,
        };
        policy.allocate(&mut ctx).values().sum()
    };
    let default = slots_with("cost");
    let cautious = slots_with("cost?safety=0.4");
    assert!(
        cautious > default,
        "safety=0.4 should hold more slots: {cautious} vs {default}"
    );
    // An explicit safety equal to the default is exactly the default.
    assert_eq!(slots_with("cost?safety=0.85"), default);
}

#[test]
fn registry_is_the_single_policy_construction_path() {
    // The deprecated `scheduler::by_name` shim is gone; every spec the
    // shim used to accept resolves through the registry directly.
    let reg = PolicyRegistry::with_builtins();
    assert!(reg.resolve("cost").is_ok());
    assert!(reg.resolve("cost?safety=0.9").is_ok());
    assert!(reg.resolve("cost?bogus=1").is_err());
    assert!(reg.resolve("nope").is_err());
}

// -- experiment builder ------------------------------------------------------

#[test]
fn builder_defaults_are_the_paper_trial() {
    let b = Broker::experiment();
    let d = ExperimentConfig::default();
    assert_eq!(b.config().policy, d.policy);
    assert_eq!(b.config().deadline, d.deadline);
    assert_eq!(b.config().seed, d.seed);
    assert_eq!(b.config().user, d.user);
    assert_eq!(b.config().budget, None);
    assert!(b.config().competition.is_none());
}

#[test]
fn builder_validates_before_running() {
    assert!(Broker::experiment().deadline_h(0.0).simulate().is_err());
    assert!(Broker::experiment().deadline_h(f64::NAN).simulate().is_err());
    assert!(Broker::experiment().budget(-5.0).simulate().is_err());
    assert!(Broker::experiment().policy("typo").simulate().is_err());
    assert!(Broker::experiment()
        .policy("cost?safety=nope")
        .simulate()
        .is_err());
    assert!(Broker::experiment().plan("not a plan").simulate().is_err());
    let err = Broker::experiment()
        .policy("unknown-policy")
        .simulate()
        .map(|_| ())
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("unknown policy"),
        "error should name the problem: {err:#}"
    );
}

#[test]
fn builder_gusto_scenario_matches_legacy_path_exactly() {
    let seed = 0x5EED;
    let via_builder: Report = Broker::scenario("gusto")
        .unwrap()
        .seed(seed)
        .run()
        .unwrap();
    let legacy: Report = GridSimulation::gusto_ionization(ExperimentConfig {
        deadline: 15.0 * HOUR,
        policy: "cost".to_string(),
        seed,
        ..Default::default()
    })
    .run();
    // Bit-exact replay: same events, same floating-point trajectories,
    // same timeline, same rendered summary.
    assert_eq!(via_builder.events, legacy.events);
    assert_eq!(via_builder.ticks, legacy.ticks);
    assert_eq!(via_builder.makespan_s.to_bits(), legacy.makespan_s.to_bits());
    assert_eq!(via_builder.total_cost.to_bits(), legacy.total_cost.to_bits());
    assert_eq!(via_builder.busy_cpus.points(), legacy.busy_cpus.points());
    assert_eq!(via_builder.summary(), legacy.summary());
}

#[test]
fn advisor_matches_inlined_pipeline_actions() {
    // The facade is a refactor, not a behavior change: one tick through
    // ScheduleAdvisor equals policy.allocate + plan_actions by hand.
    let src = "parameter i integer range from 1 to 30\ntask main\nexecute r $i\nendtask";
    let specs =
        nimrod_g::plan::expand(&nimrod_g::plan::Plan::parse(src).unwrap(), 0)
            .unwrap();
    let mut exp =
        nimrod_g::engine::Experiment::new(specs, 10.0 * HOUR, None, "u", 3);
    exp.dispatch(JobId(0), ResourceId(0), 0.0).unwrap();
    let views: Vec<ResourceView> = (0..8)
        .map(|i| ResourceView {
            id: ResourceId(i),
            slots: 2 + i % 3,
            planning_speed: 0.5 + 0.2 * i as f64,
            rate: 0.3 * (1 + i) as f64,
            in_flight: u32::from(i == 0),
            measured_jphps: None,
            batch_queue: false,
        })
        .collect();
    let index = CandidateIndex::from_views(&views);
    let inlined = {
        let mut policy = PolicyRegistry::with_builtins().resolve("cost").unwrap();
        let mut rng = Rng::new(9);
        let alloc = {
            let mut ctx = SchedCtx {
                now: 0.0,
                deadline: 10.0 * HOUR,
                budget_headroom: None,
                remaining_jobs: exp.remaining(),
                job_work_ref_h: 2.0,
                resources: &views,
                candidates: &index,
                rng: &mut rng,
            };
            policy.allocate(&mut ctx)
        };
        nimrod_g::dispatcher::plan_actions(&alloc, &exp)
    };
    let via_advisor = {
        let mut advisor = ScheduleAdvisor::resolve("cost", 2.0).unwrap();
        let mut rng = Rng::new(9);
        advisor.advise(
            TickCtx {
                now: 0.0,
                deadline: 10.0 * HOUR,
                budget_headroom: None,
                views: &views,
                candidates: &index,
            },
            &exp,
            &mut rng,
        )
    };
    assert_eq!(inlined, via_advisor);
}

// -- scenario presets --------------------------------------------------------

fn smoke(name: &str) -> Report {
    let report = Broker::scenario(name)
        .unwrap()
        .seed(0xCAFE)
        .run()
        .unwrap_or_else(|e| panic!("{name}: {e:#}"));
    assert_eq!(report.jobs_total, 165, "{name}");
    assert!(
        report.jobs_completed + report.jobs_failed <= report.jobs_total,
        "{name}: {}",
        report.summary()
    );
    assert!(report.events > 0 && report.ticks > 0, "{name}");
    report
}

/// Scenarios without a binding budget must account for every job.
fn assert_all_terminal(name: &str, r: &Report) {
    assert_eq!(
        r.jobs_completed + r.jobs_failed,
        r.jobs_total,
        "{name}: {}",
        r.summary()
    );
}

#[test]
fn scenario_catalog_is_complete_and_runnable() {
    let names = nimrod_g::broker::scenarios::names();
    assert!(names.len() >= 4, "at least four presets required");
    for name in names {
        assert!(Broker::scenario(name).is_ok());
    }
    assert!(Broker::scenario("no-such-scenario").is_err());
}

#[test]
fn smoke_gusto() {
    let r = smoke("gusto");
    assert_all_terminal("gusto", &r);
    assert!(r.jobs_completed >= 160, "{}", r.summary());
}

#[test]
fn smoke_peak_offpeak() {
    let r = smoke("peak-offpeak");
    assert_all_terminal("peak-offpeak", &r);
}

#[test]
fn smoke_flash_crowd() {
    let r = smoke("flash-crowd");
    assert_all_terminal("flash-crowd", &r);
}

#[test]
fn smoke_cheap_but_flaky() {
    let r = smoke("cheap-but-flaky");
    assert_all_terminal("cheap-but-flaky", &r);
    let failures: u32 = r.per_resource.values().map(|u| u.jobs_failed).sum();
    assert!(failures > 0, "the flaky grid should produce some failures");
    assert!(
        r.jobs_completed >= 150,
        "retries should carry most jobs through churn: {}",
        r.summary()
    );
}

#[test]
fn smoke_tight_budget() {
    // A binding budget may leave jobs unscheduled — the hard invariant is
    // that spend never exceeds the envelope.
    let r = smoke("tight-budget");
    assert!(
        r.total_cost <= 5.0e5 + 1e-6,
        "budget invariant violated: {}",
        r.total_cost
    );
}

#[test]
fn smoke_global_scale() {
    let r = smoke("global-scale");
    assert_all_terminal("global-scale", &r);
    assert!(r.resources_used >= 5, "{}", r.summary());
}

#[test]
fn mega_grid_preset_reaches_contract_scale() {
    // The scale-stress preset promises ≥5,000 resources and ≥50,000 jobs.
    // Running it to completion belongs in `cargo bench` (grid_scaling);
    // here we build it and drive the first scheduler tick to prove the
    // pipeline fans out at that scale.
    let mut sim = Broker::scenario("mega-grid")
        .unwrap()
        .seed(1)
        .simulate()
        .unwrap();
    assert!(
        sim.tb().resources.len() >= 5000,
        "{} machines",
        sim.tb().resources.len()
    );
    assert!(
        sim.exp().jobs.len() >= 50_000,
        "{} jobs",
        sim.exp().jobs.len()
    );
    sim.run_until(1.0); // the t = 0 tick
    let in_flight: u32 = sim.exp().in_flight_counts().iter().sum();
    assert!(
        in_flight > 1000,
        "first tick should fan dispatches across the grid, got {in_flight}"
    );
}

#[test]
fn index_storm_preset_reaches_contract_scale() {
    // The candidate-index stress preset promises a 10,000-machine grid
    // shared by 4 tenants under churn + demand repricing. Running it to
    // completion belongs in release mode (`nimrod run --scenario
    // index-storm`, the CI smoke matrix); here we build it and drive the
    // t = 0 ticks to prove every tenant's index-backed allocation fans out
    // at that scale.
    let mut world = Broker::scenario("index-storm")
        .unwrap()
        .seed(1)
        .world()
        .unwrap();
    assert!(
        world.tb.resources.len() >= 10_000,
        "{} machines",
        world.tb.resources.len()
    );
    assert_eq!(world.tenant_count(), 4);
    world.run_until(1.0); // the t = 0 tick of each tenant
    for tid in 0..world.tenant_count() {
        let in_flight: u32 = world.exp(tid).in_flight_counts().iter().sum();
        assert!(
            in_flight > 0,
            "tenant {tid} should dispatch on the first tick"
        );
    }
    assert!(world.slot_conservation_ok());
}

#[test]
fn smoke_contested_gusto() {
    // Three tenants (cost / time / deadline-only), one shared GUSTO grid:
    // every tenant accounts for every job, and realized costs/makespans
    // diverge by policy — the contention is real.
    let wr = Broker::scenario("contested-gusto")
        .unwrap()
        .seed(0xCAFE)
        .run_world()
        .unwrap();
    assert_eq!(wr.tenants.len(), 3);
    for t in &wr.tenants {
        assert_eq!(t.report.jobs_total, 165, "{}", t.user);
        assert_eq!(
            t.report.jobs_completed + t.report.jobs_failed,
            t.report.jobs_total,
            "{} ({}): {}",
            t.user,
            t.policy,
            t.report.summary()
        );
        assert!(t.report.jobs_completed >= 150, "{}", t.report.summary());
    }
    let cost = &wr.tenants[0].report;
    let time = &wr.tenants[1].report;
    assert!(
        (cost.total_cost - time.total_cost).abs() > 1.0,
        "cost-opt and time-opt tenants must realize different spends: {} vs {}",
        cost.total_cost,
        time.total_cost
    );
    assert!(
        (cost.makespan_s - time.makespan_s).abs() > 60.0,
        "policies must realize different makespans: {} vs {}",
        cost.makespan_s,
        time.makespan_s
    );
    let fairness = wr.fairness_jain();
    assert!(
        fairness > 0.3 && fairness <= 1.0 + 1e-9,
        "fairness out of range: {fairness}"
    );
}

#[test]
fn smoke_auction_rush() {
    // Eight staggered-deadline tenants on a demand-priced grid: the rush
    // must move prices (peak premium > 1) and every tenant must finish.
    let wr = Broker::scenario("auction-rush")
        .unwrap()
        .seed(0xCAFE)
        .run_world()
        .unwrap();
    assert_eq!(wr.tenants.len(), 8);
    for t in &wr.tenants {
        assert_eq!(t.report.jobs_total, 48, "{}", t.user);
        assert_eq!(
            t.report.jobs_completed + t.report.jobs_failed,
            t.report.jobs_total,
            "{} ({}): {}",
            t.user,
            t.policy,
            t.report.summary()
        );
    }
    assert!(
        wr.peak_premium > 1.0,
        "demand pricing must reprice busy machines: peak {}",
        wr.peak_premium
    );
    assert!(!wr.price_index.is_empty(), "price trajectory must be sampled");
    // Deadlines are staggered 6..20 h in tenant order.
    let d0 = wr.tenants[0].report.deadline_s;
    let d7 = wr.tenants[7].report.deadline_s;
    assert!(d0 < d7, "staggered deadlines: {d0} vs {d7}");
}

#[test]
fn smoke_grace_auction() {
    // Three tenants trading through the GRACE market: every tenant
    // accounts for every job, agreements are struck and visible in the
    // world report, and the clearing-price trajectory is sampled.
    let wr = Broker::scenario("grace-auction")
        .unwrap()
        .seed(0xCAFE)
        .run_world()
        .unwrap();
    assert_eq!(wr.tenants.len(), 3);
    for t in &wr.tenants {
        assert_eq!(t.report.jobs_total, 165, "{}", t.user);
        assert_eq!(
            t.report.jobs_completed + t.report.jobs_failed,
            t.report.jobs_total,
            "{} ({}): {}",
            t.user,
            t.policy,
            t.report.summary()
        );
    }
    assert!(wr.has_market_data(), "grace world must trade");
    assert!(
        wr.agreements_won() > 0,
        "auctions must strike agreements: {}",
        wr.summary()
    );
    assert!(
        !wr.clearing_prices.is_empty(),
        "clearing prices must be sampled"
    );
    // One round can award many agreements, so the ratio may sit below 1;
    // it just has to be a real positive figure.
    assert!(wr.rounds_per_agreement() > 0.0);
    let shares = wr.award_share();
    assert_eq!(shares.len(), 3);
    assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    assert!(wr.summary().contains("grace:"), "{}", wr.summary());
}

#[test]
fn smoke_grace_rush() {
    // The 8-tenant staggered crowd bidding instead of taking posted
    // prices: the multi-tenant stress case for the market layer.
    let wr = Broker::scenario("grace-rush")
        .unwrap()
        .seed(0xCAFE)
        .run_world()
        .unwrap();
    assert_eq!(wr.tenants.len(), 8);
    for t in &wr.tenants {
        assert_eq!(t.report.jobs_total, 48, "{}", t.user);
        assert_eq!(
            t.report.jobs_completed + t.report.jobs_failed,
            t.report.jobs_total,
            "{} ({}): {}",
            t.user,
            t.policy,
            t.report.summary()
        );
    }
    assert!(wr.agreements_won() > 0, "{}", wr.summary());
}

#[test]
fn grace_scenarios_are_deterministic_and_seedable() {
    let run = |seed: u64| {
        Broker::scenario("grace-auction")
            .unwrap()
            .seed(seed)
            .run_world()
            .unwrap()
    };
    let a = run(6);
    let b = run(6);
    assert_eq!(a.events, b.events);
    assert_eq!(a.agreements_won(), b.agreements_won());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.agreements_won, y.agreements_won);
        assert_eq!(x.negotiation_rounds, y.negotiation_rounds);
        assert_eq!(
            x.report.total_cost.to_bits(),
            y.report.total_cost.to_bits()
        );
        assert_eq!(
            x.report.makespan_s.to_bits(),
            y.report.makespan_s.to_bits()
        );
    }
    let c = run(7);
    assert!(
        a.events != c.events
            || a.tenants[0].report.total_cost.to_bits()
                != c.tenants[0].report.total_cost.to_bits(),
        "different seeds should produce different trajectories"
    );
}

#[test]
fn multi_tenant_scenarios_are_deterministic_and_seedable() {
    let run = |seed: u64| {
        Broker::scenario("contested-gusto")
            .unwrap()
            .seed(seed)
            .run_world()
            .unwrap()
    };
    let a = run(3);
    let b = run(3);
    assert_eq!(a.events, b.events);
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(
            x.report.total_cost.to_bits(),
            y.report.total_cost.to_bits()
        );
        assert_eq!(
            x.report.makespan_s.to_bits(),
            y.report.makespan_s.to_bits()
        );
    }
    let c = run(4);
    assert!(
        a.events != c.events
            || a.tenants[0].report.total_cost.to_bits()
                != c.tenants[0].report.total_cost.to_bits(),
        "different seeds should produce different trajectories"
    );
}

#[test]
fn scenarios_are_deterministic_and_seedable() {
    let a = Broker::scenario("flash-crowd").unwrap().seed(3).run().unwrap();
    let b = Broker::scenario("flash-crowd").unwrap().seed(3).run().unwrap();
    assert_eq!(a.events, b.events);
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    let c = Broker::scenario("flash-crowd").unwrap().seed(4).run().unwrap();
    assert!(
        a.events != c.events || a.total_cost.to_bits() != c.total_cost.to_bits(),
        "different seeds should produce different trajectories"
    );
}
