//! Index-backed allocation must be a pure optimization: for every builtin
//! policy, across seeds, availability churn, demand repricing and
//! GRACE-auction worlds, the incremental candidate index must replay the
//! sort-every-tick baseline (`set_full_allocation_sort`) bit-exactly —
//! same events, same floating-point trajectories, same spend. Any missed
//! or stale re-key diverges the traces and fails here.

use nimrod_g::broker::Broker;
use nimrod_g::economy::market::GraceConfig;
use nimrod_g::grid::competition::CompetitionModel;
use nimrod_g::metrics::WorldReport;
use nimrod_g::scheduler::ALL_POLICIES;
use nimrod_g::sim::GridWorld;

/// Assert two world runs replayed the identical trace, bit for bit.
fn assert_same_trace(a: &WorldReport, b: &WorldReport, tag: &str) {
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{tag}");
    assert_eq!(
        a.agreements_won(),
        b.agreements_won(),
        "{tag}: market outcomes diverged"
    );
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let who = format!("{tag}/{} ({})", x.user, x.policy);
        assert_eq!(x.report.ticks, y.report.ticks, "{who}: ticks");
        assert_eq!(
            x.report.jobs_completed, y.report.jobs_completed,
            "{who}: completions"
        );
        assert_eq!(
            x.report.makespan_s.to_bits(),
            y.report.makespan_s.to_bits(),
            "{who}: makespan"
        );
        assert_eq!(
            x.report.total_cost.to_bits(),
            y.report.total_cost.to_bits(),
            "{who}: spend"
        );
        assert_eq!(
            x.report.busy_cpus.points(),
            y.report.busy_cpus.points(),
            "{who}: busy-cpu timeline"
        );
    }
}

/// Run `build()` twice — incremental index versus forced full re-rank —
/// and demand identical traces.
fn check_pair(build: impl Fn() -> GridWorld, tag: &str) {
    let incremental = build().run_world();
    let mut forced = build();
    forced.set_full_allocation_sort(true);
    let full_sort = forced.run_world();
    assert_same_trace(&incremental, &full_sort, tag);
}

const SMALL_PLAN: &str = "parameter i integer range from 1 to 30\n\
                          task main\nexecute icc $i\nendtask";

#[test]
fn allocation_matches_full_sort_bit_exactly_for_all_policies() {
    // Every builtin policy, two seeds, on the churny GUSTO grid (default
    // MTBFs — machines fail and recover mid-run, exercising index
    // eviction/re-insertion) with a budget so the cost optimizer's shed
    // path runs too.
    for policy in ALL_POLICIES {
        for seed in [3u64, 11] {
            check_pair(
                || {
                    Broker::experiment()
                        .plan(SMALL_PLAN)
                        .deadline_h(24.0)
                        .policy(policy)
                        .budget(5.0e5)
                        .seed(seed)
                        .testbed_scale(0.4)
                        .world()
                        .unwrap()
                },
                &format!("{policy}/seed{seed}"),
            );
        }
    }
}

#[test]
fn allocation_matches_full_sort_under_churn_and_demand_repricing() {
    // The dirty-view firehose: fast availability churn, demand-responsive
    // owners (every occupancy move repricing quotes) and background
    // competition claims, multi-tenant so cross-tenant dirtying is in
    // play. The worst case for a stale index.
    check_pair(
        || {
            Broker::experiment()
                .plan(SMALL_PLAN)
                .deadline_h(20.0)
                .policy("cost")
                .seed(9)
                .testbed_scale(0.4)
                .demand_pricing(0.8)
                .competition(CompetitionModel {
                    mean_interarrival_s: 1200.0,
                    mean_duration_s: 2.0 * 3600.0,
                    mean_cpus: 20.0,
                })
                .tweak_testbed(|tb| {
                    for spec in &mut tb.resources {
                        spec.mtbf_s = 2.0 * 3600.0;
                        spec.mttr_s = 0.4 * 3600.0;
                    }
                })
                .tenant(
                    Broker::experiment()
                        .plan(SMALL_PLAN)
                        .deadline_h(12.0)
                        .policy("time")
                        .user("davida"),
                )
                .tenant(
                    Broker::experiment()
                        .plan(SMALL_PLAN)
                        .deadline_h(16.0)
                        .policy("conservative-time")
                        .user("astro"),
                )
                .world()
                .unwrap()
        },
        "churn+demand",
    );
}

#[test]
fn allocation_matches_full_sort_in_grace_auction_worlds() {
    // Award/expiry repricing dirties views between directory refreshes;
    // the index must follow. Short TTLs force mid-sweep expiries.
    for ttl in [GraceConfig::default().agreement_ttl_s, 90.0] {
        check_pair(
            || {
                Broker::experiment()
                    .plan(SMALL_PLAN)
                    .deadline_h(18.0)
                    .policy("cost")
                    .budget(2.0e6)
                    .seed(7)
                    .testbed_scale(0.4)
                    .demand_pricing(0.5)
                    .grace_market(GraceConfig {
                        agreement_ttl_s: ttl,
                        ..GraceConfig::default()
                    })
                    .tenant(
                        Broker::experiment()
                            .plan(SMALL_PLAN)
                            .deadline_h(10.0)
                            .policy("time")
                            .user("davida"),
                    )
                    .world()
                    .unwrap()
            },
            &format!("grace/ttl{ttl}"),
        );
    }
}

#[test]
fn full_view_rebuild_and_full_allocation_sort_compose() {
    // Both bench baselines at once — the fully pre-incremental pipeline —
    // must still replay the incremental trace bit-exactly, and must touch
    // strictly more view entries.
    let build = || {
        Broker::experiment()
            .plan(SMALL_PLAN)
            .deadline_h(20.0)
            .policy("cost")
            .seed(5)
            .testbed_scale(0.4)
            .world()
            .unwrap()
    };
    let incremental = build().run_world();
    let mut forced = build();
    forced.set_full_view_rebuild(true);
    forced.set_full_allocation_sort(true);
    let baseline = forced.run_world();
    assert_same_trace(&incremental, &baseline, "composed-baselines");
    let touched = |wr: &WorldReport| -> u64 {
        wr.tenants.iter().map(|t| t.report.view_refreshes).sum()
    };
    assert!(
        touched(&incremental) < touched(&baseline),
        "incremental must touch fewer entries: {} vs {}",
        touched(&incremental),
        touched(&baseline)
    );
}
