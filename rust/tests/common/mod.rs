//! Helpers shared by the equivalence-style integration suites
//! (`determinism.rs`, `parallel_equivalence.rs`): a contested multi-tenant
//! world builder with enough shared mutable state to surface ordering bugs,
//! and the bit-exact [`WorldReport`] comparator.
//!
//! Each integration-test binary compiles this module independently, so not
//! every binary uses every helper.
#![allow(dead_code)]

use nimrod_g::broker::{Broker, ExperimentBuilder};
use nimrod_g::metrics::WorldReport;
use nimrod_g::sim::GridWorld;

pub const PLAN: &str = "parameter i integer range from 1 to 40\n\
                        task main\nexecute icc $i\nendtask";

/// Builder for a contested three-tenant world with demand repricing —
/// enough shared mutable state (cross-tenant occupancy, premium repricing,
/// churny views) that any nondeterministic iteration order would shuffle
/// the trace. Returned as a builder so callers can layer extra knobs
/// (thread count, markets) before finishing with `world()`.
pub fn contested_builder(seed: u64) -> ExperimentBuilder {
    Broker::experiment()
        .plan(PLAN)
        .deadline_h(18.0)
        .policy("cost")
        .user("rajkumar")
        .seed(seed)
        .testbed_scale(0.5)
        .demand_pricing(0.7)
        .tenant(
            Broker::experiment()
                .plan(PLAN)
                .deadline_h(10.0)
                .policy("time")
                .user("davida"),
        )
        .tenant(
            Broker::experiment()
                .plan(PLAN)
                .deadline_h(14.0)
                .policy("deadline-only")
                .user("stranger"),
        )
}

/// The contested world finished with the default (sequential) driver.
pub fn contested_world(seed: u64) -> GridWorld {
    contested_builder(seed).world().expect("world builds")
}

/// Two runs must match bit-for-bit: u64 counters exactly, f64s via
/// `to_bits` (so `-0.0` vs `0.0` or a NaN sneaking in still fails).
/// Wall-clock telemetry (`alloc_ns`, the tick-phase timers) is
/// deliberately not compared.
pub fn assert_identical(a: &WorldReport, b: &WorldReport, tag: &str) {
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{tag}");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let who = format!("{tag}/{} ({})", x.user, x.policy);
        assert_eq!(x.report.ticks, y.report.ticks, "{who}: ticks");
        assert_eq!(
            x.report.jobs_completed, y.report.jobs_completed,
            "{who}: completions"
        );
        assert_eq!(
            x.report.jobs_failed, y.report.jobs_failed,
            "{who}: failures"
        );
        assert_eq!(
            x.report.makespan_s.to_bits(),
            y.report.makespan_s.to_bits(),
            "{who}: makespan"
        );
        assert_eq!(
            x.report.total_cost.to_bits(),
            y.report.total_cost.to_bits(),
            "{who}: spend"
        );
        assert_eq!(
            x.report.busy_cpus.points(),
            y.report.busy_cpus.points(),
            "{who}: busy-cpu timeline"
        );
    }
    assert_eq!(
        a.price_index.len(),
        b.price_index.len(),
        "{tag}: price samples"
    );
    for (i, ((ta, pa), (tb, pb))) in
        a.price_index.iter().zip(&b.price_index).enumerate()
    {
        assert_eq!(ta.to_bits(), tb.to_bits(), "{tag}: price sample {i} time");
        assert_eq!(pa.to_bits(), pb.to_bits(), "{tag}: price sample {i} value");
    }
    assert_eq!(
        a.peak_premium.to_bits(),
        b.peak_premium.to_bits(),
        "{tag}: peak premium"
    );
    assert_eq!(
        a.clearing_prices.len(),
        b.clearing_prices.len(),
        "{tag}: clearing samples"
    );
    for (i, ((ta, pa), (tb, pb))) in
        a.clearing_prices.iter().zip(&b.clearing_prices).enumerate()
    {
        assert_eq!(ta.to_bits(), tb.to_bits(), "{tag}: clearing {i} time");
        assert_eq!(pa.to_bits(), pb.to_bits(), "{tag}: clearing {i} value");
    }
}
