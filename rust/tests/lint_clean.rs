//! Tier-1 gate: the determinism & dirty-discipline lint must be clean over
//! `rust/src`, and the lint itself must behave — each rule has a firing, a
//! clean and an allowed-with-reason fixture, scoping keeps the rules out of
//! non-tick modules, and a `lint:allow` without a reason is itself a
//! violation. Running in-process from the root crate's test suite means a
//! plain `cargo test` fails on violations, with no extra CI plumbing.

use std::path::Path;

use nimrod_lint::{fixtures, lint_source, lint_tree, Rule};

fn rules_fired(path: &str, src: &str) -> Vec<Rule> {
    lint_source(path, src).into_iter().map(|d| d.rule).collect()
}

fn fires(path: &str, src: &str, rule: Rule) -> bool {
    rules_fired(path, src).contains(&rule)
}

// -- the tree itself ---------------------------------------------------------

#[test]
fn source_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let (diags, files) = lint_tree(&root).expect("rust/src is readable");
    assert!(files > 20, "suspiciously few files scanned: {files}");
    assert!(
        diags.is_empty(),
        "nimrod-lint found {} violation(s):\n{}",
        diags.len(),
        diags
            .iter()
            .map(|d| format!("  {d}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn every_allow_in_the_tree_carries_a_reason() {
    // ALLOW-REASON diagnostics are unsuppressible, so a clean tree already
    // implies this — asserted separately so a reasonless allow is reported
    // as the hygiene failure it is, not just "some violation".
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let (diags, _) = lint_tree(&root).expect("rust/src is readable");
    let hygiene: Vec<_> = diags
        .iter()
        .filter(|d| d.rule == Rule::AllowHygiene)
        .collect();
    assert!(hygiene.is_empty(), "reasonless/unknown allows: {hygiene:?}");
}

// -- ND-HASH -----------------------------------------------------------------

#[test]
fn nd_hash_fires_in_tick_path_modules() {
    assert!(fires("sim/state.rs", fixtures::ND_HASH_FIRING, Rule::NdHash));
    assert!(fires("scheduler/cache.rs", fixtures::ND_HASH_FIRING, Rule::NdHash));
    assert!(fires("types.rs", fixtures::ND_HASH_FIRING, Rule::NdHash));
}

#[test]
fn nd_hash_clean_and_scoped() {
    assert!(!fires("sim/state.rs", fixtures::ND_HASH_CLEAN, Rule::NdHash));
    // Outside the tick path the same source is fine — ND-HASH is a
    // tick-path rule, not a blanket container ban.
    assert!(!fires("plan/occupancy.rs", fixtures::ND_HASH_FIRING, Rule::NdHash));
}

#[test]
fn nd_hash_allowed_with_reason() {
    assert!(!fires("sim/state.rs", fixtures::ND_HASH_ALLOWED, Rule::NdHash));
}

// -- ND-CLOCK ----------------------------------------------------------------

#[test]
fn nd_clock_fires_on_wall_clock_reads() {
    assert!(fires("sim/driver.rs", fixtures::ND_CLOCK_FIRING, Rule::NdClock));
    assert!(fires("engine/mod.rs", fixtures::ND_CLOCK_FIRING, Rule::NdClock));
}

#[test]
fn nd_clock_clean_and_scoped() {
    assert!(!fires("sim/driver.rs", fixtures::ND_CLOCK_CLEAN, Rule::NdClock));
    // util is not a sim path: the bench harness may read real clocks.
    assert!(!fires("util/bench.rs", fixtures::ND_CLOCK_FIRING, Rule::NdClock));
}

#[test]
fn nd_clock_allowed_with_reason() {
    assert!(!fires("sim/driver.rs", fixtures::ND_CLOCK_ALLOWED, Rule::NdClock));
}

// -- ND-FLOAT ----------------------------------------------------------------

#[test]
fn nd_float_fires_on_raw_partial_cmp() {
    assert!(fires("scheduler/policy.rs", fixtures::ND_FLOAT_FIRING, Rule::NdFloat));
    // ND-FLOAT is not scoped to tick paths: a partial comparator is a
    // latent NaN bug anywhere.
    assert!(fires("plan/mod.rs", fixtures::ND_FLOAT_FIRING, Rule::NdFloat));
}

#[test]
fn nd_float_clean_and_exempt_in_index() {
    assert!(!fires("scheduler/policy.rs", fixtures::ND_FLOAT_CLEAN, Rule::NdFloat));
    // scheduler::index owns TotalF64 — its own PartialOrd impl delegates
    // to total_cmp and is exempt.
    assert!(!fires("scheduler/index.rs", fixtures::ND_FLOAT_FIRING, Rule::NdFloat));
}

#[test]
fn nd_float_allowed_with_reason() {
    assert!(!fires("scheduler/policy.rs", fixtures::ND_FLOAT_ALLOWED, Rule::NdFloat));
}

// -- DIRTY-PAIR --------------------------------------------------------------

#[test]
fn dirty_pair_fires_on_unpaired_marks() {
    let diags = lint_source("sim/world.rs", fixtures::DIRTY_PAIR_FIRING);
    let hit = diags
        .iter()
        .find(|d| d.rule == Rule::DirtyPair)
        .expect("unpaired mark_view must fire");
    assert!(hit.message.contains("poke"), "names the fn: {}", hit.message);
}

#[test]
fn dirty_pair_clean_when_paired_and_scoped() {
    assert!(!fires("sim/world.rs", fixtures::DIRTY_PAIR_CLEAN, Rule::DirtyPair));
    // The rule is scoped to sim/world.rs — other files have no dirty queue.
    assert!(!fires("sim/live.rs", fixtures::DIRTY_PAIR_FIRING, Rule::DirtyPair));
}

#[test]
fn dirty_pair_allowed_with_reason_naming_the_rekey() {
    assert!(!fires("sim/world.rs", fixtures::DIRTY_PAIR_ALLOWED, Rule::DirtyPair));
}

// -- PANIC-BUDGET ------------------------------------------------------------

#[test]
fn panic_budget_fires_on_unwrap_in_library_code() {
    assert!(fires("util/head.rs", fixtures::PANIC_BUDGET_FIRING, Rule::PanicBudget));
    assert!(fires("sim/world.rs", fixtures::PANIC_BUDGET_FIRING, Rule::PanicBudget));
}

#[test]
fn panic_budget_skips_cfg_test_modules() {
    assert!(!fires("util/head.rs", fixtures::PANIC_BUDGET_CLEAN, Rule::PanicBudget));
}

#[test]
fn panic_budget_allowed_with_reason() {
    assert!(!fires("util/port.rs", fixtures::PANIC_BUDGET_ALLOWED, Rule::PanicBudget));
}

// -- PAR-SHARED --------------------------------------------------------------

#[test]
fn par_shared_fires_on_shared_state_in_par_section() {
    let diags = lint_source("sim/shard.rs", fixtures::PAR_SHARED_FIRING);
    let hits: Vec<_> = diags
        .iter()
        .filter(|d| d.rule == Rule::ParShared)
        .collect();
    assert!(
        hits.iter().any(|d| d.message.contains("mark_view_all")),
        "cross-tenant dirty broadcast must fire: {hits:?}"
    );
    assert!(
        hits.iter().any(|d| d.message.contains("self.rng")),
        "world-RNG access must fire: {hits:?}"
    );
    // Marker-driven, not path-scoped: the same source fires anywhere.
    assert!(fires("broker/mod.rs", fixtures::PAR_SHARED_FIRING, Rule::ParShared));
}

#[test]
fn par_shared_clean_snapshot_reads_and_unmarked_fns() {
    // Snapshot reads (`wv.*`), tenant-local marks and the pre-forked
    // shard RNG are all fine; the unmarked merge-barrier fn may touch
    // shared state freely.
    assert!(!fires("sim/shard.rs", fixtures::PAR_SHARED_CLEAN, Rule::ParShared));
}

#[test]
fn par_shared_allowed_with_reason() {
    assert!(!fires("sim/shard.rs", fixtures::PAR_SHARED_ALLOWED, Rule::ParShared));
}

#[test]
fn par_shared_fires_inside_pool_scatter_closures() {
    // A WorkerPool `scatter` call ships its closure to the parallel
    // lanes, so the call line and any multi-line closure body are held
    // to par-section discipline with no marker required.
    let diags = lint_source("sim/shard.rs", fixtures::PAR_SHARED_POOL_FIRING);
    let hits: Vec<_> = diags
        .iter()
        .filter(|d| d.rule == Rule::ParShared)
        .collect();
    assert!(
        hits.iter().any(|d| d.message.contains("self.rng")),
        "single-line closure RNG draw must fire: {hits:?}"
    );
    assert!(
        hits.iter().any(|d| d.message.contains("self.total_in_flight")),
        "multi-line closure occupancy read must fire: {hits:?}"
    );
    // Call-driven, not path-scoped.
    assert!(fires("sim/world.rs", fixtures::PAR_SHARED_POOL_FIRING, Rule::ParShared));
}

#[test]
fn par_shared_pool_discipline_ends_with_the_call() {
    // A clean scatter raises nothing, and the merge-barrier code right
    // after the call may touch shared state freely.
    assert!(!fires("sim/shard.rs", fixtures::PAR_SHARED_POOL_CLEAN, Rule::ParShared));
}

#[test]
fn par_shared_pool_allowed_with_reason() {
    assert!(!fires("sim/shard.rs", fixtures::PAR_SHARED_POOL_ALLOWED, Rule::ParShared));
}

#[test]
fn par_shared_fires_inside_streaming_commit_callbacks() {
    // `scatter_streaming` runs its commit callback while later shards are
    // still in flight, so the whole call statement — commit closure
    // included — is parallel-section code with no marker required.
    let diags = lint_source("sim/shard.rs", fixtures::PAR_SHARED_STREAM_FIRING);
    let hits: Vec<_> = diags
        .iter()
        .filter(|d| d.rule == Rule::ParShared)
        .collect();
    assert!(
        hits.iter().any(|d| d.message.contains("self.total_in_flight")),
        "commit-callback occupancy write must fire: {hits:?}"
    );
    assert!(
        hits.iter().any(|d| d.message.contains("self.rng")),
        "commit-callback world-RNG draw must fire: {hits:?}"
    );
    // Call-driven, not path-scoped.
    assert!(fires("sim/world.rs", fixtures::PAR_SHARED_STREAM_FIRING, Rule::ParShared));
}

#[test]
fn par_shared_streaming_discipline_ends_with_the_call() {
    // Commits routed through a MergeCtx are clean, and the post-batch
    // replay right after the call may touch shared state freely.
    assert!(!fires("sim/shard.rs", fixtures::PAR_SHARED_STREAM_CLEAN, Rule::ParShared));
}

#[test]
fn par_shared_streaming_allowed_with_reason() {
    assert!(!fires("sim/shard.rs", fixtures::PAR_SHARED_STREAM_ALLOWED, Rule::ParShared));
}

// -- ALLOW-REASON (escape-hatch hygiene) -------------------------------------

#[test]
fn allow_without_reason_is_a_violation_and_does_not_suppress() {
    let rules = rules_fired("sim/clock.rs", fixtures::ALLOW_NO_REASON);
    assert!(
        rules.contains(&Rule::AllowHygiene),
        "bare lint:allow must be flagged: {rules:?}"
    );
    assert!(
        rules.contains(&Rule::NdClock),
        "an invalid allow must not silence the underlying rule: {rules:?}"
    );
}

#[test]
fn allow_naming_unknown_rule_is_a_violation() {
    assert!(fires("sim/x.rs", fixtures::ALLOW_UNKNOWN_RULE, Rule::AllowHygiene));
}

#[test]
fn rule_ids_are_stable() {
    let ids: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
    assert_eq!(
        ids,
        vec![
            "ND-HASH",
            "ND-CLOCK",
            "ND-FLOAT",
            "DIRTY-PAIR",
            "PANIC-BUDGET",
            "PAR-SHARED",
            "ALLOW-REASON"
        ]
    );
}
