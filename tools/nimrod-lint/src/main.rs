//! CLI for `nimrod-lint`.
//!
//! Usage: `cargo run -p nimrod-lint -- [--report FILE] [--rules] [ROOT]...`
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or I/O error. The
//! report file (when requested) is written before a nonzero exit so CI can
//! archive it either way.

use std::path::PathBuf;
use std::process::ExitCode;

use nimrod_lint::{format_report, lint_tree, Diagnostic, Rule};

const USAGE: &str = "usage: nimrod-lint [--report FILE] [--rules] [ROOT]...
  ROOT       directory (or single .rs file) to scan; defaults to rust/src
  --report   also write the full report to FILE
  --rules    print the rule table and exit";

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut report_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--report" => match args.next() {
                Some(p) => report_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("nimrod-lint: --report needs a file path\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--rules" => {
                for r in Rule::ALL {
                    println!("{:<13} {}", r.id(), r.summary());
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("nimrod-lint: unknown flag `{flag}`\n{USAGE}");
                return ExitCode::from(2);
            }
            _ => roots.push(PathBuf::from(a)),
        }
    }
    if roots.is_empty() {
        roots.push(PathBuf::from("rust/src"));
    }

    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut files_scanned = 0usize;
    for root in &roots {
        match lint_tree(root) {
            Ok((d, n)) => {
                diags.extend(d);
                files_scanned += n;
            }
            Err(e) => {
                eprintln!("nimrod-lint: cannot scan {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }

    let report = format_report(&diags, files_scanned);
    if let Some(p) = &report_path {
        if let Err(e) = std::fs::write(p, &report) {
            eprintln!("nimrod-lint: cannot write report {}: {e}", p.display());
            return ExitCode::from(2);
        }
    }

    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("nimrod-lint: clean — {files_scanned} files, 0 violations");
        ExitCode::SUCCESS
    } else {
        println!(
            "nimrod-lint: {} violation(s) across {files_scanned} files",
            diags.len()
        );
        ExitCode::FAILURE
    }
}
