//! Per-rule fixture snippets for the lint's own test suite.
//!
//! Each rule ships three fixtures: one that fires, one that is clean, and
//! one silenced by a `lint:allow(<rule>): <reason>` marker. Two extra
//! fixtures exercise the hygiene rule: an allow with no reason and an allow
//! naming an unknown rule ID. The snippets are valid-looking Rust but are
//! never compiled — they exist only as scanner input (fixture tests pass
//! pseudo-paths like `"sim/state.rs"` to pick the scope under test).

pub const ND_HASH_FIRING: &str = include_str!("../fixtures/nd_hash_firing.rs");
pub const ND_HASH_CLEAN: &str = include_str!("../fixtures/nd_hash_clean.rs");
pub const ND_HASH_ALLOWED: &str = include_str!("../fixtures/nd_hash_allowed.rs");

pub const ND_CLOCK_FIRING: &str = include_str!("../fixtures/nd_clock_firing.rs");
pub const ND_CLOCK_CLEAN: &str = include_str!("../fixtures/nd_clock_clean.rs");
pub const ND_CLOCK_ALLOWED: &str = include_str!("../fixtures/nd_clock_allowed.rs");

pub const ND_FLOAT_FIRING: &str = include_str!("../fixtures/nd_float_firing.rs");
pub const ND_FLOAT_CLEAN: &str = include_str!("../fixtures/nd_float_clean.rs");
pub const ND_FLOAT_ALLOWED: &str = include_str!("../fixtures/nd_float_allowed.rs");

pub const DIRTY_PAIR_FIRING: &str = include_str!("../fixtures/dirty_pair_firing.rs");
pub const DIRTY_PAIR_CLEAN: &str = include_str!("../fixtures/dirty_pair_clean.rs");
pub const DIRTY_PAIR_ALLOWED: &str = include_str!("../fixtures/dirty_pair_allowed.rs");

pub const PANIC_BUDGET_FIRING: &str = include_str!("../fixtures/panic_budget_firing.rs");
pub const PANIC_BUDGET_CLEAN: &str = include_str!("../fixtures/panic_budget_clean.rs");
pub const PANIC_BUDGET_ALLOWED: &str = include_str!("../fixtures/panic_budget_allowed.rs");

pub const PAR_SHARED_FIRING: &str = include_str!("../fixtures/par_shared_firing.rs");
pub const PAR_SHARED_CLEAN: &str = include_str!("../fixtures/par_shared_clean.rs");
pub const PAR_SHARED_ALLOWED: &str = include_str!("../fixtures/par_shared_allowed.rs");

// WorkerPool variant: the `scatter` call site itself (and any multi-line
// closure body it opens) is in the parallel section, marker or not.
pub const PAR_SHARED_POOL_FIRING: &str = include_str!("../fixtures/par_shared_pool_firing.rs");
pub const PAR_SHARED_POOL_CLEAN: &str = include_str!("../fixtures/par_shared_pool_clean.rs");
pub const PAR_SHARED_POOL_ALLOWED: &str = include_str!("../fixtures/par_shared_pool_allowed.rs");

// Streaming-merge variant: `scatter_streaming`'s commit callback runs
// while later shards are still in flight, so the whole call statement —
// commit closure included — is scanned as parallel-section code.
pub const PAR_SHARED_STREAM_FIRING: &str = include_str!("../fixtures/par_shared_stream_firing.rs");
pub const PAR_SHARED_STREAM_CLEAN: &str = include_str!("../fixtures/par_shared_stream_clean.rs");
pub const PAR_SHARED_STREAM_ALLOWED: &str = include_str!("../fixtures/par_shared_stream_allowed.rs");

pub const ALLOW_NO_REASON: &str = include_str!("../fixtures/allow_no_reason.rs");
pub const ALLOW_UNKNOWN_RULE: &str = include_str!("../fixtures/allow_unknown_rule.rs");
